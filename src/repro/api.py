"""Top-level training entry point: pick an engine composition by problem.

``repro.fit(X, spec)`` routes to the right (GramProvider x Selector)
composition of the solver engine for the problem size and hardware:

* small m            -> blocked solver, precomputed Gram (O(m^2) is cheap)
* medium m           -> blocked solver, on-the-fly rows (no m^2 memory);
                        the fused Pallas f-update on TPU
* large m            -> shrinking repack driver around the blocked solver
* mesh given         -> row-sharded solver over the mesh's data axes

Every strategy returns the same ``SMOResult``; explicit strategies are
available for benchmarks and tests that compare compositions.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.core.batched_smo import solve_blocked
from repro.core.distributed_smo import solve_blocked_distributed
from repro.core.engine.gram import SINGLE_PASS_MAX
from repro.core.engine.types import SMOResult
from repro.core.ocssvm import SlabSpec
from repro.core.shrinking import solve_blocked_shrinking
from repro.core.smo import solve as solve_smo

Array = jax.Array

# Above this row count the shrinking repack driver wins: per-iteration
# work drops to the active (support-vector) set.
_SHRINKING_MIN_M = 8192

STRATEGIES = ("auto", "paper", "mvp", "blocked", "shrinking", "distributed")


def _auto_gram_mode(m: int, interpret: Optional[bool] = None) -> str:
    if interpret is not None:
        # An explicit interpret override is a request to exercise the
        # Pallas provider deterministically (CPU CI forces interpret=True;
        # TPU perf runs force interpret=False) — don't second-guess it
        # from the problem size or whatever backend jax resolved.
        return "pallas"
    if m <= SINGLE_PASS_MAX // 2:
        return "precomputed"
    if jax.default_backend() == "tpu":
        return "pallas"            # fused fupdate kernel on the MXU
    return "on_the_fly"


def fit(
    X: Array,
    spec: Optional[SlabSpec] = None,
    *,
    strategy: str = "auto",
    gram_mode: Optional[str] = None,
    interpret: Optional[bool] = None,
    precision: str = "f32",
    P: int = 8,
    tol: float = 1e-4,
    mesh=None,
    data_axes: Tuple[str, ...] = ("data",),
    **kwargs,
) -> SMOResult:
    """Train a One-Class Slab SVM; returns an ``SMOResult``.

    strategy: "auto" (size/hardware heuristic), "paper" / "mvp" (the
    sequential Algorithm 1 selectors), "blocked", "shrinking", or
    "distributed" (requires ``mesh``). interpret: force Pallas
    interpret mode on (True; CPU CI) or off (False; TPU) for the
    ``gram_mode="pallas"`` provider instead of auto-detecting the
    backend. precision: Gram tile-input dtype ("f32" default, "bf16",
    "f16") — halves kernel HBM traffic; dot products still accumulate
    f32 (``repro.kernels.precision``; every strategy honors it,
    including "distributed"). Extra kwargs flow to the chosen solver
    (max_iters/max_outer, patience, gamma0, ...).
    """
    if spec is None:
        spec = SlabSpec()
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}; "
                         f"expected one of {STRATEGIES}")
    m = X.shape[0]

    if strategy == "auto":
        if mesh is not None:
            strategy = "distributed"
        elif m > _SHRINKING_MIN_M:
            strategy = "shrinking"
        else:
            strategy = "blocked"

    # The sequential solvers call their iteration cap max_iters, the
    # blocked family max_outer; accept either so "auto" can reroute a call
    # without the caller caring which solver won.
    if strategy in ("paper", "mvp"):
        if "max_outer" in kwargs:
            kwargs["max_iters"] = kwargs.pop("max_outer")
    elif "max_iters" in kwargs:
        kwargs["max_outer"] = kwargs.pop("max_iters")

    if strategy == "distributed":
        if mesh is None:
            raise ValueError("strategy='distributed' needs a mesh")
        if gram_mode is not None or interpret is not None:
            raise ValueError(
                "gram_mode/interpret are not configurable for the "
                "distributed strategy: the sharded provider owns Gram "
                "access (Pallas-in-shard is a ROADMAP open item)")
        return solve_blocked_distributed(X, spec, mesh,
                                         data_axes=data_axes, P_pairs=P,
                                         tol=tol, precision=precision,
                                         **kwargs)

    gm = gram_mode if gram_mode is not None else _auto_gram_mode(m, interpret)
    if strategy in ("paper", "mvp"):
        return solve_smo(X, spec, selection=strategy, gram_mode=gm,
                         interpret=interpret, precision=precision, tol=tol,
                         **kwargs)
    if strategy == "shrinking":
        return solve_blocked_shrinking(X, spec, P=P, gram_mode=gm,
                                       interpret=interpret,
                                       precision=precision, tol=tol,
                                       **kwargs)
    return solve_blocked(X, spec, P=P, gram_mode=gm, interpret=interpret,
                         precision=precision, tol=tol, **kwargs)


def serve(X: Optional[Array] = None, spec: Optional[SlabSpec] = None, *,
          model: Optional[str] = None, registry=None,
          quota: Optional[int] = None, **kwargs):
    """Train-then-serve: a warm ``ServingModel`` ready to ``score(q)``.

    The serving-side counterpart of ``fit``: hits the process-wide
    warm-model cache (fit + SV compaction + tile packing happen once per
    (spec, data) key) and returns a ``repro.serve.ServingModel`` whose
    ``score`` runs batched through the Pallas decision kernel. kwargs
    flow to ``repro.serve.ModelCache.get_or_fit`` (cache=, offsets=,
    sv_threshold=, tn=, precision=) and on to ``fit`` (strategy,
    interpret, tol, ...); ``precision="bf16"`` trains AND serves with
    16-bit Gram tile streams (f32 accumulate/epilogue).

    ``model=`` switches on multi-model routing: with ``X`` the recipe is
    registered under that name in ``registry`` (default: the
    process-wide ``repro.serve.default_registry()``; idempotent — a
    *different* recipe under the same name raises
    ``DuplicateModelError``) and the registry's warm model comes back;
    without ``X`` it is a pure name lookup (``UnknownModelError`` if
    absent). ``quota=`` records the per-model admission budget the
    ``AdmissionController`` enforces.
    """
    from repro.serve.registry import serve as _serve
    return _serve(X, spec, model=model, registry=registry, quota=quota,
                  **kwargs)
