"""Top-level training entry point: pick an engine composition by problem.

``repro.fit(X, spec)`` routes to the right (GramProvider x Selector)
composition of the solver engine for the problem size and hardware:

* small m            -> blocked solver, precomputed Gram (O(m^2) is cheap)
* medium m           -> blocked solver, on-the-fly rows (no m^2 memory);
                        the fused Pallas f-update on TPU
* large m            -> shrinking repack driver around the blocked solver
* mesh given / "sharded" -> row-sharded solver over the mesh's data axes
                        (per-shard Pallas fupdate on the hot loop); large
                        m additionally gets the sharded shrinking repack
                        driver. With no mesh given, "sharded" builds one
                        from the launch layer
                        (``repro.launch.mesh.make_solver_mesh``).

Every strategy returns the same ``SMOResult``; explicit strategies are
available for benchmarks and tests that compare compositions.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.core.batched_smo import solve_blocked
from repro.core.distributed_smo import solve_blocked_distributed
from repro.core.engine.gram import SINGLE_PASS_MAX
from repro.core.engine.types import SMOResult
from repro.core.ocssvm import SlabSpec
from repro.core.shrinking import (solve_blocked_shrinking,
                                  solve_sharded_shrinking)
from repro.core.smo import solve as solve_smo

Array = jax.Array

# Above this row count the shrinking repack driver wins: per-iteration
# work drops to the active (support-vector) set.
_SHRINKING_MIN_M = 8192

STRATEGIES = ("auto", "paper", "mvp", "blocked", "pallas", "shrinking",
              "distributed", "sharded")


def _auto_gram_mode(m: int, interpret: Optional[bool] = None) -> str:
    if interpret is not None:
        # An explicit interpret override is a request to exercise the
        # Pallas provider deterministically (CPU CI forces interpret=True;
        # TPU perf runs force interpret=False) — don't second-guess it
        # from the problem size or whatever backend jax resolved.
        return "pallas"
    if m <= SINGLE_PASS_MAX // 2:
        return "precomputed"
    if jax.default_backend() == "tpu":
        return "pallas"            # fused fupdate kernel on the MXU
    return "on_the_fly"


def fit(
    X: Array,
    spec: Optional[SlabSpec] = None,
    *,
    strategy: str = "auto",
    gram_mode: Optional[str] = None,
    interpret: Optional[bool] = None,
    precision: str = "f32",
    P: int = 8,
    tol: float = 1e-4,
    mesh=None,
    data_axes: Tuple[str, ...] = ("data",),
    multi_pod: bool = False,
    ledger=None,
    **kwargs,
) -> SMOResult:
    """Train a One-Class Slab SVM; returns an ``SMOResult``.

    strategy: "auto" (size/hardware heuristic), "paper" / "mvp" (the
    sequential Algorithm 1 selectors), "blocked", "pallas" (the blocked
    solver pinned to the Pallas Gram/fupdate provider — tile sizes come
    from the committed autotune table, ``kernels/tuned_configs.json``,
    unless ``REPRO_NO_AUTOTUNE=1``; see docs/kernels.md), "shrinking",
    "sharded" (row-sharded engine over a mesh — built from the launch
    layer via ``make_solver_mesh(multi_pod=...)`` when ``mesh`` is not
    given; large m composes with the sharded shrinking repack driver),
    or "distributed" (the plain row-sharded solver; requires ``mesh``).
    interpret: force Pallas interpret mode on (True; CPU CI) or off
    (False; TPU) instead of auto-detecting the backend — this reaches
    the per-shard fupdate kernel for the sharded strategies too.
    precision: Gram tile-input dtype ("f32" default, "bf16", "f16") —
    halves kernel HBM traffic; dot products still accumulate f32
    (``repro.kernels.precision``; every strategy honors it, including
    the sharded ones). ledger: a
    ``repro.core.engine.CollectiveLedger`` the sharded strategies fill
    with per-device collective-bytes accounting (ignored by the local
    strategies). Extra kwargs flow to the chosen solver
    (max_iters/max_outer, patience, gamma0, ...).
    """
    if spec is None:
        spec = SlabSpec()
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}; "
                         f"expected one of {STRATEGIES}")
    m = X.shape[0]

    if strategy == "auto":
        if mesh is not None:
            strategy = "sharded"
        elif m > _SHRINKING_MIN_M:
            strategy = "shrinking"
        else:
            strategy = "blocked"

    # The sequential solvers call their iteration cap max_iters, the
    # blocked family max_outer; accept either so "auto" can reroute a call
    # without the caller caring which solver won.
    if strategy in ("paper", "mvp"):
        if "max_outer" in kwargs:
            kwargs["max_iters"] = kwargs.pop("max_outer")
    elif "max_iters" in kwargs:
        kwargs["max_outer"] = kwargs.pop("max_iters")

    if strategy in ("distributed", "sharded"):
        if gram_mode is not None:
            raise ValueError(
                "gram_mode is not configurable for the sharded/"
                "distributed strategies: the sharded provider owns Gram "
                "access (its hot loop is the per-shard Pallas fupdate; "
                "the local repack solves of the sharded shrinking driver "
                "pick their own provider)")
        if strategy == "distributed" and mesh is None:
            raise ValueError("strategy='distributed' needs a mesh; "
                             "use strategy='sharded' to build one from "
                             "the launch layer")
        if mesh is None:
            from repro.launch.mesh import make_solver_mesh
            mesh, data_axes = make_solver_mesh(multi_pod=multi_pod)
        if strategy == "sharded" and m > _SHRINKING_MIN_M:
            return solve_sharded_shrinking(X, spec, mesh,
                                           data_axes=data_axes,
                                           P_pairs=P, tol=tol,
                                           precision=precision,
                                           interpret=interpret,
                                           ledger=ledger, **kwargs)
        # Below the shrinking threshold the plain sharded solve runs;
        # surface a clear error for shrinking-only knobs instead of an
        # opaque TypeError (the accepted kwargs must not silently change
        # when a growing dataset crosses the threshold).
        shrink_only = [k for k in ("warm_iters", "max_rounds",
                                   "round_iters", "margin", "gather_max")
                       if k in kwargs]
        if shrink_only:
            raise ValueError(
                f"kwargs {shrink_only} configure the sharded shrinking "
                f"driver, which only runs for m > {_SHRINKING_MIN_M} "
                f"(got m={m}); drop them or call "
                "repro.core.solve_sharded_shrinking directly")
        return solve_blocked_distributed(X, spec, mesh,
                                         data_axes=data_axes, P_pairs=P,
                                         tol=tol, precision=precision,
                                         interpret=interpret,
                                         ledger=ledger, **kwargs)

    if strategy == "pallas":
        if gram_mode is not None and gram_mode != "pallas":
            raise ValueError(
                f"strategy='pallas' pins gram_mode='pallas'; got "
                f"gram_mode={gram_mode!r} — drop it or use "
                f"strategy='blocked'")
        return solve_blocked(X, spec, P=P, gram_mode="pallas",
                             interpret=interpret, precision=precision,
                             tol=tol, **kwargs)

    gm = gram_mode if gram_mode is not None else _auto_gram_mode(m, interpret)
    if strategy in ("paper", "mvp"):
        return solve_smo(X, spec, selection=strategy, gram_mode=gm,
                         interpret=interpret, precision=precision, tol=tol,
                         **kwargs)
    if strategy == "shrinking":
        return solve_blocked_shrinking(X, spec, P=P, gram_mode=gm,
                                       interpret=interpret,
                                       precision=precision, tol=tol,
                                       **kwargs)
    return solve_blocked(X, spec, P=P, gram_mode=gm, interpret=interpret,
                         precision=precision, tol=tol, **kwargs)


def serve(X: Optional[Array] = None, spec: Optional[SlabSpec] = None, *,
          model: Optional[str] = None, registry=None,
          quota: Optional[int] = None, **kwargs):
    """Train-then-serve: a warm ``ServingModel`` ready to ``score(q)``.

    The serving-side counterpart of ``fit``: hits the process-wide
    warm-model cache (fit + SV compaction + tile packing happen once per
    (spec, data) key) and returns a ``repro.serve.ServingModel`` whose
    ``score`` runs batched through the Pallas decision kernel. kwargs
    flow to ``repro.serve.ModelCache.get_or_fit`` (cache=, offsets=,
    sv_threshold=, tn=, precision=) and on to ``fit`` (strategy,
    interpret, tol, ...); ``precision="bf16"`` trains AND serves with
    16-bit Gram tile streams (f32 accumulate/epilogue).

    ``model=`` switches on multi-model routing: with ``X`` the recipe is
    registered under that name in ``registry`` (default: the
    process-wide ``repro.serve.default_registry()``; idempotent — a
    *different* recipe under the same name raises
    ``DuplicateModelError``) and the registry's warm model comes back;
    without ``X`` it is a pure name lookup (``UnknownModelError`` if
    absent). ``quota=`` records the per-model admission budget the
    ``AdmissionController`` enforces.
    """
    from repro.serve.registry import serve as _serve
    return _serve(X, spec, model=model, registry=registry, quota=quota,
                  **kwargs)
