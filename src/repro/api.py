"""Top-level training entry point: pick an engine composition by problem.

``repro.fit(X, spec)`` routes to the right (GramProvider x Selector)
composition of the solver engine for the problem size and hardware:

* small m            -> blocked solver, precomputed Gram (O(m^2) is cheap)
* medium m           -> blocked solver, on-the-fly rows (no m^2 memory);
                        the fused Pallas f-update on TPU
* large m            -> shrinking repack driver around the blocked solver
* mesh given / "sharded" -> row-sharded solver over the mesh's data axes
                        (per-shard Pallas fupdate on the hot loop); large
                        m additionally gets the sharded shrinking repack
                        driver. With no mesh given, "sharded" builds one
                        from the launch layer
                        (``repro.launch.mesh.make_solver_mesh``).

Every strategy returns the same ``SMOResult``; explicit strategies are
available for benchmarks and tests that compare compositions.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import numpy as np

from repro.core.batched_smo import solve_blocked
from repro.core.distributed_smo import solve_blocked_distributed
from repro.core.engine.gram import SINGLE_PASS_MAX
from repro.core.engine.state import (SolverArtifact, WarmStart,
                                     artifact_from_result,
                                     prepare_warm_start)
from repro.core.engine.types import SMOResult
from repro.core.ocssvm import SlabSpec
from repro.core.shrinking import (solve_blocked_shrinking,
                                  solve_sharded_shrinking)
from repro.core.smo import solve as solve_smo

Array = jax.Array

# Above this row count the shrinking repack driver wins: per-iteration
# work drops to the active (support-vector) set.
_SHRINKING_MIN_M = 8192

STRATEGIES = ("auto", "paper", "mvp", "blocked", "pallas", "shrinking",
              "distributed", "sharded")


def _auto_gram_mode(m: int, interpret: Optional[bool] = None) -> str:
    if interpret is not None:
        # An explicit interpret override is a request to exercise the
        # Pallas provider deterministically (CPU CI forces interpret=True;
        # TPU perf runs force interpret=False) — don't second-guess it
        # from the problem size or whatever backend jax resolved.
        return "pallas"
    if m <= SINGLE_PASS_MAX // 2:
        return "precomputed"
    if jax.default_backend() == "tpu":
        return "pallas"            # fused fupdate kernel on the MXU
    return "on_the_fly"


def fit(
    X: Array,
    spec: Optional[SlabSpec] = None,
    *,
    strategy: str = "auto",
    gram_mode: Optional[str] = None,
    interpret: Optional[bool] = None,
    precision: str = "f32",
    P: int = 8,
    tol: float = 1e-4,
    mesh=None,
    data_axes: Tuple[str, ...] = ("data",),
    multi_pod: bool = False,
    ledger=None,
    warm_start=None,
    warm_info_out: Optional[dict] = None,
    **kwargs,
) -> SMOResult:
    """Train a One-Class Slab SVM; returns an ``SMOResult``.

    strategy: "auto" (size/hardware heuristic), "paper" / "mvp" (the
    sequential Algorithm 1 selectors), "blocked", "pallas" (the blocked
    solver pinned to the Pallas Gram/fupdate provider — tile sizes come
    from the committed autotune table, ``kernels/tuned_configs.json``,
    unless ``REPRO_NO_AUTOTUNE=1``; see docs/kernels.md), "shrinking",
    "sharded" (row-sharded engine over a mesh — built from the launch
    layer via ``make_solver_mesh(multi_pod=...)`` when ``mesh`` is not
    given; large m composes with the sharded shrinking repack driver),
    or "distributed" (the plain row-sharded solver; requires ``mesh``).
    interpret: force Pallas interpret mode on (True; CPU CI) or off
    (False; TPU) instead of auto-detecting the backend — this reaches
    the per-shard fupdate kernel for the sharded strategies too.
    precision: Gram tile-input dtype ("f32" default, "bf16", "f16") —
    halves kernel HBM traffic; dot products still accumulate f32
    (``repro.kernels.precision``; every strategy honors it, including
    the sharded ones). ledger: a
    ``repro.core.engine.CollectiveLedger`` the sharded strategies fill
    with per-device collective-bytes accounting (ignored by the local
    strategies). warm_start: a prior fit to seed from — a
    ``SolverArtifact`` (or an ``SMOResult``, converted; or an
    already-prepared ``engine.WarmStart``): gamma seeds from the
    overlapping rows and the f-cache is reconciled with one fused rank-s
    sweep instead of the O(m^2) init (``docs/streaming.md``; the
    paper/mvp strategies seed gamma only). warm_info_out: a dict the
    warm-start accounting (overlap/fresh/expired/correction counts) is
    written into. Extra kwargs flow to the chosen solver
    (max_iters/max_outer, patience, gamma0, ...).
    """
    if spec is None:
        spec = SlabSpec()
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}; "
                         f"expected one of {STRATEGIES}")
    m = X.shape[0]

    warm = None
    if warm_start is not None:
        if isinstance(warm_start, WarmStart):
            warm = warm_start          # prepared by the caller (fit_update)
        else:
            art = _as_artifact(warm_start, precision=precision)
            warm, winfo = prepare_warm_start(art, X, spec,
                                             precision=precision)
            if warm_info_out is not None:
                warm_info_out.update(dataclasses.asdict(winfo))

    if strategy == "auto":
        if mesh is not None:
            strategy = "sharded"
        elif m > _SHRINKING_MIN_M:
            strategy = "shrinking"
        else:
            strategy = "blocked"

    # The sequential solvers call their iteration cap max_iters, the
    # blocked family max_outer; accept either so "auto" can reroute a call
    # without the caller caring which solver won.
    if strategy in ("paper", "mvp"):
        if "max_outer" in kwargs:
            kwargs["max_iters"] = kwargs.pop("max_outer")
    elif "max_iters" in kwargs:
        kwargs["max_outer"] = kwargs.pop("max_iters")

    if strategy in ("distributed", "sharded"):
        if gram_mode is not None:
            raise ValueError(
                "gram_mode is not configurable for the sharded/"
                "distributed strategies: the sharded provider owns Gram "
                "access (its hot loop is the per-shard Pallas fupdate; "
                "the local repack solves of the sharded shrinking driver "
                "pick their own provider)")
        if strategy == "distributed" and mesh is None:
            raise ValueError("strategy='distributed' needs a mesh; "
                             "use strategy='sharded' to build one from "
                             "the launch layer")
        if mesh is None:
            from repro.launch.mesh import make_solver_mesh
            mesh, data_axes = make_solver_mesh(multi_pod=multi_pod)
        if strategy == "sharded" and m > _SHRINKING_MIN_M:
            return solve_sharded_shrinking(X, spec, mesh,
                                           data_axes=data_axes,
                                           P_pairs=P, tol=tol,
                                           precision=precision,
                                           interpret=interpret,
                                           ledger=ledger, warm=warm,
                                           **kwargs)
        # Below the shrinking threshold the plain sharded solve runs;
        # surface a clear error for shrinking-only knobs instead of an
        # opaque TypeError (the accepted kwargs must not silently change
        # when a growing dataset crosses the threshold).
        shrink_only = [k for k in ("warm_iters", "max_rounds",
                                   "round_iters", "margin", "gather_max")
                       if k in kwargs]
        if shrink_only:
            raise ValueError(
                f"kwargs {shrink_only} configure the sharded shrinking "
                f"driver, which only runs for m > {_SHRINKING_MIN_M} "
                f"(got m={m}); drop them or call "
                "repro.core.solve_sharded_shrinking directly")
        return solve_blocked_distributed(X, spec, mesh,
                                         data_axes=data_axes, P_pairs=P,
                                         tol=tol, precision=precision,
                                         interpret=interpret,
                                         ledger=ledger, warm=warm,
                                         **kwargs)

    if strategy == "pallas":
        if gram_mode is not None and gram_mode != "pallas":
            raise ValueError(
                f"strategy='pallas' pins gram_mode='pallas'; got "
                f"gram_mode={gram_mode!r} — drop it or use "
                f"strategy='blocked'")
        return solve_blocked(X, spec, P=P, gram_mode="pallas",
                             interpret=interpret, precision=precision,
                             tol=tol, warm=warm, **kwargs)

    gm = gram_mode if gram_mode is not None else _auto_gram_mode(m, interpret)
    if strategy in ("paper", "mvp"):
        # The sequential facades predate the warm f-cache path: seed
        # gamma only (the init pass still scores it from scratch).
        if warm is not None:
            kwargs["gamma0"] = warm.gamma0
        return solve_smo(X, spec, selection=strategy, gram_mode=gm,
                         interpret=interpret, precision=precision, tol=tol,
                         **kwargs)
    if strategy == "shrinking":
        return solve_blocked_shrinking(X, spec, P=P, gram_mode=gm,
                                       interpret=interpret,
                                       precision=precision, tol=tol,
                                       warm=warm, **kwargs)
    return solve_blocked(X, spec, P=P, gram_mode=gm, interpret=interpret,
                         precision=precision, tol=tol, warm=warm, **kwargs)


def _as_artifact(prev, *, precision: str = "f32") -> SolverArtifact:
    if isinstance(prev, SolverArtifact):
        return prev
    if isinstance(prev, SMOResult):
        return artifact_from_result(prev, precision=precision)
    raise TypeError(
        f"expected a SolverArtifact or SMOResult, got {type(prev).__name__}")


def fit_update(
    prev,
    X_new: Array,
    spec: Optional[SlabSpec] = None,
    *,
    min_overlap: float = 0.5,
    stats_out: Optional[dict] = None,
    **kwargs,
) -> SMOResult:
    """Delta-solve: re-fit on ``X_new`` warm-started from a prior fit.

    ``prev`` is a ``SolverArtifact`` (or an ``SMOResult``, converted).
    Rows are matched by content hash — appended rows enter with zero
    coefficient, expired rows' contribution is subtracted from the
    f-cache with the same fused rank-s sweep the hot loop runs — so the
    solve starts next to the prior optimum: on small deltas it converges
    in a small fraction of the cold iteration count (the streaming
    acceptance test asserts <= 25% on a 5% append).

    When the overlap fraction falls below ``min_overlap`` the warm seed
    is more misdirection than head start (most of the f-cache would be
    corrections), so the call falls back to a cold ``fit`` — the routing
    is recorded in ``stats_out`` (``mode``: "warm" | "cold", plus the
    overlap/fresh/expired/correction counts). The same cold route — with
    ``stats_out["fallback"]`` recording why — is taken when the warm
    path cannot run at all: an explicit ``gamma0`` seed among the kwargs
    (the solvers take ``warm=`` or ``gamma0=``, not both), or an engine
    raising ``NotImplementedError`` from incremental structures
    mid-update (the sharded Gram facade's ``append_rows``). A streaming
    refresh degrades to a cold refit; it never surfaces a traceback.

    ``spec`` defaults to the artifact's; kwargs flow to ``fit``
    (strategy, precision, tol, ...). ``precision`` defaults to the
    artifact's so the warm correction rows are rounded to the same Gram
    tiles the prior solve streamed.
    """
    precision = kwargs.pop("precision", None)
    art = _as_artifact(prev, precision=precision or "f32")
    if precision is None:
        precision = art.precision
    if spec is None:
        spec = art.spec
    warm, info = prepare_warm_start(art, X_new, spec, precision=precision)
    mode = "warm" if info.overlap_frac >= min_overlap else "cold"
    fallback = None
    g0 = kwargs.get("gamma0")
    if g0 is not None:
        if int(np.shape(g0)[0]) == int(X_new.shape[0]):
            # An explicit dual seed and a warm-start seed are mutually
            # exclusive down in the solvers ("pass warm= or gamma0=,
            # not both") — detect it HERE and take the documented cold
            # route (where gamma0 IS the seed) instead of surfacing the
            # solver's ValueError after warm state was prepared.
            mode = "cold"
            fallback = "gamma0_conflict"
        else:
            # A seed pinned to a previous data shape (e.g. a registry
            # recipe carrying gamma0 in its fit kwargs, refreshed with
            # appended rows) cannot seed ANY fit on X_new — drop it so
            # the warm/cold routing above stands, rather than crash
            # whichever route it reaches.
            kwargs.pop("gamma0")
            fallback = "gamma0_stale_dropped"
    p_injected = False
    if mode == "warm" and "P" not in kwargs:
        # A delta-solve's violators concentrate on the delta: the fresh
        # rows must acquire mass and the corrected rows re-equilibrate,
        # while the rest of the active set barely moves. Scaling the
        # working-set size with the delta lets one rank-2P sweep touch
        # most of the moving set — fewer full HBM passes over X, which
        # is the blocked solver's per-iteration cost — instead of
        # drip-feeding 8 pairs at a time through a cold-sized block.
        # Capped at m/16 so the per-shard top_k of the sharded engine
        # (local rows ~ m/devices) never asks for more pairs than a
        # shard holds.
        moving = info.n_fresh + info.n_corr
        kwargs["P"] = max(8, min(64, info.m // 16,
                                 1 << max(moving // 2, 1).bit_length()))
        p_injected = True
    if stats_out is not None:
        stats_out.update(dataclasses.asdict(info))
        stats_out["mode"] = mode
        stats_out["P"] = kwargs.get("P")
        if fallback is not None:
            stats_out["fallback"] = fallback
    if mode == "cold":
        return fit(X_new, spec, precision=precision, **kwargs)
    try:
        return fit(X_new, spec, precision=precision, warm_start=warm,
                   **kwargs)
    except NotImplementedError as e:
        # The documented cold-refit fallback for engines whose
        # incremental structures cannot mutate mid-update — e.g. the
        # sharded Gram facade raising from append_rows/expire_rows. A
        # streaming refresh must degrade to a cold refit (counted in the
        # registry's refresh_modes), never surface a traceback after the
        # warm state was prepared.
        if stats_out is not None:
            stats_out["mode"] = "cold"
            stats_out["fallback"] = f"warm_unsupported: {e}"
        if p_injected:
            kwargs.pop("P", None)   # the delta-scaled working set was
            #                         sized for the warm route only
        return fit(X_new, spec, precision=precision, **kwargs)


def serve(X: Optional[Array] = None, spec: Optional[SlabSpec] = None, *,
          model: Optional[str] = None, registry=None,
          quota: Optional[int] = None, **kwargs):
    """Train-then-serve: a warm ``ServingModel`` ready to ``score(q)``.

    The serving-side counterpart of ``fit``: hits the process-wide
    warm-model cache (fit + SV compaction + tile packing happen once per
    (spec, data) key) and returns a ``repro.serve.ServingModel`` whose
    ``score`` runs batched through the Pallas decision kernel. kwargs
    flow to ``repro.serve.ModelCache.get_or_fit`` (cache=, offsets=,
    sv_threshold=, tn=, precision=) and on to ``fit`` (strategy,
    interpret, tol, ...); ``precision="bf16"`` trains AND serves with
    16-bit Gram tile streams (f32 accumulate/epilogue).

    ``model=`` switches on multi-model routing: with ``X`` the recipe is
    registered under that name in ``registry`` (default: the
    process-wide ``repro.serve.default_registry()``; idempotent — a
    *different* recipe under the same name raises
    ``DuplicateModelError``) and the registry's warm model comes back;
    without ``X`` it is a pure name lookup (``UnknownModelError`` if
    absent). ``quota=`` records the per-model admission budget the
    ``AdmissionController`` enforces.
    """
    from repro.serve.registry import serve as _serve
    return _serve(X, spec, model=model, registry=registry, quota=quota,
                  **kwargs)
