"""Adafactor (factored second moments, no momentum) — the optimizer for the
multi-hundred-B MoE configs where AdamW's fp32 moments cannot fit HBM even
fully sharded (arctic-480b: 2 x 4 bytes/param = 3.8 TB).

Factored state for rank>=2 leaves is O(rows + cols) instead of O(rows*cols):
arctic's optimizer state drops from 3.8 TB to ~2 GB. Follows Shazeer &
Stern (2018): exponential decay 1 - step^-0.8, update RMS clipping at 1.0,
relative step sizes off (we pass an explicit lr schedule).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdafactorState(NamedTuple):
    step: jax.Array
    vr: dict   # row second moments (rank>=2) or full v (rank<2)
    vc: dict   # col second moments (rank>=2) or empty placeholder


def _factored(shape) -> bool:
    return len(shape) >= 2


def init(params) -> AdafactorState:
    def vrow(p):
        if _factored(p.shape):
            return jnp.zeros(p.shape[:-1], jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)

    def vcol(p):
        if _factored(p.shape):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        return jnp.zeros((1,), jnp.float32)

    return AdafactorState(step=jnp.zeros((), jnp.int32),
                          vr=jax.tree.map(vrow, params),
                          vc=jax.tree.map(vcol, params))


def update(grads, state: AdafactorState, params, *, lr,
           eps: float = 1e-30, clip_threshold: float = 1.0,
           weight_decay: float = 0.0):
    step = state.step + 1
    beta2 = 1.0 - step.astype(jnp.float32) ** -0.8

    def upd(p, g, vr, vc):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if _factored(p.shape):
            vr = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
            rfac = jax.lax.rsqrt(
                vr / jnp.mean(vr, axis=-1, keepdims=True) + eps)
            cfac = jax.lax.rsqrt(vc + eps)
            u = g * rfac[..., None] * cfac[..., None, :]
        else:
            vr = beta2 * vr + (1 - beta2) * g2
            u = g * jax.lax.rsqrt(vr + eps)
        # RMS clip.
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms / clip_threshold)
        new_p = p.astype(jnp.float32) - lr * u
        if weight_decay:
            new_p = new_p - lr * weight_decay * p.astype(jnp.float32)
        return new_p.astype(p.dtype), vr, vc

    out = jax.tree.map(upd, params, grads, state.vr, state.vc)
    def is_tuple(t):
        return isinstance(t, tuple)
    return (jax.tree.map(lambda t: t[0], out, is_leaf=is_tuple),
            AdafactorState(step=step,
                           vr=jax.tree.map(lambda t: t[1], out, is_leaf=is_tuple),
                           vc=jax.tree.map(lambda t: t[2], out, is_leaf=is_tuple)))
