"""AdamW in pure JAX. Moments in fp32, sharded like the params (plus the
params' FSDP axis when enabled — ZeRO-1 falls out of using identical
PartitionSpecs for m/v as for the weights)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def update(grads, state: AdamWState, params, *, lr, b1: float = 0.9,
           b2: float = 0.95, eps: float = 1e-8, weight_decay: float = 0.1):
    step = state.step + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        new_p = p.astype(jnp.float32) - lr * (
            mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v)
