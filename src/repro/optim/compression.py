"""Error-feedback int8 gradient compression for data-parallel all-reduce.

1-bit/8-bit SGD family trick: quantize the local gradient to int8 with a
per-leaf scale before the cross-replica sum, keep the quantization residual
locally, add it back into the next step's gradient (error feedback keeps
the scheme unbiased in the long run). Cuts DP all-reduce bytes 4x vs fp32
(2x vs bf16) — the knob that matters on the inter-pod links.

Used by ``train/train_step.py`` when compress_grads=True: gradients are
computed per-shard under shard_map, compressed, psum'd, decompressed.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """-> (int8 q, fp32 scale, new residual). q*scale + residual == g + err."""
    g = g.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, g - deq


def psum_compressed(grads, err_state, axis_names) -> Tuple[dict, dict]:
    """All-reduce int8-quantized grads over ``axis_names`` (inside shard_map).

    The int8 payload is summed in int32 (no overflow below 2^23 replicas);
    scales are psum-averaged. Returns (mean fp32 grads, new error state).
    """
    n = jax.lax.psum(1, axis_names)

    def one(g, err):
        q, scale, new_err = compress(g, err)
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_names)
        ssum = jax.lax.psum(scale, axis_names)
        # Each replica used its own scale; approximate the sum with the
        # mean scale (error feedback absorbs the residual).
        mean_g = qsum.astype(jnp.float32) * (ssum / n) / n
        return mean_g, new_err

    out = jax.tree.map(one, grads, err_state)
    def is_tuple(t):
        return isinstance(t, tuple)
    return (jax.tree.map(lambda t: t[0], out, is_leaf=is_tuple),
            jax.tree.map(lambda t: t[1], out, is_leaf=is_tuple))
