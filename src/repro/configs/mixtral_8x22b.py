"""mixtral-8x22b — 8-expert top-2 MoE, sliding-window attention
[arXiv:2401.04088; hf]. Window 4096 per the assigned spec."""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=32768,
    layer_pattern=(LayerSpec("swa", moe=True),),
    window=4096,
    n_experts=8, top_k=2, expert_ff=16384,
    mlp_type="swiglu", rope_theta=1000000.0,
)
