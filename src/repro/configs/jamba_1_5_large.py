"""jamba-1.5-large-398b — Mamba+attention 1:7 interleave with 16e top-2 MoE
every other layer [arXiv:2403.19887; hf].

Period of 8: one attention layer then seven Mamba layers; MoE MLP on odd
period positions (every 2nd layer). 72 layers = 9 periods. Adafactor (398B).
"""
from repro.configs.base import ArchConfig, LayerSpec

_PERIOD = tuple(
    LayerSpec("full" if i == 0 else "mamba", moe=(i % 2 == 1))
    for i in range(8)
)

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536,
    layer_pattern=_PERIOD,
    n_experts=16, top_k=2, expert_ff=24576,
    ssm_state=16, ssm_conv=4, ssm_expand=2,
    mlp_type="swiglu", rope_theta=1000000.0,
    optimizer="adafactor",
)
