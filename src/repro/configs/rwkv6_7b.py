"""rwkv6-7b "Finch" — attention-free, data-dependent decay
[arXiv:2404.05892; hf]. 64 heads x 64 dims; squared-ReLU channel mix."""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=0, head_dim=64,
    d_ff=14336, vocab_size=65536,
    layer_pattern=(LayerSpec("rwkv"),),
    rwkv_head_dim=64,
    mlp_type="relu2",
)
