"""internvl2-26b — InternViT + InternLM2 VLM [arXiv:2404.16821; hf].

LLM BACKBONE only: the InternViT frontend is a stub — input_specs()
supplies 256 precomputed patch embeddings (B, 256, d_model) prepended to
the text embeddings. Loss/logits are evaluated on text positions.
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=92553,
    layer_pattern=(LayerSpec("full"),),
    mlp_type="swiglu", rope_theta=1000000.0,
    frontend="vision", n_frontend_tokens=256,
)
