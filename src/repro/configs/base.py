"""Unified architecture configuration for the assigned model pool.

Every architecture is described by a repeating ``layer_pattern`` of
``LayerSpec``s (mixer kind + MoE flag). The decoder stack scans over whole
pattern periods (params stacked per period) so HLO size stays flat in depth;
a partial tail period is unrolled.

Mixer kinds: "full" (causal GQA), "swa" (sliding-window GQA), "mamba"
(selective SSM), "rwkv" (RWKV6 Finch time-mix).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str = "full"          # full | swa | mamba | rwkv
    moe: bool = False


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    layer_pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    window: int = 0              # sliding-window size for "swa" mixers
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 2
    expert_ff: int = 0
    dense_residual_ff: int = 0   # arctic-style parallel dense MLP
    capacity_factor: float = 1.25
    # --- MLP ---
    mlp_type: str = "swiglu"     # swiglu | geglu | relu2
    # --- SSM (mamba) ---
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0             # 0 => d_model // 16
    # --- RWKV ---
    rwkv_head_dim: int = 64
    # --- modality frontend (stub) ---
    frontend: str = "none"       # none | audio | vision
    n_frontend_tokens: int = 0   # vision: patch tokens prepended
    # --- numerics / misc ---
    param_dtype: str = "bfloat16"
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    optimizer: str = "adamw"     # adamw | adafactor (multi-hundred-B MoEs)
    remat: str = "full"          # full | dots | none | boundaries
    tp_mlp: bool = False         # explicit shard_map TP MLP (bf16 psums)
    moe_impl: str = "psum"       # psum (weights FSDP'd, EP combine psum)
    #                            | a2a (experts over "data" via all-to-all,
    #                              ff-TP over "model"; weights never move)
    notes: str = ""

    # ------------------------------------------------------------------
    @property
    def period(self) -> int:
        return len(self.layer_pattern)

    @property
    def n_repeats(self) -> int:
        return self.n_layers // self.period

    @property
    def n_tail(self) -> int:
        return self.n_layers - self.n_repeats * self.period

    def layer_spec(self, i: int) -> LayerSpec:
        return self.layer_pattern[i % self.period]

    @property
    def dt_rank_actual(self) -> int:
        return self.dt_rank if self.dt_rank else max(1, self.d_model // 16)

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def moe_ff(self) -> int:
        return self.expert_ff if self.expert_ff else self.d_ff

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the embedding/unembedding
        shard on any reasonable model axis (Megatron-style padding; pad
        columns are masked to -inf in the loss)."""
        return ((self.vocab_size + 255) // 256) * 256

    def param_count(self) -> int:
        """Total parameters (embeddings included, frontend stubs excluded)."""
        d = self.d_model
        total = self.vocab_size * d * 2          # embed + unembed
        for i in range(self.n_layers):
            spec = self.layer_spec(i)
            if spec.mixer in ("full", "swa"):
                total += d * (self.n_heads * self.head_dim)          # wq
                total += 2 * d * (self.n_kv_heads * self.head_dim)   # wk, wv
                total += (self.n_heads * self.head_dim) * d          # wo
            elif spec.mixer == "mamba":
                inner = self.ssm_inner
                total += d * 2 * inner                                # in_proj
                total += inner * self.ssm_conv                        # conv
                total += inner * (self.dt_rank_actual + 2 * self.ssm_state)
                total += self.dt_rank_actual * inner                  # dt_proj
                total += inner * self.ssm_state + inner               # A_log, D
                total += inner * d                                    # out_proj
            elif spec.mixer == "rwkv":
                total += 4 * d * d + d * d                            # r,k,v,g,o
                total += 2 * d * 64                                   # decay lora
            if spec.moe:
                total += self.n_experts * self._ffn_params(self.moe_ff)
                total += d * self.n_experts                           # router
                if self.dense_residual_ff:
                    total += self._ffn_params(self.dense_residual_ff)
            else:
                total += self._ffn_params(self.d_ff)
            total += 2 * d                                            # norms
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE top-k instead of all experts)."""
        if self.n_experts == 0:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        # subtract inactive experts
        n_moe_layers = sum(1 for i in range(self.n_layers)
                           if self.layer_spec(i).moe)
        total -= (self.n_experts - self.top_k) * n_moe_layers \
            * self._ffn_params(self.moe_ff)
        return total

    def _ffn_params(self, ff: int) -> int:
        gated = self.mlp_type in ("swiglu", "geglu")
        return self.d_model * ff * (3 if gated else 2)

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        period = self.period
        n_layers = max(period, 2 if period == 1 else period)
        small_heads = 4
        head_dim = 16
        d_model = small_heads * head_dim
        return dataclasses.replace(
            self,
            n_layers=n_layers,
            d_model=d_model,
            n_heads=small_heads,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=head_dim,
            d_ff=128,
            expert_ff=64 if self.n_experts else 0,
            dense_residual_ff=64 if self.dense_residual_ff else 0,
            vocab_size=512,
            n_experts=4 if self.n_experts else 0,
            window=min(self.window, 8) if self.window else 0,
            ssm_state=8,
            ssm_expand=2,
            dt_rank=8,
            rwkv_head_dim=16,
            n_frontend_tokens=4 if self.n_frontend_tokens else 0,
            param_dtype="float32",
            remat="none",
        )


# Shape cells assigned to every LM arch (seq_len, global_batch, step kind).
SHAPES = {
    "train_4k":    dict(seq_len=4096,   batch=256, step="train"),
    "prefill_32k": dict(seq_len=32768,  batch=32,  step="prefill"),
    "decode_32k":  dict(seq_len=32768,  batch=128, step="decode"),
    "long_500k":   dict(seq_len=524288, batch=1,   step="decode"),
}


def is_subquadratic(cfg: ArchConfig) -> bool:
    """True if no layer needs an unbounded full-attention KV cache."""
    return all(spec.mixer != "full" for spec in cfg.layer_pattern)


def long_context_capable(cfg: ArchConfig) -> bool:
    """long_500k policy: run for archs whose sequence mixing is
    sub-quadratic (SSM/hybrid/SWA-dominant); skip pure full-attention."""
    kinds = {spec.mixer for spec in cfg.layer_pattern}
    if kinds == {"full"}:
        return False
    return True
