"""gemma3-27b — 5:1 local:global attention, 128k ctx [hf:google/gemma-3-27b-pt; unverified].

62 layers = 10 full (5 SWA + 1 global) periods + 2 SWA tail layers.
GeGLU MLPs, 1024-token sliding window on local layers, head_dim 128
(decoupled from d_model / n_heads, as in the released config).
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=21504, vocab_size=262144,
    layer_pattern=(LayerSpec("swa"), LayerSpec("swa"), LayerSpec("swa"),
                   LayerSpec("swa"), LayerSpec("swa"), LayerSpec("full")),
    window=1024,
    mlp_type="geglu", rope_theta=1000000.0,
)
