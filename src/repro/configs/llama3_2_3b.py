"""llama3.2-3b — dense decoder [hf:meta-llama/Llama-3.2-3B; unverified]."""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="llama3.2-3b", family="dense",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=128256,
    layer_pattern=(LayerSpec("full"),),
    mlp_type="swiglu", rope_theta=500000.0,
)
