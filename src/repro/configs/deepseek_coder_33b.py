"""deepseek-coder-33b — llama-arch dense decoder [arXiv:2401.14196; hf]."""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=19200, vocab_size=32256,
    layer_pattern=(LayerSpec("full"),),
    mlp_type="swiglu", rope_theta=100000.0,
)
