"""The paper's own experimental configuration (Section 4 / Table 1)."""
from repro.core.kernel_fn import linear
from repro.core.ocssvm import SlabSpec

# Table 1 protocol: linear kernel, nu1=0.5, nu2=0.01, eps=2/3.
PAPER_SPEC = SlabSpec(nu1=0.5, nu2=0.01, eps=2.0 / 3.0, kernel=linear())
# Fig. 2 variant: nu1=0.2, nu2=0.08, eps=1/2.
FIG2_SPEC = SlabSpec(nu1=0.2, nu2=0.08, eps=0.5, kernel=linear())
TABLE1_SIZES = (500, 1000, 2000, 5000)
