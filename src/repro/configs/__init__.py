"""Architecture registry: --arch <id> -> ArchConfig."""
from repro.configs.base import (ArchConfig, LayerSpec, SHAPES,
                                long_context_capable)
from repro.configs.llama3_2_3b import CONFIG as _llama
from repro.configs.minitron_8b import CONFIG as _minitron
from repro.configs.gemma3_27b import CONFIG as _gemma
from repro.configs.deepseek_coder_33b import CONFIG as _deepseek
from repro.configs.musicgen_large import CONFIG as _musicgen
from repro.configs.arctic_480b import CONFIG as _arctic
from repro.configs.mixtral_8x22b import CONFIG as _mixtral
from repro.configs.jamba_1_5_large import CONFIG as _jamba
from repro.configs.rwkv6_7b import CONFIG as _rwkv
from repro.configs.internvl2_26b import CONFIG as _internvl

ARCHS = {c.name: c for c in (
    _llama, _minitron, _gemma, _deepseek, _musicgen,
    _arctic, _mixtral, _jamba, _rwkv, _internvl)}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]

__all__ = ["ArchConfig", "LayerSpec", "SHAPES", "ARCHS", "get_arch",
           "long_context_capable"]
