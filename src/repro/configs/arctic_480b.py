"""arctic-480b — 128-expert top-2 MoE + dense residual MLP
[hf:Snowflake/snowflake-arctic-base; hf].

Every layer: GQA attention + (dense residual MLP || 128e top-2 MoE), both
with ff=4864. Adafactor optimizer (AdamW fp32 moments do not fit v5e HBM
at 480B even fully sharded — see DESIGN.md).
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=4864, vocab_size=32000,
    layer_pattern=(LayerSpec("full", moe=True),),
    n_experts=128, top_k=2, expert_ff=4864, dense_residual_ff=4864,
    mlp_type="swiglu", rope_theta=500000.0,
    optimizer="adafactor",
)
