"""minitron-8b — pruned nemotron dense decoder [arXiv:2407.14679; hf].

Nemotron lineage uses squared-ReLU non-gated MLPs.
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=256000,
    layer_pattern=(LayerSpec("full"),),
    mlp_type="relu2", rope_theta=500000.0,
)
