"""musicgen-large — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

Transformer BACKBONE only: the EnCodec frontend is a stub — input_specs()
supplies precomputed frame embeddings (B, S, d_model) in place of the token
embedding; the head predicts the 2048-entry codebook. MHA (kv == q heads).
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=2048,
    layer_pattern=(LayerSpec("full"),),
    mlp_type="gelu", rope_theta=10000.0,
    frontend="audio",
)
