"""Serving steps: prefill (fill KV/SSM caches) and decode (one token).

Decode donates the cache so XLA updates buffers in place — at 500k-token
contexts the cache IS the memory footprint and a copy would double it.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.transformer import forward, init_cache

Array = jax.Array


def make_prefill(cfg: ArchConfig, *, constrain=lambda x, k: x,
                 q_chunk: int = 2048) -> Callable:
    def prefill(params, cache, batch: dict):
        kwargs = {}
        if "embeds" in batch:
            kwargs["embeds"] = batch["embeds"]
        else:
            kwargs["tokens"] = batch["tokens"]
        if "vision_embeds" in batch:
            kwargs["vision_embeds"] = batch["vision_embeds"]
        logits, cache, _ = forward(params, cfg, cache=cache,
                                   constrain=constrain, q_chunk=q_chunk,
                                   **kwargs)
        return logits[:, -1:], cache

    return prefill


def make_decode(cfg: ArchConfig, *, constrain=lambda x, k: x) -> Callable:
    def decode(params, cache, token: Array):
        logits, cache, _ = forward(params, cfg, tokens=token, cache=cache,
                                   constrain=constrain)
        return logits, cache

    return decode


def greedy_generate(cfg: ArchConfig, params, prompt: Array, n_new: int,
                    cache_len: Optional[int] = None) -> Array:
    """Reference autoregressive loop (examples / tests)."""
    b, s = prompt.shape
    cache_len = cache_len or (s + n_new)
    cache = init_cache(cfg, b, cache_len, dtype=cfg.dtype)
    prefill = make_prefill(cfg)
    decode = make_decode(cfg)
    logits, cache = prefill(params, cache, {"tokens": prompt})
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]

    def body(carry, _):
        cache, tok = carry
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        return (cache, tok), tok

    (_, _), toks = jax.lax.scan(body, (cache, tok), None, length=n_new - 1)
    rest = toks[:, :, 0].T  # (n_new-1, b, 1) -> (b, n_new-1)
    return jnp.concatenate([tok, rest], axis=1)
