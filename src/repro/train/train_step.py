"""Training step: loss, (accumulated) grads, clipping, optimizer update.

Built as a closure over the static ArchConfig so the whole step jits to one
XLA program. Microbatching (gradient accumulation) runs as a lax.scan over
microbatch slices — activations for only one microbatch are ever live.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.transformer import forward
from repro.optim import adafactor, adamw
from repro.optim.schedules import warmup_cosine

Array = jax.Array

AUX_LOSS_WEIGHT = 0.01
IGNORE_LABEL = -1


def cross_entropy(logits: Array, labels: Array) -> Array:
    """Mean next-token CE over labels != IGNORE_LABEL (fp32 math)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    safe = jnp.maximum(labels, 0)
    ll = jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    mask = (labels != IGNORE_LABEL).astype(jnp.float32)
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def fused_unembed_ce(hidden: Array, unembed: Array, labels: Array, *,
                     vocab_size: int, chunk: int = 16384) -> Array:
    """CE fused into the unembedding matmul, scanned over vocab chunks.

    The full (tokens, V) logits tensor never exists — each chunk computes
    hidden @ W[:, v:v+chunk], folds it into an online logsumexp (carry =
    running max + scaled sum + label logit), and is discarded. Backward
    recomputes each chunk's logits (one extra unembed-matmul of FLOPs) —
    the standard memory/compute trade for 256k-vocab models on an
    unsharded-vocab (pure-FSDP) layout.
    """
    b, s, d = hidden.shape
    V = unembed.shape[-1]
    # chunk count must divide V exactly (no padded copies of the matrix)
    nc = max(1, (V + chunk - 1) // chunk)
    while V % nc:
        nc += 1
    chunk = V // nc
    w_chunks = unembed.reshape(d, nc, chunk).transpose(1, 0, 2)

    safe = jnp.maximum(labels, 0)
    mask = (labels != IGNORE_LABEL).astype(jnp.float32)

    def body(carry, xs):
        m, ssum, lab = carry
        ci, w = xs
        lg = (hidden @ w).astype(jnp.float32)            # (B, S, chunk)
        col0 = ci * chunk
        cols = col0 + jnp.arange(chunk)
        lg = jnp.where(cols[None, None, :] < vocab_size, lg, -1e30)
        m_new = jnp.maximum(m, lg.max(axis=-1))
        ssum = ssum * jnp.exp(m - m_new) + jnp.exp(
            lg - m_new[..., None]).sum(-1)
        # label logit if the label falls inside this chunk
        in_chunk = (safe >= col0) & (safe < col0 + chunk)
        idx = jnp.clip(safe - col0, 0, chunk - 1)
        lab_here = jnp.take_along_axis(lg, idx[..., None], axis=-1)[..., 0]
        lab = jnp.where(in_chunk, lab_here, lab)
        return (m_new, ssum, lab), None

    init = (jnp.full((b, s), -jnp.inf, jnp.float32),
            jnp.zeros((b, s), jnp.float32),
            jnp.zeros((b, s), jnp.float32))
    (m, ssum, lab), _ = jax.lax.scan(body, init,
                                     (jnp.arange(nc), w_chunks))
    lse = m + jnp.log(ssum)
    ll = lab - lse
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


FUSED_CE_MIN_VOCAB = 65536


def loss_fn(params, cfg: ArchConfig, batch: dict, constrain) -> Tuple[Array, dict]:
    kwargs = {}
    if "embeds" in batch:
        kwargs["embeds"] = batch["embeds"]
    else:
        kwargs["tokens"] = batch["tokens"]
    if "vision_embeds" in batch:
        kwargs["vision_embeds"] = batch["vision_embeds"]
    labels = batch["labels"]
    fused = cfg.padded_vocab >= FUSED_CE_MIN_VOCAB
    if fused:
        hidden, _, aux = forward(params, cfg, constrain=constrain,
                                 return_hidden=True, **kwargs)
        if "vision_embeds" in batch:
            hidden = hidden[:, batch["vision_embeds"].shape[1]:]
        ce = fused_unembed_ce(hidden, params["unembed"], labels,
                              vocab_size=cfg.vocab_size)
    else:
        logits, _, aux = forward(params, cfg, constrain=constrain, **kwargs)
        if "vision_embeds" in batch:
            logits = logits[:, batch["vision_embeds"].shape[1]:]
        ce = cross_entropy(logits, labels)
    loss = ce + AUX_LOSS_WEIGHT * aux
    return loss, {"ce": ce, "aux": aux}


def global_norm(tree) -> Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda x: x * scale, tree), norm


class TrainState(NamedTuple):
    params: dict
    opt_state: tuple
    step: Array


def init_train_state(cfg: ArchConfig, params) -> TrainState:
    opt = adafactor if cfg.optimizer == "adafactor" else adamw
    return TrainState(params=params, opt_state=opt.init(params),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(cfg: ArchConfig, *, constrain=lambda x, k: x,
                    peak_lr: float = 3e-4, warmup_steps: int = 100,
                    total_steps: int = 10_000, grad_clip: float = 1.0,
                    microbatches: int = 1,
                    accum_dtype=None) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    accum_dtype: gradient-accumulation dtype across microbatches. Defaults
    to fp32 below 100B params; bf16 above — at arctic/jamba scale two
    params-shaped fp32 buffers alone exceed a v5e's HBM (477e9 x 4 B / 256
    chips = 7.5 GB each; the while-loop carry double-buffers it).
    """
    opt = adafactor if cfg.optimizer == "adafactor" else adamw
    if accum_dtype is None:
        accum_dtype = (jnp.bfloat16 if cfg.param_count() >= 1e11
                       else jnp.float32)

    def grads_of(params, batch):
        (loss, extras), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, cfg, batch, constrain)
        return loss, extras, grads

    def train_step(state: TrainState, batch: dict):
        if microbatches > 1:
            def slice_mb(x):
                b = x.shape[0] // microbatches
                return x.reshape(microbatches, b, *x.shape[1:])

            mbatch = jax.tree.map(slice_mb, batch)

            def acc_body(carry, mb):
                loss_a, grads_a = carry
                loss, _extras, grads = grads_of(state.params, mb)
                return (loss_a + loss,
                        jax.tree.map(lambda a, g: a + g.astype(a.dtype),
                                     grads_a, grads)), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), state.params)
            (loss_sum, grads), _ = jax.lax.scan(
                acc_body, (jnp.zeros(()), zero), mbatch)
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            extras = {"ce": loss, "aux": jnp.zeros(())}
        else:
            loss, extras, grads = grads_of(state.params, batch)

        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        lr = warmup_cosine(state.step, peak_lr=peak_lr,
                           warmup_steps=warmup_steps,
                           total_steps=total_steps)
        new_params, new_opt = opt.update(grads, state.opt_state,
                                         state.params, lr=lr)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr, **extras}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step
