"""The paper's toy dataset (Section 4 / Figs 1-2), reconstructed.

Figs 1-2 show 2-D points with the learned slab (two parallel lines). We
generate a target class concentrated in a band around a line plus a fraction
of background anomalies, with ground-truth labels for MCC evaluation
(+1 = target / inside-slab, -1 = anomaly).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def make_toy(key: Array, m: int, anomaly_frac: float = 0.15,
             d: int = 2, band_width: float = 0.35,
             direction=None) -> Tuple[Array, Array]:
    """Returns (X, y) with y in {-1, +1}; target points live in a slab band."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    n_anom = max(1, int(m * anomaly_frac))
    n_tgt = m - n_anom

    w = (jnp.ones((d,)) if direction is None else jnp.asarray(direction))
    w = w / jnp.linalg.norm(w)

    # Target: spread along the band direction, tight across it.
    along = jax.random.normal(k1, (n_tgt, 1)) * 2.0 + 3.0
    across = jax.random.normal(k2, (n_tgt, d)) * band_width
    across = across - (across @ w)[:, None] * w[None, :]
    X_tgt = along * w[None, :] + across

    # Anomalies: uniform box covering the scene.
    X_anom = jax.random.uniform(k3, (n_anom, d), minval=-4.0, maxval=10.0)

    X = jnp.concatenate([X_tgt, X_anom], axis=0)
    y = jnp.concatenate([jnp.ones((n_tgt,)), -jnp.ones((n_anom,))])
    perm = jax.random.permutation(k4, m)
    return X[perm], y[perm]
