"""Synthetic LM data pipeline with a checkpointable cursor.

Deterministic: batch(i) is a pure function of (seed, i), so a restored run
resumes the exact stream — the property fault-tolerant training needs.
Batches are placed with the mesh's batch sharding when one is provided.

The token stream is Zipf-ish (realistic embedding-gather skew) and labels
are next-token shifted with a final IGNORE at the boundary.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig

IGNORE_LABEL = -1


@dataclasses.dataclass
class DataCursor:
    seed: int
    step: int = 0


class SyntheticPipeline:
    def __init__(self, cfg: ArchConfig, batch: int, seq_len: int, *,
                 seed: int = 0, sharding=None):
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.cursor = DataCursor(seed=seed)
        self.sharding = sharding

    def _tokens(self, step: int) -> np.ndarray:
        rng = np.random.default_rng((self.cursor.seed, step))
        # Zipf-like skew clipped to the vocab.
        z = rng.zipf(1.3, size=(self.batch, self.seq_len + 1))
        return (z % self.cfg.vocab_size).astype(np.int32)

    def next_batch(self) -> dict:
        cfg = self.cfg
        toks = self._tokens(self.cursor.step)
        self.cursor.step += 1
        tokens = toks[:, :-1]
        labels = toks[:, 1:].copy()
        batch = {}
        if cfg.frontend == "audio":
            rng = np.random.default_rng((self.cursor.seed, self.cursor.step,
                                         7))
            batch["embeds"] = rng.standard_normal(
                (self.batch, self.seq_len, cfg.d_model)).astype(np.float32)
            batch["labels"] = labels
        elif cfg.frontend == "vision":
            nv = cfg.n_frontend_tokens
            rng = np.random.default_rng((self.cursor.seed, self.cursor.step,
                                         11))
            batch["tokens"] = tokens[:, :self.seq_len - nv]
            batch["labels"] = labels[:, :self.seq_len - nv]
            batch["vision_embeds"] = rng.standard_normal(
                (self.batch, nv, cfg.d_model)).astype(np.float32)
        else:
            batch["tokens"] = tokens
            batch["labels"] = labels
        out = {k: jnp.asarray(v) for k, v in batch.items()}
        if self.sharding is not None:
            out = {k: jax.device_put(v, self.sharding[k])
                   for k, v in out.items() if k in self.sharding} | {
                k: v for k, v in out.items() if k not in self.sharding}
        return out

    # --- checkpointable cursor ------------------------------------------
    def state_dict(self) -> dict:
        return dataclasses.asdict(self.cursor)

    def load_state_dict(self, d: dict) -> None:
        self.cursor = DataCursor(**d)
