from repro.data.toy_ocssvm import make_toy

__all__ = ["make_toy"]
