"""Fault-tolerant training loop: periodic async checkpoints, crash restart,
heartbeat-based straggler detection, failure injection for tests.

The loop is deliberately framework-shaped: a ``StepFn`` (anything from the
LM train step to the SMO solver's outer iteration) runs under supervision;
failures raise, the supervisor restores the latest checkpoint (params, opt
state, data cursor, RNG) and replays. At 1000+ nodes the same structure
holds — the checkpoint store becomes a distributed FS and the heartbeat
table a side-channel service; both are injected here as interfaces.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import AsyncCheckpointer, restore_latest


@dataclasses.dataclass
class HeartbeatTable:
    """Simulated per-node heartbeats with straggler / failure detection."""
    n_nodes: int
    timeout_s: float = 30.0
    straggler_factor: float = 2.0
    last_beat: Dict[int, float] = dataclasses.field(default_factory=dict)
    step_times: Dict[int, List[float]] = dataclasses.field(
        default_factory=dict)

    def beat(self, node: int, step_time: Optional[float] = None,
             now: Optional[float] = None):
        now = time.monotonic() if now is None else now
        self.last_beat[node] = now
        if step_time is not None:
            self.step_times.setdefault(node, []).append(step_time)

    def dead_nodes(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return [n for n in range(self.n_nodes)
                if now - self.last_beat.get(n, now) > self.timeout_s]

    def stragglers(self) -> List[int]:
        medians = {n: float(np.median(t)) for n, t in self.step_times.items()
                   if t}
        if not medians:
            return []
        global_median = float(np.median(list(medians.values())))
        return [n for n, m in medians.items()
                if m > self.straggler_factor * global_median]


class FaultTolerantLoop:
    """Supervised step loop with checkpoint/restart.

    step_fn(state, batch) -> (state, metrics);
    pipeline must expose next_batch()/state_dict()/load_state_dict().
    """

    def __init__(self, step_fn: Callable, init_state: Any, pipeline,
                 ckpt_dir: str, *, save_every: int = 50,
                 max_restarts: int = 5, keep: int = 3,
                 failure_injector: Optional[Callable[[int], None]] = None):
        self.step_fn = step_fn
        self.state = init_state
        self._init_state = jax.tree.map(np.asarray, init_state)
        self.pipeline = pipeline
        self._init_pipeline_state = dict(pipeline.state_dict())
        self.ckpt = AsyncCheckpointer(ckpt_dir, keep=keep)
        self.ckpt_dir = ckpt_dir
        self.save_every = save_every
        self.max_restarts = max_restarts
        self.failure_injector = failure_injector
        self.restarts = 0
        self.metrics_log: List[dict] = []

    def _save(self, step: int):
        self.ckpt.save(step, self.state,
                       extra={"data": self.pipeline.state_dict()})

    def _restore(self) -> int:
        # Make sure any in-flight write has landed before picking "latest".
        self.ckpt.wait()
        restored, step = restore_latest(self.ckpt_dir, self.state)
        if restored is None:
            # No checkpoint yet: restart from the TRUE initial state (the
            # live state has already been mutated by the failed attempt).
            self.state = jax.tree.map(jnp.asarray, self._init_state)
            self.pipeline.load_state_dict(dict(self._init_pipeline_state))
            return 0
        self.state = restored
        import json, os
        with open(os.path.join(self.ckpt_dir, f"step_{step:09d}",
                               "manifest.json")) as f:
            extra = json.load(f)["extra"]
        if "data" in extra:
            self.pipeline.load_state_dict(extra["data"])
        return step + 1

    def run(self, n_steps: int) -> Any:
        step = self._restore()
        while step < n_steps:
            try:
                if self.failure_injector is not None:
                    self.failure_injector(step)
                t0 = time.monotonic()
                batch = self.pipeline.next_batch()
                self.state, metrics = self.step_fn(self.state, batch)
                jax.block_until_ready(jax.tree.leaves(self.state)[0])
                metrics = {k: float(v) for k, v in metrics.items()}
                metrics["step"] = step
                metrics["step_time_s"] = time.monotonic() - t0
                self.metrics_log.append(metrics)
                if (step + 1) % self.save_every == 0:
                    self._save(step)
                step += 1
            except Exception:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                step = self._restore()
        self.ckpt.wait()
        return self.state
