"""Version-compatibility shims for jax APIs that moved between releases.

``jax.shard_map`` (with its ``check_vma`` kwarg) only exists in newer jax;
on 0.4.x the same functionality lives at
``jax.experimental.shard_map.shard_map`` with the kwarg spelled
``check_rep``. Call sites import ``shard_map`` from here and always use
the new-style ``check_vma`` spelling.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check_vma})
