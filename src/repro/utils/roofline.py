"""Three-term roofline model for TPU v5e + analytic FLOP/byte inventory.

Terms (seconds), per the brief:
    compute    = FLOPs / (chips * 197e12)          [bf16 MXU peak]
    memory     = HBM bytes / (chips * 819e9)
    collective = link bytes / (chips * 50e9)       [per-link ICI, ring model]

Two FLOP sources are reported side by side:
  * hlo:      trip-count-scaled dot FLOPs parsed from the compiled module
              (utils/hlo_analysis.py),
  * analytic: MODEL_FLOPS = 6*N_active*T (train) / 2*N_active*T (decode)
              plus exact attention terms — the "useful work" yardstick.
Their ratio exposes remat recompute and dispatch overheads.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.configs.base import ArchConfig, SHAPES

V5E = dict(peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9)


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    hbm_bytes: float
    coll_bytes: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        # overlap model: perfectly overlapped => max; report max as the bound
        return max(self.compute_s, self.memory_s, self.collective_s)

    def to_dict(self) -> Dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes, "chips": self.chips,
        }


def terms(flops: float, hbm_bytes: float, coll_bytes: float,
          chips: int) -> RooflineTerms:
    return RooflineTerms(
        compute_s=flops / (chips * V5E["peak_flops"]),
        memory_s=hbm_bytes / (chips * V5E["hbm_bw"]),
        collective_s=coll_bytes / (chips * V5E["ici_bw"]),
        flops=flops, hbm_bytes=hbm_bytes, coll_bytes=coll_bytes, chips=chips)


# ---------------------------------------------------------------------------
# analytic inventory
# ---------------------------------------------------------------------------

def _attn_context(cfg: ArchConfig, mixer: str, seq_len: int,
                  decode_pos: int = 0, decode: bool = False) -> float:
    """Average visible context length per query position."""
    if decode:
        ctx = decode_pos
        if mixer == "swa" and cfg.window:
            ctx = min(ctx, cfg.window)
        return float(ctx)
    if mixer == "swa" and cfg.window and seq_len > cfg.window:
        # ramp up to the window, then constant
        w = cfg.window
        return (w * (w + 1) / 2 + (seq_len - w) * w) / seq_len
    return (seq_len + 1) / 2.0


def model_flops(cfg: ArchConfig, shape_name: str) -> float:
    """Analytic step FLOPs: 6*N_active*T train / 2*N_active*T decode,
    plus exact attention score/value FLOPs (the 6N rule misses them)."""
    sh = SHAPES[shape_name]
    S, B, step = sh["seq_len"], sh["batch"], sh["step"]
    n_active = cfg.active_param_count()
    decode = step == "decode"
    tokens = B * (1 if decode else S)
    mult = 2 if decode else (2 if step == "prefill" else 6)
    total = float(mult) * n_active * tokens

    # attention score+value FLOPs: 4 * ctx * (Hq * Dh) per token per layer
    bwd = 2 if step == "train" else 0   # bwd recomputes ~2x attn matmuls
    for i in range(cfg.n_layers):
        mixer = cfg.layer_spec(i).mixer
        if mixer not in ("full", "swa"):
            continue
        ctx = _attn_context(cfg, mixer, S, decode_pos=S, decode=decode)
        per_tok = 4.0 * ctx * cfg.n_heads * cfg.head_dim
        total += per_tok * tokens * (1 + bwd)
    return total


def model_hbm_bytes(cfg: ArchConfig, shape_name: str, chips: int,
                    *, fsdp: bool = True) -> float:
    """Analytic HBM traffic per step (global, all chips summed).

    Train: params read fwd+bwd + grads written + optimizer state r/w;
    activations written once per layer block and re-read in bwd (full
    remat => recomputed, still one write+read at block granularity).
    Decode: params read once + full KV/state cache read + small writes.
    """
    sh = SHAPES[shape_name]
    S, B, step = sh["seq_len"], sh["batch"], sh["step"]
    p_bytes = cfg.active_param_count() * 2.0         # bf16
    d = cfg.d_model

    if step == "train":
        tokens = B * S
        act_block = tokens * d * 2.0                  # bf16 per layer block
        acts = act_block * cfg.n_layers * 2.0 * 2.0   # w+r, fwd+bwd(remat)
        opt = cfg.param_count() * (12.0 if cfg.optimizer == "adamw" else 1.0)
        return 3.0 * cfg.param_count() * 2.0 + opt + acts
    if step == "prefill":
        tokens = B * S
        acts = tokens * d * 2.0 * cfg.n_layers * 2.0
        cache = _cache_bytes(cfg, B, S)
        return p_bytes + acts + cache
    # decode
    cache = _cache_bytes(cfg, B, S)
    return p_bytes + cache + B * d * 2.0 * cfg.n_layers * 4.0


def _cache_bytes(cfg: ArchConfig, B: int, S: int) -> float:
    total = 0.0
    for i in range(cfg.n_layers):
        mixer = cfg.layer_spec(i).mixer
        if mixer == "full":
            total += 2.0 * B * S * cfg.n_kv_heads * cfg.head_dim * 2.0
        elif mixer == "swa":
            w = min(cfg.window or S, S)
            total += 2.0 * B * w * cfg.n_kv_heads * cfg.head_dim * 2.0
        elif mixer == "mamba":
            total += B * cfg.ssm_inner * cfg.ssm_state * 4.0
            total += B * (cfg.ssm_conv - 1) * cfg.ssm_inner * 2.0
        elif mixer == "rwkv":
            total += B * cfg.rwkv_heads * cfg.rwkv_head_dim ** 2 * 4.0
    return total


def mfu_fraction(t: RooflineTerms, useful_flops: float) -> float:
    """Fraction of roofline: useful FLOPs / (chips * peak * bound time)."""
    bound = t.step_time_s
    if bound <= 0:
        return 0.0
    return useful_flops / (t.chips * V5E["peak_flops"] * bound)
