"""Mini HLO cost analyzer over compiled-module text.

Why: XLA's built-in ``compiled.cost_analysis()`` counts while-loop bodies
ONCE (verified: a 10-iteration scan reports exactly 1/10 the FLOPs), so for
scan-over-layers models its numbers are off by the layer count. This parser
walks the compiled HLO text, builds per-computation costs, and scales loop
bodies by their ``known_trip_count`` backend config — giving trip-aware:

  * dot FLOPs (2 * prod(result dims) * prod(contracting dims)),
  * HBM traffic estimate (operands read + result written per top-level
    instruction; fusion interiors excluded — the fusion call site is the
    HBM boundary),
  * per-kind collective link bytes (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute, -start variants too),
    using operand sizes as the brief specifies.

It is an estimator, not an exact replay of the TPU compiler — CPU fusion
boundaries differ from TPU's — but it is applied uniformly across every
(arch x shape x mesh) cell, so roofline comparisons and perf-iteration
deltas are meaningful. FLOPs are additionally cross-checked against the
analytic inventory in utils/roofline.py.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INST_HEAD_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*")
_COMP_NAME_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_PARAM_RE = re.compile(r"%?([\w\.\-]+):\s*")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_COND_BODY_RE = re.compile(
    r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "Cost", scale: float = 1.0):
        self.flops += other.flops * scale
        self.bytes += other.bytes * scale
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * scale

    @property
    def collective_bytes(self) -> float:
        return sum(self.coll.values())


@dataclass
class _Inst:
    name: str
    type_str: str
    op: str
    line: str


@dataclass
class _Comp:
    name: str
    params: Dict[str, str]
    insts: List[_Inst]


def _balanced(s: str, start: int) -> int:
    """Index one past the paren group opening at s[start] ('(')."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(s)


def _split_top_commas(s: str) -> List[str]:
    parts, depth, cur = [], 0, []
    for ch in s:
        if ch in "([":
            depth += 1
        elif ch in ")]":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return parts


def _parse_header(line: str) -> Optional[Tuple[str, Dict[str, str]]]:
    """Parse '%name (p0: T0, p1: (T1a, T1b)) -> T {' headers (tuple-safe)."""
    stripped = line.strip()
    m = _COMP_NAME_RE.match(stripped)
    if not m or not stripped.endswith("{"):
        return None
    popen = stripped.index("(", m.start(1))
    pclose = _balanced(stripped, popen)
    if "->" not in stripped[pclose:]:
        return None
    params: Dict[str, str] = {}
    for part in _split_top_commas(stripped[popen + 1:pclose - 1]):
        if ":" not in part:
            continue
        name, type_str = part.split(":", 1)
        params[name.strip().lstrip("%")] = type_str.strip()
    return m.group(1), params


def _parse_computations(text: str) -> Tuple[Dict[str, _Comp], Optional[str]]:
    comps: Dict[str, _Comp] = {}
    entry = None
    cur: Optional[_Comp] = None
    for line in text.splitlines():
        if cur is None or line.rstrip().endswith("{"):
            hdr = _parse_header(line)
            if hdr is not None:
                cur = _Comp(name=hdr[0], params=hdr[1], insts=[])
                comps[cur.name] = cur
                if line.strip().startswith("ENTRY"):
                    entry = cur.name
                continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        inst = _parse_inst(line)
        if inst is not None:
            cur.insts.append(inst)
    return comps, entry


def _parse_inst(line: str) -> Optional[_Inst]:
    """Parse '%name = TYPE op(...)' where TYPE may be a tuple."""
    m = _INST_HEAD_RE.match(line)
    if not m:
        return None
    rest_start = m.end()
    rest = line[rest_start:]
    if rest.startswith("("):                      # tuple-typed result
        close = _balanced(line, rest_start)
        type_str = line[rest_start:close]
        tail = line[close:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str = rest[:sp]
        tail = rest[sp:]
    om = re.match(r"\s+([\w\-]+)\(", tail)
    if not om:
        return None
    return _Inst(name=m.group(1), type_str=type_str, op=om.group(1),
                 line=line)


_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "iota", "partition-id", "replica-id",
}


def _dot_flops(inst: _Inst, symtab: Dict[str, str]) -> float:
    result_dims = shape_dims(inst.type_str)
    ops = _OPERANDS_RE.findall(inst.line.split("(", 1)[1])
    lhs_shape = symtab.get(ops[0], "") if ops else ""
    cm = _CONTRACT_RE.search(inst.line)
    contract = 1
    if cm and lhs_shape:
        ldims = shape_dims(lhs_shape)
        for ci in cm.group(1).split(","):
            if ci and int(ci) < len(ldims):
                contract *= ldims[int(ci)]
    out = 1
    for d in result_dims:
        out *= d
    return 2.0 * out * contract


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return default


def _collective_operand_bytes(inst: _Inst, n_devices: int) -> Tuple[str, float]:
    base = None
    for kind in COLLECTIVES:
        if inst.op.startswith(kind):
            base = kind
            break
    assert base is not None
    result_bytes = shape_bytes(inst.type_str)
    g = _group_size(inst.line, n_devices)
    if base == "all-gather":
        return base, result_bytes / max(g, 1)   # operand = one shard
    if base == "reduce-scatter":
        return base, result_bytes * max(g, 1)   # operand = unscattered
    return base, float(result_bytes)            # ar / a2a / permute


def analyze(text: str, *, n_devices: int = 1) -> Cost:
    comps, entry = _parse_computations(text)
    if entry is None:
        # fall back: treat the largest computation as entry
        entry = max(comps, key=lambda c: len(comps[c].insts)) if comps else None
        if entry is None:
            return Cost()
    memo: Dict[str, Cost] = {}

    def comp_cost(name: str) -> Cost:
        if name in memo:
            return memo[name]
        memo[name] = Cost()  # break cycles defensively
        comp = comps.get(name)
        if comp is None:
            return memo[name]
        symtab: Dict[str, str] = dict(comp.params)
        total = Cost()
        for inst in comp.insts:
            symtab[inst.name] = inst.type_str
            op = inst.op
            if op == "while":
                cb = _COND_BODY_RE.search(inst.line)
                tm = _TRIP_RE.search(inst.line)
                trips = int(tm.group(1)) if tm else 1
                if cb:
                    total.add(comp_cost(cb.group(2)), scale=trips)
                    total.add(comp_cost(cb.group(1)), scale=trips)
                continue
            if op in ("fusion", "call", "conditional", "async-start",
                      "custom-call", "map", "reduce", "reduce-window",
                      "scatter", "select-and-scatter", "sort"):
                cm = _CALLS_RE.search(inst.line)
                if cm:
                    sub = comp_cost(cm.group(1))
                    total.flops += sub.flops
                    for k, v in sub.coll.items():
                        total.coll[k] = total.coll.get(k, 0.0) + v
                # bytes at the call-site boundary:
                if op != "async-start":
                    ops_ = _OPERANDS_RE.findall(inst.line.split("(", 1)[1])
                    rd = sum(shape_bytes(symtab.get(o, "")) for o in ops_)
                    total.bytes += shape_bytes(inst.type_str) + rd
                continue
            if any(op.startswith(k) for k in COLLECTIVES):
                if op.endswith("-done"):
                    continue
                kind, b = _collective_operand_bytes(inst, n_devices)
                total.coll[kind] = total.coll.get(kind, 0.0) + b
                total.bytes += shape_bytes(inst.type_str)
                continue
            if op == "dot":
                total.flops += _dot_flops(inst, symtab)
            if op == "convolution":
                # rough: 2 * output elems * kernel elems
                ops_ = _OPERANDS_RE.findall(inst.line.split("(", 1)[1])
                if len(ops_) >= 2:
                    kdims = shape_dims(symtab.get(ops_[1], ""))
                    kn = 1
                    for d in kdims:
                        kn *= d
                    on = 1
                    for d in shape_dims(inst.type_str):
                        on *= d
                    total.flops += 2.0 * on * kn
            if op not in _SKIP_BYTES_OPS:
                ops_ = _OPERANDS_RE.findall(inst.line.split("(", 1)[1])
                rd = sum(shape_bytes(symtab.get(o, "")) for o in ops_)
                total.bytes += shape_bytes(inst.type_str) + rd
        memo[name] = total
        return total

    return comp_cost(entry)


def collective_summary(text: str, *, n_devices: int = 1) -> Dict[str, float]:
    cost = analyze(text, n_devices=n_devices)
    out = dict(cost.coll)
    out["total"] = cost.collective_bytes
    return out
