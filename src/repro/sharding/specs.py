"""Logical-axis sharding rules -> PartitionSpec, with divisibility fallback.

Axis mapping (production mesh: ("pod",) "data", "model"):

  vocab / heads / ff / experts / inner  -> "model"   (tensor/expert parallel)
  dmodel                                -> "data" when FSDP is on (ZeRO-3
                                           weight sharding; all-gather at use)
  batch                                 -> ("pod", "data")

Any logical axis whose size does not divide its mesh axis falls back to
replicated (e.g. internvl2's 92553 vocab on a 16-way model axis) — the rule
engine checks divisibility per leaf, so odd published shapes never break
lowering. Stacked scan-over-period params (leading R axis) get a leading
None automatically.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Rules keyed by (parent, leaf) or leaf name: logical axes for the LAST
# len(rule) dims of the param. None = replicated dim.
_RULES = {
    ("", "embed"): ("vocab", "dmodel"),
    ("", "unembed"): ("dmodel", "vocab"),
    ("attn", "wq"): ("dmodel", "heads"),
    ("attn", "wk"): ("dmodel", "heads"),
    ("attn", "wv"): ("dmodel", "heads"),
    ("attn", "wo"): ("heads", "dmodel"),
    ("mlp", "w1"): ("dmodel", "ff"),
    ("mlp", "w3"): ("dmodel", "ff"),
    ("mlp", "w2"): ("ff", "dmodel"),
    ("dense_res", "w1"): ("dmodel", "ff"),
    ("dense_res", "w3"): ("dmodel", "ff"),
    ("dense_res", "w2"): ("ff", "dmodel"),
    ("moe", "router"): (None, None),
    ("moe", "w1"): ("experts", "dmodel", "ff"),
    ("moe", "w3"): ("experts", "dmodel", "ff"),
    ("moe", "w2"): ("experts", "ff", "dmodel"),
    ("mamba", "in_proj"): ("dmodel", "inner"),
    ("mamba", "conv_w"): (None, "inner"),
    ("mamba", "x_proj"): ("inner", None),
    ("mamba", "dt_proj"): (None, "inner"),
    ("mamba", "A_log"): ("inner", None),
    ("mamba", "D"): ("inner",),
    ("mamba", "out_proj"): ("inner", "dmodel"),
    ("rwkv", "wr"): ("dmodel", "heads"),
    ("rwkv", "wk"): ("dmodel", "heads"),
    ("rwkv", "wv"): ("dmodel", "heads"),
    ("rwkv", "wg"): ("dmodel", "heads"),
    ("rwkv", "wo"): ("heads", "dmodel"),
    ("cmix", "wk"): ("dmodel", "ff"),
    ("cmix", "wv"): ("ff", "dmodel"),
}


def _logical_to_mesh(logical: Optional[str], fsdp: bool,
                     layout: str = "tp") -> Optional[str]:
    if logical is None:
        return None
    if layout == "fsdp":
        # Pure data-parallel layout: no tensor parallelism; weights are
        # ZeRO-3 sharded over the "model" axis (gathered at use) and the
        # batch spans BOTH mesh axes. The right choice for models whose
        # optimizer state fits a 16-way shard (<= ~30B dense) — trades the
        # per-layer activation all-reduces (which scale with per-device
        # tokens) for weight all-gathers (which scale with params/pass).
        return "model" if logical == "dmodel" else None
    if logical == "dmodel":
        return "data" if fsdp else None
    return "model"


def _moe_experts_divisible(shape, mesh: Mesh) -> bool:
    return shape[-3] % mesh.shape["model"] == 0


def spec_for_param(path, shape, mesh: Mesh, *, fsdp: bool,
                   layout: str = "tp", moe_layout: str = "psum") -> P:
    """PartitionSpec for one param leaf given its tree path."""
    names = [k.key for k in path if isinstance(k, jax.tree_util.DictKey)]
    leaf = names[-1] if names else ""
    parent = ""
    for n in reversed(names[:-1]):
        if n in ("attn", "mlp", "moe", "mamba", "rwkv", "cmix", "dense_res"):
            parent = n
            break
    rule = _RULES.get((parent, leaf)) or _RULES.get(("", leaf))
    if rule is None:
        return P()  # norms, scalars, biases: replicated

    rule = list(rule)
    # MoE: experts over "model" when divisible (EP; ff replicated within a
    # shard), else expert-TP on the ff dim. moe_layout="a2a": experts over
    # the data axes + ff-TP over "model" (weights fully sharded, no ZeRO
    # gathers — tokens move instead; see models/moe._moe_forward_a2a).
    if parent == "moe" and leaf in ("w1", "w2", "w3"):
        if moe_layout == "a2a":
            baxes = batch_axes(mesh)
            dp = 1
            for ax in baxes:
                dp *= mesh.shape[ax]
            E = shape[-3]
            ff = shape[-1] if leaf in ("w1", "w3") else shape[-2]
            if E % dp == 0 and ff % mesh.shape["model"] == 0:
                ndim = len(shape)
                axes = [None] * ndim
                axes[ndim - 3] = baxes
                if leaf in ("w1", "w3"):
                    axes[ndim - 1] = "model"
                else:
                    axes[ndim - 2] = "model"
                return P(*axes)
        if _moe_experts_divisible(shape, mesh):
            rule = (["experts", "dmodel", None] if leaf in ("w1", "w3")
                    else ["experts", None, "dmodel"])
        else:
            rule = ([None, "dmodel", "ff"] if leaf in ("w1", "w3")
                    else [None, "ff", "dmodel"])
    if layout == "fsdp":
        # ZeRO-3 wants the LARGEST axis sharded; prefer the non-dmodel
        # axis when it divides (ff/vocab/heads are the big dims).
        big = ["dmodel" if r is not None else None for r in rule]
        rule = big

    ndim = len(shape)
    axes: list = [None] * ndim
    offset = ndim - len(rule)   # leading stacked axes (scan segments)
    for i, logical in enumerate(rule):
        ax = _logical_to_mesh(logical, fsdp, layout)
        if ax is not None and shape[offset + i] % mesh.shape[ax] == 0:
            axes[offset + i] = ax
            if layout == "fsdp":
                break  # one sharded dim is enough for ZeRO-3
    return P(*axes)


def make_param_specs(params_shapes, mesh: Mesh, *, fsdp: bool = True,
                     layout: str = "tp", moe_layout: str = "psum"):
    """Map a pytree of ShapeDtypeStructs/arrays -> pytree of PartitionSpec."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for_param(path, leaf.shape, mesh, fsdp=fsdp,
                                          layout=layout,
                                          moe_layout=moe_layout),
        params_shapes)


def make_param_shardings(params_shapes, mesh: Mesh, *, fsdp: bool = True,
                         layout: str = "tp"):
    specs = make_param_specs(params_shapes, mesh, fsdp=fsdp, layout=layout)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def batch_axes(mesh: Mesh, layout: str = "tp") -> Tuple[str, ...]:
    if layout == "fsdp":
        return tuple(ax for ax in ("pod", "data", "model")
                     if ax in mesh.axis_names)
    return tuple(ax for ax in ("pod", "data") if ax in mesh.axis_names)


def make_constrain(mesh: Mesh, *, fsdp: bool = False, layout: str = "tp"):
    """Activation sharding-constraint function passed into the model.

    Carries a ``shard_ctx`` attribute (mesh, data axes, fsdp flag) so
    layers that run explicit shard_map regions (MoE dispatch) can build
    matching in/out specs. layout="fsdp" = no tensor parallelism: batch
    spans every axis and attention/MoE internals stay batch-sharded.
    """
    baxes = batch_axes(mesh, layout)
    model_size = 1 if layout == "fsdp" else mesh.shape["model"]

    dp_size = 1
    for ax in baxes:
        dp_size *= mesh.shape[ax]

    def _b(dim):
        """Largest batch-axis prefix whose product divides ``dim``
        (decode B=1 replicates; B=256 on 512 chips shards 32-way)."""
        axes = baxes
        while axes:
            dp = 1
            for ax in axes:
                dp *= mesh.shape[ax]
            if dim % dp == 0 and dim > 1:
                return axes
            axes = axes[:-1]
        return None

    def constrain(x, kind: str):
        if kind == "activations":
            spec = P(_b(x.shape[0]), *([None] * (x.ndim - 1)))
        elif kind == "logits":
            vshard = ("model" if layout != "fsdp"
                      and x.shape[-1] % model_size == 0 else None)
            spec = P(_b(x.shape[0]), *([None] * (x.ndim - 2)), vshard)
        elif kind == "attn_q5":
            # Stacked query chunks (nc, B, qc, H, Dh). Head-parallel when
            # heads divide the model axis (zero-comm scores); else
            # query-chunk sequence sharding with replicated k/v.
            _, b, qc, h, _ = x.shape
            if layout == "fsdp":
                spec = P(None, _b(b), None, None, None)
            elif h % model_size == 0:
                spec = P(None, _b(b), None, "model", None)
            elif qc % model_size == 0:
                spec = P(None, _b(b), "model", None, None)
            else:
                spec = P(None, _b(b), None, None, None)
        elif kind == "attn_kv":
            # x: (B, T, H, Dh): head-sharded when divisible, else
            # replicated inside the layer (scores stay device-local).
            b, _, h, _ = x.shape
            if layout != "fsdp" and h % model_size == 0:
                spec = P(_b(b), None, "model", None)
            else:
                spec = P(_b(b), None, None, None)
        elif kind == "moe_tokens":
            # flattened (T, d)
            spec = P(_b(x.shape[0]), None)
        elif kind == "moe_dispatch":
            # (E, C, d): experts over "model" (EP), capacity over data.
            e, c, _ = x.shape
            espec = "model" if e % model_size == 0 else None
            cspec = baxes if c % dp_size == 0 else None
            if espec is None and c % (dp_size * model_size) == 0:
                cspec = baxes + ("model",)
            spec = P(espec, cspec, None)
        else:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    if layout == "tp":
        constrain.shard_ctx = {"mesh": mesh, "data_axes": baxes,
                               "fsdp": fsdp}
    return constrain


def cache_spec_for_leaf(path, shape, mesh: Mesh) -> P:
    """KV caches / SSM states: batch over the data axes, plus a second
    sharded dim so no single state replicates at long context:

      KV k/v (B, S, Hkv, Dh):  B -> data axes, S -> "model"
                               (B==1: S -> data axes + "model" combined —
                               the 500k-decode flash-decoding layout; the
                               softmax stats all-reduce is tiny)
      Mamba conv (B, K-1, inner) / h (B, inner, N): inner -> "model"
      RWKV wkv (B, H, D, D): H -> "model"; shifts (B, d): d -> "model"

    Leaves may carry a leading stacked-segment axis (scan over periods).
    """
    names = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            names.append(str(k.key))
        elif hasattr(k, "name"):
            names.append(str(k.name))
    leaf = names[-1] if names else ""
    if leaf == "pos":
        return P()
    baxes = batch_axes(mesh)
    dp = 1
    for ax in baxes:
        dp *= mesh.shape[ax]
    model = mesh.shape["model"]

    ndim = len(shape)
    # nominal rank per leaf kind
    rank = {"k": 4, "v": 4, "conv": 3, "h": 3, "wkv": 4,
            "x_tm": 2, "x_cm": 2}.get(leaf, ndim)
    off = ndim - rank
    axes: list = [None] * ndim
    bdim = off  # batch dim position
    b_ok = shape[bdim] % dp == 0 and shape[bdim] > 1

    if leaf in ("k", "v"):
        s_dim, h_dim = off + 1, off + 2
        if b_ok:
            axes[bdim] = baxes
            if shape[s_dim] % model == 0:
                axes[s_dim] = "model"
        else:
            combined = baxes + ("model",)
            if shape[s_dim] % (dp * model) == 0:
                axes[s_dim] = combined
            elif shape[s_dim] % model == 0:
                axes[s_dim] = "model"
    elif leaf == "conv":
        if b_ok:
            axes[bdim] = baxes
        if shape[off + 2] % model == 0:
            axes[off + 2] = "model"
    elif leaf == "h":
        if b_ok:
            axes[bdim] = baxes
        if shape[off + 1] % model == 0:
            axes[off + 1] = "model"
    elif leaf == "wkv":
        if b_ok:
            axes[bdim] = baxes
        if shape[off + 1] % model == 0:
            axes[off + 1] = "model"
    elif leaf in ("x_tm", "x_cm"):
        if b_ok:
            axes[bdim] = baxes
        if shape[off + 1] % model == 0:
            axes[off + 1] = "model"
    return P(*axes)


def make_cache_shardings(cache_shapes, mesh: Mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, cache_spec_for_leaf(path, leaf.shape, mesh)),
        cache_shapes)
