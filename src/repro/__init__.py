"""repro — One-Class Slab SVM reproduction as a JAX/Pallas system.

``repro.fit(X, spec)`` is the front door: it composes the solver engine
(``repro.core.engine``) for the problem size and hardware. The import is
lazy so lightweight subpackage imports stay cheap.
"""


def __getattr__(name):
    if name == "fit":
        from repro.api import fit
        return fit
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = ["fit"]
