"""repro — One-Class Slab SVM reproduction as a JAX/Pallas system.

``repro.fit(X, spec)`` is the training front door: it composes the solver
engine (``repro.core.engine``) for the problem size and hardware.
``repro.serve(X, spec)`` is the serving front door: warm-model cache +
batched Pallas scoring (``repro.serve``). Imports are lazy so lightweight
subpackage imports stay cheap.
"""


def __getattr__(name):
    if name == "fit":
        from repro.api import fit
        return fit
    if name == "fit_update":
        from repro.api import fit_update
        return fit_update
    if name == "serve":
        # Import the subpackage (a callable module): ``repro.serve(X, s)``
        # and ``repro.serve.ModelCache`` resolve to the same object no
        # matter which is touched first.
        import repro.serve as serve_pkg
        return serve_pkg
    if name == "serve_async":
        # the coroutine front door: awaits scores through the
        # process-default admission controller + background driver
        from repro.serve.async_driver import serve_async
        return serve_async
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = ["fit", "fit_update", "serve", "serve_async"]
