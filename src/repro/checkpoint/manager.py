"""Sharded, atomic, hash-verified checkpoints in plain npz + JSON manifest.

Layout:  <dir>/step_000123/
            manifest.json   {step, tree structure, leaf dtypes/shapes, sha256}
            arrays.npz      flat leaf arrays keyed by tree path

Writes go to a tmp dir + atomic rename, so a crash mid-save never corrupts
the latest checkpoint (fault-tolerance invariant). ``AsyncCheckpointer``
moves serialization off the training thread. Any pytree works — model
params, optimizer state, data cursors, and mid-solve SMO state alike.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _path_key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _flatten_with_paths(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {_path_key(path): np.asarray(leaf) for path, leaf in leaves}


def save(directory: str, step: int, tree: Any, *, extra: Optional[dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = _flatten_with_paths(tree)
    npz_path = os.path.join(tmp, "arrays.npz")
    np.savez(npz_path, **flat)
    with open(npz_path, "rb") as f:
        digest = hashlib.sha256(f.read()).hexdigest()

    treedef = jax.tree_util.tree_structure(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "keys": sorted(flat),
        "sha256": digest,
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(directory: str, step: int, like: Any, *,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``like`` (shapes/dtypes preserved).

    ``shardings``: optional matching pytree of NamedShardings — this is the
    elastic-reshard path: the same checkpoint can be restored onto any mesh.
    """
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    npz_path = os.path.join(path, "arrays.npz")
    with open(npz_path, "rb") as f:
        digest = hashlib.sha256(f.read()).hexdigest()
    if digest != manifest["sha256"]:
        raise IOError(f"checkpoint {path} corrupt: sha mismatch")
    data = np.load(npz_path)

    leaves_with_paths = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves_with_paths))
    new_leaves = []
    for (path_keys, leaf), shd in zip(leaves_with_paths, shard_leaves):
        key = _path_key(path_keys)
        arr = data[key]
        if shd is not None:
            arr = jax.device_put(arr, shd)
        else:
            arr = jnp.asarray(arr, dtype=leaf.dtype if hasattr(leaf, "dtype")
                              else None)
        new_leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def restore_latest(directory: str, like: Any, *, shardings: Any = None):
    step = latest_step(directory)
    if step is None:
        return None, None
    return restore(directory, step, like, shardings=shardings), step


class AsyncCheckpointer:
    """Serialize + write off the training thread; at most one in flight."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._inflight: Optional[Future] = None
        self._lock = threading.Lock()

    def save(self, step: int, tree: Any, *, extra: Optional[dict] = None) -> Future:
        # Block on the previous write (bounded staleness), then snapshot to
        # host memory synchronously so the caller may mutate afterwards.
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)

        def work():
            p = save(self.directory, step, host_tree, extra=extra)
            self._gc()
            return p

        with self._lock:
            self._inflight = self._pool.submit(work)
        return self._inflight

    def wait(self):
        with self._lock:
            f = self._inflight
        if f is not None:
            f.result()

    def _gc(self):
        steps = sorted(s for s in (
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp")))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                          ignore_errors=True)
