"""Elastic resharding: restore any checkpoint onto any mesh.

Checkpoints store mesh-agnostic full arrays (manager.py gathers to host),
so elastic rescale is just "restore with the new mesh's shardings". This
module adds the spec re-derivation so callers only name the new mesh:

    new_state = reshard_checkpoint(dir, step, like_state, new_mesh)

covering the 512 -> 256 -> 128 chip scenarios (node loss, pool shrink).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding

from repro.checkpoint import manager
from repro.sharding.specs import make_param_specs


def shardings_for(like: Any, mesh: Mesh, *, fsdp: bool = True):
    """Param-rule shardings for every leaf of a params-like tree."""
    specs = make_param_specs(jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), like), mesh,
        fsdp=fsdp)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def reshard_checkpoint(directory: str, step: int, like: Any, mesh: Mesh, *,
                       fsdp: bool = True):
    return manager.restore(directory, step, like,
                           shardings=shardings_for(like, mesh, fsdp=fsdp))


def reshard_live(tree: Any, mesh: Mesh, *, fsdp: bool = True):
    """Re-lay live arrays onto a new mesh (no disk round trip)."""
    return jax.device_put(tree, shardings_for(tree, mesh, fsdp=fsdp))
