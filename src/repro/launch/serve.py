"""Serving launcher: prefill + batched decode on the production mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch musicgen-large \
        --reduced --batch 2 --prompt-len 16 --new-tokens 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.models.transformer import init_cache, init_params
from repro.sharding.specs import make_constrain
from repro.train.serve_step import make_decode, make_prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        mesh = make_test_mesh((1, 1), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    fsdp = cfg.param_count() >= 4e9 and not args.reduced
    constrain = make_constrain(mesh, fsdp=fsdp)

    total_len = args.prompt_len + args.new_tokens
    with mesh:
        params = init_params(cfg, jax.random.PRNGKey(0))
        cache = init_cache(cfg, args.batch, total_len, dtype=cfg.dtype)
        prefill = jax.jit(make_prefill(cfg, constrain=constrain),
                          donate_argnums=(1,))
        decode = jax.jit(make_decode(cfg, constrain=constrain),
                         donate_argnums=(1,))
        prompt = jax.random.randint(jax.random.PRNGKey(1),
                                    (args.batch, args.prompt_len), 0,
                                    cfg.vocab_size)
        t0 = time.perf_counter()
        logits, cache = prefill(params, cache, {"tokens": prompt})
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out = [tok]
        for _ in range(args.new_tokens - 1):
            logits, cache = decode(params, cache, tok)
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
            out.append(tok)
        jax.block_until_ready(tok)
        dt = time.perf_counter() - t0
    tokens = jnp.concatenate(out, axis=1)
    print(f"generated {tokens.shape} in {dt*1e3:.0f} ms "
          f"({dt / (args.new_tokens * args.batch) * 1e3:.1f} ms/token)")
    print("first sequence:", tokens[0].tolist())


if __name__ == "__main__":
    main()
