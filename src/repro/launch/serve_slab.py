"""Slab scoring-service launcher: the OCSSVM serving subsystem as a CLI.

Fits (or cache-hits) a slab on the toy problem, then drives a synthetic
request stream through the micro-batching ``ScoringService`` and prints
per-bucket latency/throughput counters.

    PYTHONPATH=src python -m repro.launch.serve_slab --m 2000 \
        --requests 64 --min-batch 8 --max-batch 512

    # pod-scale sharded scoring (forced host devices for a dry run):
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m repro.launch.serve_slab --sharded-devices 4

    # multi-model: registry + deadline-aware admission windows (keep the
    # quota strictly below --max-batch, or bucket fill drains the window
    # before the quota can bind — the controller warns if it cannot)
    PYTHONPATH=src python -m repro.launch.serve_slab \
        --models a=rbf:0.5 --models b=linear --deadline-ms 20 --quota 256

    # same fleet, flushed by the background event-loop driver instead of
    # the submit loop polling (deadlines honored with nobody polling)
    PYTHONPATH=src python -m repro.launch.serve_slab \
        --models a=rbf:0.5 --models b=linear --deadline-ms 20 --driver

    # cross-process fleet: one process fits and publishes the packed
    # model to shared memory, N others attach (bitwise-identical, no fit)
    PYTHONPATH=src python -m repro.launch.serve_slab --shm-publish warm-rbf
    PYTHONPATH=src python -m repro.launch.serve_slab --shm-attach warm-rbf
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

import repro
from repro.core import SlabSpec, linear, poly, rbf
from repro.data import make_toy
from repro.launch.mesh import make_test_mesh
from repro.serve import (AdmissionController, AsyncDriver, ModelRegistry,
                         QuotaExceededError, ScoringService, attach,
                         live_refs, publish, run_request_stream)


def _make_kernel(name: str, gamma: float):
    if name == "linear":
        return linear()
    if name == "poly":
        return poly(gamma=gamma, coef0=1.0, degree=2)
    if name == "rbf":
        return rbf(gamma=gamma)
    raise ValueError(f"unknown kernel {name!r} (linear/rbf/poly)")


def _kernel(args):
    return _make_kernel(args.kernel, args.gamma)


def _parse_model_flag(flag: str, args) -> tuple:
    """``NAME=KERNEL[:GAMMA[:NU1[:NU2[:EPS]]]]`` -> (name, SlabSpec).

    Unspecified fields inherit the single-model CLI defaults, so
    ``--models a=rbf:0.5 --models b=linear`` is a complete fleet spec.
    """
    name, sep, conf = flag.partition("=")
    if not sep or not name or not conf:
        raise ValueError(f"--models wants NAME=KERNEL[:GAMMA[:NU1[:NU2"
                         f"[:EPS]]]], got {flag!r}")
    parts = conf.split(":")
    kernel_name = parts[0]
    floats = [float(p) for p in parts[1:]]
    gamma = floats[0] if len(floats) > 0 else args.gamma
    nu1 = floats[1] if len(floats) > 1 else args.nu1
    nu2 = floats[2] if len(floats) > 2 else args.nu2
    eps = floats[3] if len(floats) > 3 else args.eps
    return name, SlabSpec(nu1=nu1, nu2=nu2, eps=eps,
                          kernel=_make_kernel(kernel_name, gamma))


def _run_multi_model(args):
    """Registry + admission-controller serving loop for ``--models``."""
    X, _ = make_toy(jax.random.PRNGKey(args.seed), args.m)
    registry = ModelRegistry()
    for flag in args.models:
        name, spec = _parse_model_flag(flag, args)
        registry.register(name, X, spec, quota=args.quota, tol=args.tol,
                          P=16, precision=args.precision)
    names = registry.names()

    ctrl = AdmissionController(registry, max_batch=args.max_batch,
                               max_wait_s=args.max_wait_ms / 1e3)
    t0 = time.perf_counter()
    for name in names:
        svc = ctrl.service(name)          # fit-on-first-use happens here
        svc.scorer.warmup()
        sm = registry.get(name)
        print(f"model {name}: {sm.n_sv} SVs packed {tuple(sm.t_pad.shape)} "
              f"[{args.precision}] quota={registry.quota(name)}")
    print(f"fleet of {len(names)} models warm in "
          f"{(time.perf_counter() - t0)*1e3:.0f} ms "
          f"(cache {registry.cache.hits} hits / "
          f"{registry.cache.misses} misses)")

    rng = np.random.default_rng(args.seed)
    sizes = rng.integers(args.min_batch, args.max_batch + 1,
                         size=args.requests)
    requests = [np.asarray(make_toy(jax.random.PRNGKey(1000 + i), int(n))[0])
                for i, n in enumerate(sizes)]
    deadline_s = args.deadline_ms / 1e3 if args.deadline_ms else None
    handles, rejected = [], 0

    def submit_stream():
        nonlocal rejected
        for i, q in enumerate(requests):
            model = names[i % len(names)]
            deadline = (ctrl.clock() + deadline_s) if deadline_s else None
            try:
                handles.append(ctrl.submit(model, q, deadline=deadline))
            except QuotaExceededError:
                rejected += 1
            if not args.driver:
                ctrl.poll()

    t0 = time.perf_counter()
    if args.driver:
        # the background driver owns every flush: it sleeps until the
        # earliest pending window is due (deadline pressure, window age,
        # bucket fill) and polls — the submit loop never does
        with AsyncDriver(ctrl):
            submit_stream()
            wait_until = time.monotonic() + 60.0
            while (not all(h.done for h in handles)
                   and time.monotonic() < wait_until):
                time.sleep(0.002)
        # context exit stops the driver after a final drain
    else:
        submit_stream()
        ctrl.drain()
    stream_s = time.perf_counter() - t0
    served_q = sum(h.n for h in handles)
    mode = "driver" if args.driver else "inline poll"
    print(f"stream[{mode}]: {len(handles)}/{args.requests} requests "
          f"admitted ({rejected} over quota) / {served_q} queries in "
          f"{stream_s*1e3:.0f} ms ({served_q/max(stream_s, 1e-9):.0f} q/s)")
    for line in ctrl.stats_lines():
        print("  " + line)

    inside = sum(int((np.asarray(h.result()) >= 0).sum()) for h in handles)
    print(f"decisions: {inside}/{served_q} inside the slab")

    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"m": args.m, "models": list(names),
                       "precision": args.precision,
                       "deadline_ms": args.deadline_ms,
                       "quota": args.quota, "stream_s": stream_s,
                       "requests": args.requests, "admitted": len(handles),
                       "rejected": rejected, "queries": served_q,
                       "per_model": ctrl.stats_dict()}, fh, indent=2)
        print(f"wrote {args.json}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--m", type=int, default=2000, help="training rows")
    ap.add_argument("--kernel", choices=("linear", "rbf", "poly"),
                    default="rbf")
    ap.add_argument("--gamma", type=float, default=0.5)
    ap.add_argument("--nu1", type=float, default=0.5)
    ap.add_argument("--nu2", type=float, default=0.05)
    ap.add_argument("--eps", type=float, default=0.5)
    ap.add_argument("--tol", type=float, default=1e-3)
    ap.add_argument("--requests", type=int, default=32,
                    help="synthetic requests in the stream")
    ap.add_argument("--min-batch", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=512)
    ap.add_argument("--coalesce", type=int, default=8,
                    help="requests submitted per flush window")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--precision", choices=("f32", "bf16", "f16"),
                    default="f32",
                    help="Gram tile precision for fit AND the packed "
                         "serving model (16-bit halves kernel HBM bytes)")
    ap.add_argument("--sharded-devices", type=int, default=0,
                    help="score through shard_map over this many devices "
                         "(needs >= that many jax devices)")
    ap.add_argument("--json", type=str, default=None,
                    help="also write the stats to this path as JSON")
    ap.add_argument("--models", action="append", default=None,
                    metavar="NAME=KERNEL[:GAMMA[:NU1[:NU2[:EPS]]]]",
                    help="repeatable; switches on the multi-model "
                         "registry + admission-controller path (e.g. "
                         "--models a=rbf:0.5 --models b=linear)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline for the admission windows "
                         "(multi-model path; default: no deadlines)")
    ap.add_argument("--quota", type=int, default=None,
                    help="per-model admission quota in queued rows "
                         "(multi-model path; default: unlimited)")
    ap.add_argument("--max-wait-ms", type=float, default=50.0,
                    help="age bound for deadline-less admission windows")
    ap.add_argument("--driver", action="store_true",
                    help="flush via the background AsyncDriver instead "
                         "of polling from the submit loop (multi-model "
                         "path)")
    ap.add_argument("--shm-publish", type=str, default=None, metavar="KEY",
                    help="publish the packed model to shared memory "
                         "under KEY (single-model path)")
    ap.add_argument("--shm-attach", type=str, default=None, metavar="KEY",
                    help="attach the packed model published under KEY "
                         "instead of fitting (single-model path)")
    args = ap.parse_args(argv)

    if args.models:
        return _run_multi_model(args)

    leases = []
    if args.shm_attach:
        # worker side of the cross-process fleet: rebuild the packed
        # model from shared memory — no fit, bitwise-identical scores
        t0 = time.perf_counter()
        sm, lease = attach(args.shm_attach)
        leases.append(lease)
        cold_s = time.perf_counter() - t0
        print(f"attach[{args.shm_attach!r}]: {sm.n_sv} SVs packed "
              f"{tuple(sm.t_pad.shape)} [{sm.precision}] in "
              f"{cold_s*1e3:.0f} ms (no fit; "
              f"{live_refs(args.shm_attach)} live leases)")
    else:
        spec = SlabSpec(nu1=args.nu1, nu2=args.nu2, eps=args.eps,
                        kernel=_kernel(args))
        X, _ = make_toy(jax.random.PRNGKey(args.seed), args.m)

        t0 = time.perf_counter()
        sm = repro.serve(X, spec, tol=args.tol, P=16,
                         precision=args.precision)
        cold_s = time.perf_counter() - t0
        cache = repro.serve.default_cache()
        print(f"serve: m={args.m} -> {sm.n_sv} SVs packed "
              f"{tuple(sm.t_pad.shape)} [{args.precision}] in "
              f"{cold_s*1e3:.0f} ms "
              f"(cache {cache.hits} hits / {cache.misses} misses)")
    if args.shm_publish:
        leases.append(publish(sm, args.shm_publish))
        print(f"publish[{args.shm_publish!r}]: segment live, "
              f"{live_refs(args.shm_publish)} leases — workers attach "
              f"with --shm-attach {args.shm_publish} (last lease out "
              f"unlinks)")

    if args.sharded_devices:
        mesh = make_test_mesh((args.sharded_devices,), ("data",))
        scorer = sm.scorer(mesh=mesh)
        print(f"sharded scoring over {args.sharded_devices} devices "
              f"(axis 'data')")
    else:
        scorer = sm.scorer()
    # warmup pre-compiles the path this scorer will actually serve with
    # (the shard_map executables when sharded)
    scorer.warmup()

    rng = np.random.default_rng(args.seed)
    sizes = rng.integers(args.min_batch, args.max_batch + 1,
                         size=args.requests)
    requests = [np.asarray(make_toy(jax.random.PRNGKey(1000 + i), int(n))[0])
                for i, n in enumerate(sizes)]

    svc = ScoringService(scorer)
    t0 = time.perf_counter()
    scores = run_request_stream(svc, requests, coalesce=args.coalesce)
    stream_s = time.perf_counter() - t0
    total_q = int(sizes.sum())
    print(f"stream: {args.requests} requests / {total_q} queries in "
          f"{stream_s*1e3:.0f} ms ({total_q/stream_s:.0f} q/s)")
    for line in svc.stats_lines():
        print("  " + line)

    inside = sum(int((np.asarray(s) >= 0).sum()) for s in scores)
    print(f"decisions: {inside}/{total_q} inside the slab")

    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"m": args.m, "n_sv": sm.n_sv,
                       "precision": sm.precision, "cold_s": cold_s,
                       "stream_s": stream_s, "requests": args.requests,
                       "queries": total_q,
                       "buckets": svc.stats_dict()}, fh, indent=2)
        print(f"wrote {args.json}")
    for lease in leases:
        lease.close()


if __name__ == "__main__":
    main()
