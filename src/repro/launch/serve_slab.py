"""Slab scoring-service launcher: the OCSSVM serving subsystem as a CLI.

Fits (or cache-hits) a slab on the toy problem, then drives a synthetic
request stream through the micro-batching ``ScoringService`` and prints
per-bucket latency/throughput counters.

    PYTHONPATH=src python -m repro.launch.serve_slab --m 2000 \
        --requests 64 --min-batch 8 --max-batch 512

    # pod-scale sharded scoring (forced host devices for a dry run):
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m repro.launch.serve_slab --sharded-devices 4
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

import repro
from repro.core import SlabSpec, linear, poly, rbf
from repro.data import make_toy
from repro.launch.mesh import make_test_mesh
from repro.serve import ScoringService, run_request_stream


def _kernel(args):
    if args.kernel == "linear":
        return linear()
    if args.kernel == "poly":
        return poly(gamma=args.gamma, coef0=1.0, degree=2)
    return rbf(gamma=args.gamma)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--m", type=int, default=2000, help="training rows")
    ap.add_argument("--kernel", choices=("linear", "rbf", "poly"),
                    default="rbf")
    ap.add_argument("--gamma", type=float, default=0.5)
    ap.add_argument("--nu1", type=float, default=0.5)
    ap.add_argument("--nu2", type=float, default=0.05)
    ap.add_argument("--eps", type=float, default=0.5)
    ap.add_argument("--tol", type=float, default=1e-3)
    ap.add_argument("--requests", type=int, default=32,
                    help="synthetic requests in the stream")
    ap.add_argument("--min-batch", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=512)
    ap.add_argument("--coalesce", type=int, default=8,
                    help="requests submitted per flush window")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--precision", choices=("f32", "bf16", "f16"),
                    default="f32",
                    help="Gram tile precision for fit AND the packed "
                         "serving model (16-bit halves kernel HBM bytes)")
    ap.add_argument("--sharded-devices", type=int, default=0,
                    help="score through shard_map over this many devices "
                         "(needs >= that many jax devices)")
    ap.add_argument("--json", type=str, default=None,
                    help="also write the stats to this path as JSON")
    args = ap.parse_args(argv)

    spec = SlabSpec(nu1=args.nu1, nu2=args.nu2, eps=args.eps,
                    kernel=_kernel(args))
    X, _ = make_toy(jax.random.PRNGKey(args.seed), args.m)

    t0 = time.perf_counter()
    sm = repro.serve(X, spec, tol=args.tol, P=16, precision=args.precision)
    cold_s = time.perf_counter() - t0
    cache = repro.serve.default_cache()
    print(f"serve: m={args.m} -> {sm.n_sv} SVs packed "
          f"{tuple(sm.t_pad.shape)} [{args.precision}] in "
          f"{cold_s*1e3:.0f} ms "
          f"(cache {cache.hits} hits / {cache.misses} misses)")

    if args.sharded_devices:
        mesh = make_test_mesh((args.sharded_devices,), ("data",))
        scorer = sm.scorer(mesh=mesh)
        print(f"sharded scoring over {args.sharded_devices} devices "
              f"(axis 'data')")
    else:
        scorer = sm.scorer()
    # warmup pre-compiles the path this scorer will actually serve with
    # (the shard_map executables when sharded)
    scorer.warmup()

    rng = np.random.default_rng(args.seed)
    sizes = rng.integers(args.min_batch, args.max_batch + 1,
                         size=args.requests)
    requests = [np.asarray(make_toy(jax.random.PRNGKey(1000 + i), int(n))[0])
                for i, n in enumerate(sizes)]

    svc = ScoringService(scorer)
    t0 = time.perf_counter()
    scores = run_request_stream(svc, requests, coalesce=args.coalesce)
    stream_s = time.perf_counter() - t0
    total_q = int(sizes.sum())
    print(f"stream: {args.requests} requests / {total_q} queries in "
          f"{stream_s*1e3:.0f} ms ({total_q/stream_s:.0f} q/s)")
    for line in svc.stats_lines():
        print("  " + line)

    inside = sum(int((np.asarray(s) >= 0).sum()) for s in scores)
    print(f"decisions: {inside}/{total_q} inside the slab")

    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"m": args.m, "n_sv": sm.n_sv,
                       "precision": args.precision, "cold_s": cold_s,
                       "stream_s": stream_s, "requests": args.requests,
                       "queries": total_q,
                       "buckets": svc.stats_dict()}, fh, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
