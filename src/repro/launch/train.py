"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
        --steps 100 --batch 8 --seq-len 256 --reduced --ckpt-dir /tmp/run1

On a real TPU slice, drop --reduced and the mesh flags pick the production
topology; on this CPU container --reduced runs the same code path end to
end (mesh (1,1), fault-tolerant loop, checkpoints, metrics).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_arch
from repro.data.synthetic import SyntheticPipeline
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.launch.specs import (batch_sds_and_shardings,
                                train_state_shardings)
from repro.models.transformer import init_params
from repro.runtime.fault_tolerance import FaultTolerantLoop
from repro.sharding.specs import make_constrain
from repro.train.train_step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config + (1,1) mesh for CPU runs")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--layout", default="tp", choices=["tp", "fsdp"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--save-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        mesh = make_test_mesh((1, 1), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    fsdp = cfg.param_count() >= 4e9
    constrain = make_constrain(mesh, fsdp=fsdp, layout=args.layout)

    params = init_params(cfg, jax.random.PRNGKey(0))
    state = init_train_state(cfg, params)
    state_shd = train_state_shardings(cfg, mesh, fsdp=fsdp,
                                      layout=args.layout)
    _, batch_shd = batch_sds_and_shardings(cfg, mesh, args.batch,
                                           args.seq_len, layout=args.layout)
    with mesh:
        state = jax.device_put(state, state_shd)
        step = jax.jit(
            make_train_step(cfg, constrain=constrain, peak_lr=args.lr,
                            warmup_steps=max(1, args.steps // 10),
                            total_steps=args.steps,
                            microbatches=args.microbatches),
            in_shardings=(state_shd, batch_shd),
            out_shardings=(state_shd, None), donate_argnums=(0,))
        pipe = SyntheticPipeline(cfg, batch=args.batch,
                                 seq_len=args.seq_len, seed=0,
                                 sharding=batch_shd)
        loop = FaultTolerantLoop(step, state, pipe, args.ckpt_dir,
                                 save_every=args.save_every)
        loop.run(args.steps)
    first, last = loop.metrics_log[0], loop.metrics_log[-1]
    print(f"step {first['step']}: loss {first['loss']:.4f}")
    print(f"step {last['step']}: loss {last['loss']:.4f} "
          f"({last['step_time_s']*1e3:.0f} ms/step, "
          f"restarts={loop.restarts})")


if __name__ == "__main__":
    main()
