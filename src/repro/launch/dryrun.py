import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any other import (jax locks the
# device count at first init). Everything below is ordinary code.
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import ARCHS, SHAPES, get_arch, long_context_capable  # noqa: E402
from repro.launch.mesh import make_production_mesh                        # noqa: E402
from repro.launch.specs import (batch_sds_and_shardings,                   # noqa: E402
                                decode_specs, param_shardings, params_sds,
                                train_state_sds, train_state_shardings)
from repro.sharding.specs import make_constrain                            # noqa: E402
from repro.train.serve_step import make_decode, make_prefill               # noqa: E402
from repro.train.train_step import make_train_step                         # noqa: E402
from repro.utils import hlo_analysis, roofline                             # noqa: E402

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: a successful
``.lower().compile()`` on the 16x16 single-pod and 2x16x16 multi-pod host
meshes means shardings divide, collectives are legal, and the memory
analysis is available for the roofline report.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/
"""

# FSDP (ZeRO-3 weight sharding over "data") on for everything that needs it;
# small models keep pure TP+DP which is faster at their scale.
FSDP_MIN_PARAMS = 4e9


def should_skip(arch: str, shape_name: str) -> str:
    cfg = get_arch(arch)
    if shape_name == "long_500k" and not long_context_capable(cfg):
        return ("pure full-attention arch: 500k dense-KV decode excluded "
                "per the long_500k sub-quadratic policy (DESIGN.md)")
    return ""


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               fsdp=None, q_chunk: int = 1024, layout: str = "tp",
               extra_tag: str = ""):
    cfg = get_arch(arch)
    sh = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    if fsdp is None:
        fsdp = cfg.param_count() >= FSDP_MIN_PARAMS
    constrain = make_constrain(mesh, fsdp=fsdp, layout=layout)

    with mesh:
        if sh["step"] == "train":
            state_sds = train_state_sds(cfg)
            state_shd = train_state_shardings(cfg, mesh, fsdp=fsdp,
                                              layout=layout)
            b_sds, b_shd = batch_sds_and_shardings(cfg, mesh, sh["batch"],
                                                   sh["seq_len"],
                                                   layout=layout)
            # Auto gradient accumulation: ~2 sequences per device per
            # microbatch (1 for deep/wide models) so activation residuals
            # and attention-score transients fit HBM.
            dp = 1
            axes = (("pod", "data", "model") if layout == "fsdp"
                    else ("pod", "data"))
            for ax in axes:
                if ax in mesh.axis_names:
                    dp *= mesh.shape[ax]
            b_loc = max(1, sh["batch"] // dp)
            big = cfg.n_layers * cfg.d_model >= 250_000
            microbatches = b_loc if big else max(1, b_loc // 2)
            step = make_train_step(cfg, constrain=constrain,
                                   microbatches=microbatches)
            lowered = jax.jit(step, in_shardings=(state_shd, b_shd),
                              out_shardings=(state_shd, None),
                              donate_argnums=(0,)).lower(state_sds, b_sds)
        elif sh["step"] == "prefill":
            p_sds = params_sds(cfg)
            p_shd = param_shardings(cfg, mesh, fsdp=fsdp)
            c_sds, c_shd, _, _ = decode_specs(cfg, mesh, sh["batch"],
                                              sh["seq_len"])
            b_sds, b_shd = batch_sds_and_shardings(cfg, mesh, sh["batch"],
                                                   sh["seq_len"])
            b_sds.pop("labels")
            b_shd.pop("labels")
            fn = make_prefill(cfg, constrain=constrain, q_chunk=q_chunk)
            lowered = jax.jit(fn, in_shardings=(p_shd, c_shd, b_shd),
                              out_shardings=(None, c_shd),
                              donate_argnums=(1,)).lower(p_sds, c_sds, b_sds)
        else:  # decode
            p_sds = params_sds(cfg)
            p_shd = param_shardings(cfg, mesh, fsdp=fsdp)
            c_sds, c_shd, tok_sds, tok_shd = decode_specs(
                cfg, mesh, sh["batch"], sh["seq_len"])
            fn = make_decode(cfg, constrain=constrain)
            lowered = jax.jit(fn, in_shardings=(p_shd, c_shd, tok_shd),
                              out_shardings=(None, c_shd),
                              donate_argnums=(1,)).lower(p_sds, c_sds,
                                                         tok_sds)
    return cfg, mesh, lowered


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             fsdp=None, q_chunk: int = 1024, layout: str = "tp") -> dict:
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "layout": layout,
        "status": "ok",
    }
    skip = should_skip(arch, shape_name)
    if skip:
        rec["status"] = "skipped"
        rec["reason"] = skip
        return rec
    t0 = time.time()
    try:
        cfg, mesh, lowered = lower_cell(arch, shape_name,
                                        multi_pod=multi_pod, fsdp=fsdp,
                                        q_chunk=q_chunk, layout=layout)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes_per_device": int(mem.argument_size_in_bytes),
            "output_bytes_per_device": int(mem.output_size_in_bytes),
            "temp_bytes_per_device": int(mem.temp_size_in_bytes),
            "alias_bytes_per_device": int(mem.alias_size_in_bytes),
        }
        peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
        rec["memory"]["peak_bytes_per_device"] = int(peak)
        rec["memory"]["fits_16gb_hbm"] = bool(peak < 16e9)

        xla_cost = compiled.cost_analysis()
        rec["xla_cost"] = {
            "flops_body_once": float(xla_cost.get("flops", -1.0)),
            "bytes_accessed_body_once": float(
                xla_cost.get("bytes accessed", -1.0)),
        }

        text = compiled.as_text()
        chips = mesh.size
        # The compiled module is the per-device program: scale by chips.
        cost = hlo_analysis.analyze(text, n_devices=chips)
        hlo_flops = cost.flops * chips
        hlo_bytes_ub = cost.bytes * chips       # upper bound (CPU fusion)
        coll_bytes = cost.collective_bytes * chips
        analytic_flops = roofline.model_flops(cfg, shape_name)
        analytic_bytes = roofline.model_hbm_bytes(cfg, shape_name, chips)
        # Roofline terms: compute + collectives from the compiled HLO
        # (trip-count-scaled), memory from the analytic inventory — the
        # CPU backend's fusion boundaries overcount TPU HBM traffic
        # (methodology in EXPERIMENTS.md §Roofline).
        terms = roofline.terms(hlo_flops, analytic_bytes, coll_bytes, chips)
        rec["hlo_cost"] = {
            "flops_trip_scaled": hlo_flops,
            "hbm_bytes_upper_bound": hlo_bytes_ub,
            "collective_bytes": coll_bytes,
            "collectives": {k: v * chips for k, v in cost.coll.items()},
        }
        rec["analytic"] = {
            "model_flops": analytic_flops,
            "model_hbm_bytes": analytic_bytes,
            "useful_flops_ratio": (analytic_flops / hlo_flops
                                   if hlo_flops else None),
        }
        rec["roofline"] = terms.to_dict()
        rec["roofline"]["mfu_fraction"] = roofline.mfu_fraction(
            terms, analytic_flops)
        # roofline fraction using analytic FLOPs as the useful-work yardstick
    except Exception as e:  # noqa: BLE001 — record, continue the sweep
        rec["status"] = "failed"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true",
                    help="all archs x shapes, single-pod + multi-pod")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--fsdp", default="auto", choices=["auto", "on", "off"])
    ap.add_argument("--q-chunk", type=int, default=1024)
    args = ap.parse_args()

    archs = sorted(ARCHS) if (args.all or args.arch == "all") else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape == "all") else [args.shape]
    meshes = ([False, True] if (args.all or args.mesh == "both")
              else [args.mesh == "multi"])
    fsdp = None if args.fsdp == "auto" else (args.fsdp == "on")

    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        for shape in shapes:
            for multi_pod in meshes:
                tag = f"{arch}__{shape}__{'multi' if multi_pod else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[skip existing] {tag}")
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                rec = run_cell(arch, shape, multi_pod=multi_pod, fsdp=fsdp,
                               q_chunk=args.q_chunk)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    extra = (f" compile={rec['compile_s']}s "
                             f"dominant={rec['roofline']['dominant']} "
                             f"peak/dev={rec['memory']['peak_bytes_per_device']/1e9:.2f}GB")
                elif status == "failed":
                    extra = " " + rec["error"][:200]
                print(f"[dryrun] {tag}: {status}{extra}", flush=True)


if __name__ == "__main__":
    main()
