"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape x step).

``input_specs`` returns everything ``dryrun.py``/``train.py`` need to lower
a step function without allocating a single parameter: weak-type-correct
ShapeDtypeStructs for params, optimizer state, KV caches and batches, plus
the matching NamedShardings derived from the sharding rules.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, SHAPES
from repro.models.transformer import init_cache, init_params
from repro.optim.adafactor import AdafactorState
from repro.optim.adamw import AdamWState
from repro.sharding.specs import (batch_axes, make_cache_shardings,
                                  make_param_specs)
from repro.train.train_step import TrainState


def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def params_sds(cfg: ArchConfig):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def cache_sds(cfg: ArchConfig, batch: int, seq_len: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, seq_len,
                                             dtype=cfg.dtype))


def param_shardings(cfg: ArchConfig, mesh: Mesh, *, fsdp: bool = True,
                    layout: str = "tp"):
    specs = make_param_specs(params_sds(cfg), mesh, fsdp=fsdp, layout=layout,
                             moe_layout=cfg.moe_impl)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def _drop_axis(spec: P, k: int) -> P:
    """Spec for a factored-moment leaf (last k axes removed)."""
    t = tuple(spec)
    return P(*t[:-k]) if len(t) >= k else P(*t)


def opt_state_shardings(cfg: ArchConfig, mesh: Mesh, *, fsdp: bool = True,
                        layout: str = "tp"):
    psds = params_sds(cfg)
    specs = make_param_specs(psds, mesh, fsdp=fsdp, layout=layout,
                             moe_layout=cfg.moe_impl)
    if layout == "fsdp":
        # ZeRO-2 moments: shard a second axis over "data" when it divides
        # (the moments never enter fwd/bwd math, so the extra resharding
        # cost is one cheap transpose at update time).
        def densify(s, p):
            t = list(s) + [None] * (len(p.shape) - len(tuple(s)))
            if "data" not in t:
                for i, ax in enumerate(t):
                    if ax is None and p.shape[i] % mesh.shape["data"] == 0 \
                            and p.shape[i] > 1:
                        t[i] = "data"
                        break
            return P(*t)

        specs = jax.tree.map(densify, specs, psds)
    rep = NamedSharding(mesh, P())
    def as_shard(tree):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), tree)
    if cfg.optimizer == "adafactor":
        vr = jax.tree.map(lambda s, p: NamedSharding(
            mesh, _drop_axis(s, 1) if len(p.shape) >= 2 else s),
            specs, psds)
        vc = jax.tree.map(lambda s, p: NamedSharding(
            mesh, P(*(tuple(s)[:-2] + tuple(s)[-1:]))
            if len(p.shape) >= 2 and len(tuple(s)) >= 2 else P()),
            specs, psds)
        return AdafactorState(step=rep, vr=vr, vc=vc)
    return AdamWState(step=rep, m=as_shard(specs), v=as_shard(specs))


def opt_state_sds(cfg: ArchConfig):
    psds = params_sds(cfg)
    if cfg.optimizer == "adafactor":
        from repro.optim import adafactor
        return jax.eval_shape(adafactor.init, psds)
    from repro.optim import adamw
    return jax.eval_shape(adamw.init, psds)


def train_state_sds(cfg: ArchConfig):
    return TrainState(params=params_sds(cfg), opt_state=opt_state_sds(cfg),
                      step=jax.ShapeDtypeStruct((), jnp.int32))


def train_state_shardings(cfg: ArchConfig, mesh: Mesh, *, fsdp: bool = True,
                          layout: str = "tp"):
    return TrainState(
        params=param_shardings(cfg, mesh, fsdp=fsdp, layout=layout),
        opt_state=opt_state_shardings(cfg, mesh, fsdp=fsdp, layout=layout),
        step=NamedSharding(mesh, P()))


def batch_sds_and_shardings(cfg: ArchConfig, mesh: Mesh, batch: int,
                            seq_len: int,
                            layout: str = "tp") -> Tuple[dict, dict]:
    baxes = batch_axes(mesh, layout)
    # Drop trailing batch axes until the global batch divides (e.g. B=256
    # under the fsdp layout on 512 chips shards 32-way over pod x data and
    # replicates over model).
    while baxes:
        dp = 1
        for ax in baxes:
            dp *= mesh.shape[ax]
        if batch % dp == 0:
            break
        baxes = baxes[:-1]
    bspec = NamedSharding(mesh, P(baxes))
    b3 = NamedSharding(mesh, P(baxes, None, None))
    sds: Dict[str, Any] = {}
    shd: Dict[str, Any] = {}
    if cfg.frontend == "audio":
        sds["embeds"] = jax.ShapeDtypeStruct((batch, seq_len, cfg.d_model),
                                             cfg.dtype)
        sds["labels"] = jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)
        shd["embeds"] = b3
        shd["labels"] = bspec
    elif cfg.frontend == "vision":
        nv = cfg.n_frontend_tokens
        sds["tokens"] = jax.ShapeDtypeStruct((batch, seq_len - nv), jnp.int32)
        sds["labels"] = jax.ShapeDtypeStruct((batch, seq_len - nv), jnp.int32)
        sds["vision_embeds"] = jax.ShapeDtypeStruct((batch, nv, cfg.d_model),
                                                    cfg.dtype)
        shd["tokens"] = bspec
        shd["labels"] = bspec
        shd["vision_embeds"] = b3
    else:
        sds["tokens"] = jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)
        sds["labels"] = jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)
        shd["tokens"] = bspec
        shd["labels"] = bspec
    return sds, shd


def decode_specs(cfg: ArchConfig, mesh: Mesh, batch: int, seq_len: int):
    """(params, cache, token) SDS + shardings for one decode step."""
    baxes = batch_axes(mesh)
    dp = 1
    for ax in baxes:
        dp *= mesh.shape[ax]
    cache = cache_sds(cfg, batch, seq_len)
    cache_shd = make_cache_shardings(cache, mesh)
    tok = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    tok_shd = NamedSharding(mesh, P(baxes if batch % dp == 0 else None, None))
    return cache, cache_shd, tok, tok_shd


def input_specs(cfg: ArchConfig, shape_name: str, mesh: Mesh, *,
                fsdp: bool = True, layout: str = "tp"):
    """ShapeDtypeStruct stand-ins for every model input of a shape cell —
    weak-type-correct, shardable, no device allocation (the dry-run
    contract). Returns (kind, sds_args, sharding_args) where the step
    function is lowered as jit(step, in_shardings=sharding_args)(*sds_args).
    """
    sh = SHAPES[shape_name]
    if sh["step"] == "train":
        state = train_state_sds(cfg)
        state_shd = train_state_shardings(cfg, mesh, fsdp=fsdp,
                                          layout=layout)
        b_sds, b_shd = batch_sds_and_shardings(cfg, mesh, sh["batch"],
                                               sh["seq_len"], layout=layout)
        return "train", (state, b_sds), (state_shd, b_shd)
    p_sds = params_sds(cfg)
    p_shd = param_shardings(cfg, mesh, fsdp=fsdp, layout=layout)
    c_sds, c_shd, tok_sds, tok_shd = decode_specs(cfg, mesh, sh["batch"],
                                                  sh["seq_len"])
    if sh["step"] == "prefill":
        b_sds, b_shd = batch_sds_and_shardings(cfg, mesh, sh["batch"],
                                               sh["seq_len"], layout=layout)
        b_sds.pop("labels")
        b_shd.pop("labels")
        return "prefill", (p_sds, c_sds, b_sds), (p_shd, c_shd, b_shd)
    return "decode", (p_sds, c_sds, tok_sds), (p_shd, c_shd, tok_shd)
