"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing never touches jax
device state. Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod: (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis composes with "data" for batch sharding; only DP-style
all-reduces cross the inter-pod links.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count "
            "before importing jax (dryrun.py does this)")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_test_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Small meshes for CPU tests (e.g. (1,1) or (2,2))."""
    n = math.prod(shape)
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


def make_solver_mesh(*, multi_pod: bool = False, devices=None):
    """Mesh + row-sharding axes for the distributed OCSSVM solver.

    This is how ``repro.fit(strategy="sharded")`` gets its mesh from the
    launch layer instead of hand-rolling one: a fleet that matches the
    production pod topology gets exactly ``make_production_mesh``
    ((16, 16) single-pod / (2, 16, 16) multi-pod), and anything smaller —
    CPU CI under ``--xla_force_host_platform_device_count``, a dev box
    with a handful of chips — gets the SAME axis structure scaled down to
    the available devices, so solver code and tests never see different
    axis names between CI and a pod.

    Returns ``(mesh, data_axes)``: the solver row-shards X/gamma/f over
    ``data_axes`` (("pod", "data") multi-pod, ("data",) otherwise); the
    "model" axis, when present, is untouched by the solver (its arrays
    are replicated over it).
    """
    devices = list(jax.devices()) if devices is None else list(devices)
    data_axes = ("pod", "data") if multi_pod else ("data",)
    if len(devices) >= math.prod((2, 16, 16) if multi_pod else (16, 16)):
        return make_production_mesh(multi_pod=multi_pod), data_axes
    n = len(devices)
    if multi_pod:
        if n < 2 or n % 2:
            raise RuntimeError(
                f"multi_pod solver mesh needs an even device count >= 2, "
                f"found {n}")
        mesh = jax.make_mesh((2, n // 2, 1), ("pod", "data", "model"),
                             devices=devices)
    else:
        mesh = jax.make_mesh((n, 1), ("data", "model"), devices=devices)
    return mesh, data_axes
