"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing never touches jax
device state. Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod: (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis composes with "data" for batch sharding; only DP-style
all-reduces cross the inter-pod links.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count "
            "before importing jax (dryrun.py does this)")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_test_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Small meshes for CPU tests (e.g. (1,1) or (2,2))."""
    n = math.prod(shape)
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])
