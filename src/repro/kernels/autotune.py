"""Tile-config autotuner for the three Pallas kernel families.

Sweeps (block_m, block_n, block_k, buffer depth) per
(family, shape, precision, backend) cell, times each feasible candidate
through the real ``ops.py`` wrappers (explicit block kwargs, so the
sweep itself never consults the table it is producing), classifies every
candidate as DMA-bound vs compute-bound on the ``utils/roofline.py``
three-term model, and commits the winners to the table
``kernels/tuned_configs.json`` that ``kernels.tiling.resolve_tiles``
consults at trace time.

The moving parts:

* :func:`candidates` — the feasible config space for one cell: block
  dims are multiples of 128 capped at the padded problem dims, and a
  VMEM model (``depth`` in-flight copies of every streamed tile + the
  resident accumulator) rejects configs that blow the ~16 MB/core
  budget. ``depth`` (double vs quad buffering) is swept only on real
  TPU backends: interpret mode has no DMA pipeline, so depth-4 rows
  would just duplicate depth-2 timings.
* :func:`cost_model` — analytic FLOPs and HBM bytes for one candidate,
  including the tile re-streaming the grid actually does (e.g. the gram
  x-panel is re-read once per column tile, so bigger ``block_n`` cuts
  HBM traffic — the whole reason the sweep finds non-default winners).
* :func:`classify` — roofline terms from those two numbers
  (``utils.roofline.terms``; collective = 0 for single-chip kernels);
  ``bound`` is the dominant term ("memory" = DMA-bound, "compute").
* :func:`sweep` — run a list of :class:`Cell` s, emit candidate + winner
  rows in the ``results/BENCH_autotune.json`` schema.
* :func:`winners_to_entries` / :func:`write_table` — turn winners into
  the committed table format and merge them into ``tuned_configs.json``
  (existing entries for other keys are preserved).

Wall-clock caveat: on CPU the kernels run in interpret mode, so the
timings are emulation numbers — stable enough to rank configs and to
serve as regression canaries (the CI gate), but not TPU projections.
The table is therefore keyed by backend, and an interpret-produced
table never steers real TPU launches (``tiling.backend_name``).

Entry points: ``benchmarks/autotune_kernels.py`` (CLI: quick/full
sweeps, BENCH JSON, ``--update-table``); docs/kernels.md documents the
produce/consume cycle.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Iterable, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.kernel_fn import rbf
from repro.kernels.decision.ops import decision
from repro.kernels.fupdate.ops import fupdate
from repro.kernels.gram.ops import gram
from repro.kernels.precision import check_precision, tile_dtype
from repro.kernels.tiling import (DEPTHS, LANE, TUNED_TABLE_PATH,
                                  _auto_interpret, backend_name)
from repro.utils.roofline import terms

# VMEM feasibility budget: ~16 MB/core on v5e, keep 10% headroom for
# semaphores/control.
VMEM_BUDGET_BYTES = int(16 * 1024 * 1024 * 0.9)

# Block-size menu per axis (capped at the padded problem dim per cell).
BLOCK_CHOICES = (128, 256, 512)
FUPDATE_BM_CHOICES = (128, 256, 512, 1024)


@dataclass(frozen=True)
class Cell:
    """One sweep cell: a (family, shape) point.

    Shape semantics per family — ``m`` is always the table-key row count:
      gram:     m x n Gram block, d features (training: n == m).
      fupdate:  m training rows, n = selected-block size (2P), d features.
      decision: m support rows, n query rows, d features.
    """

    family: str
    m: int
    n: int
    d: int


# The shapes the solver/serving paths actually launch (see
# docs/kernels.md): quick mode covers the tier-1/CI sizes, full mode
# adds larger m and wider d so nearest-shape lookups interpolate.
QUICK_CELLS = (
    Cell("gram", 512, 512, 16),
    Cell("fupdate", 512, 16, 16),
    Cell("decision", 512, 128, 16),
)
FULL_CELLS = QUICK_CELLS + (
    Cell("gram", 1024, 1024, 64),
    Cell("gram", 2048, 2048, 16),
    Cell("fupdate", 1024, 16, 64),
    Cell("fupdate", 2048, 32, 16),
    Cell("decision", 1024, 256, 64),
    Cell("decision", 4096, 256, 16),
)


def _ceil_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _block_menu(dim: int, choices: Sequence[int]) -> List[int]:
    """Feasible block sizes for one axis: multiples of 128 from the menu,
    capped at the padded dim (a block larger than the padded problem only
    inflates zero-padding work)."""
    cap = _ceil_to(max(dim, 1), LANE)
    out = [c for c in choices if c <= cap]
    return out or [LANE]


def vmem_bytes(cell: Cell, *, block_m: int, block_n: Optional[int],
               block_k: Optional[int], depth: int, precision: str) -> int:
    """Per-grid-step VMEM footprint: ``depth`` in-flight copies of every
    streamed tile plus the resident f32 accumulator/output tile."""
    dtb = jnp.dtype(tile_dtype(precision)).itemsize
    if cell.family == "gram":
        stream = (block_m * block_k + block_n * block_k) * dtb \
            + (block_m + block_n) * 4
        resident = block_m * block_n * 4
    elif cell.family == "fupdate":
        sp = _ceil_to(cell.n, LANE)
        kb = block_k
        stream = (block_m * kb + sp * kb) * dtb + (2 * block_m + 2 * sp) * 4
        resident = block_m * sp * 4 + block_m * 4
    elif cell.family == "decision":
        dp = _ceil_to(cell.d, LANE)
        stream = (block_m * dp + block_n * dp) * dtb + 2 * block_n * 4
        resident = block_m * 4 * 2
    else:
        raise ValueError(f"unknown family {cell.family!r}")
    return depth * stream + resident


def cost_model(cell: Cell, *, block_m: int, block_n: Optional[int],
               block_k: Optional[int], precision: str) -> tuple:
    """(flops, hbm_bytes) for one candidate.

    FLOPs count the logical (unpadded) work; HBM bytes count the padded
    operand panels times the number of times the grid actually streams
    them (tile reuse is what the block sizes trade off).
    """
    dtb = jnp.dtype(tile_dtype(precision)).itemsize
    if cell.family == "gram":
        m, n, d = cell.m, cell.n, cell.d
        mp, np_, dp = (_ceil_to(m, block_m), _ceil_to(n, block_n),
                       _ceil_to(d, block_k))
        flops = 2.0 * m * n * d
        hbm = (mp * dp * dtb * (np_ // block_n)      # x, once per col tile
               + np_ * dp * dtb * (mp // block_m)    # y, once per row tile
               + mp * np_ * 4.0                      # output, written once
               + (mp + np_) * 4.0)                   # norms
    elif cell.family == "fupdate":
        m, s, d = cell.m, cell.n, cell.d
        mp, sp, dp = _ceil_to(m, block_m), _ceil_to(s, LANE), \
            _ceil_to(d, block_k)
        ni = mp // block_m
        flops = 2.0 * m * s * d + 2.0 * m * s
        hbm = (mp * dp * dtb                         # x, streamed once
               + sp * dp * dtb * ni                  # xsel, per row tile
               + 3.0 * mp * 4.0                      # f in, f out, norms
               + ni * 2.0 * sp * 4.0)                # delta + sel norms
    elif cell.family == "decision":
        msv, nq, d = cell.m, cell.n, cell.d
        qp, mp, dp = (_ceil_to(nq, block_m), _ceil_to(msv, block_n),
                      _ceil_to(d, LANE))
        ni = qp // block_m
        flops = 2.0 * nq * msv * d + 2.0 * nq * msv
        hbm = (qp * dp * dtb                         # q, once per row tile
               + mp * dp * dtb * ni                  # t, per query tile
               + 2.0 * mp * 4.0 * ni                 # gamma + norms
               + 2.0 * qp * 4.0)                     # q norms + output
    else:
        raise ValueError(f"unknown family {cell.family!r}")
    return flops, hbm


def classify(flops: float, hbm_bytes: float) -> str:
    """DMA-bound ("memory") vs compute-bound via the roofline terms
    (single chip, no collectives)."""
    t = terms(flops, hbm_bytes, 0.0, 1)
    return "memory" if t.memory_s >= t.compute_s else "compute"


def candidates(cell: Cell, *, precision: str,
               interpret: bool) -> List[dict]:
    """The feasible (block_m, block_n, block_k, depth) space for a cell."""
    if cell.family == "gram":
        bms = _block_menu(cell.m, BLOCK_CHOICES)
        bns = _block_menu(cell.n, BLOCK_CHOICES)
        bks = _block_menu(cell.d, BLOCK_CHOICES)
        space = [(bm, bn, bk) for bm in bms for bn in bns for bk in bks]
    elif cell.family == "fupdate":
        bms = _block_menu(cell.m, FUPDATE_BM_CHOICES)
        bks = _block_menu(cell.d, BLOCK_CHOICES)
        space = [(bm, None, bk) for bm in bms for bk in bks]
    elif cell.family == "decision":
        bms = _block_menu(cell.n, BLOCK_CHOICES)      # query tiles
        bns = _block_menu(cell.m, BLOCK_CHOICES)      # support tiles
        space = [(bm, bn, None) for bm in bms for bn in bns]
    else:
        raise ValueError(f"unknown family {cell.family!r}")
    depths = (2,) if interpret else DEPTHS
    out = []
    for bm, bn, bk in space:
        for depth in depths:
            if vmem_bytes(cell, block_m=bm, block_n=bn, block_k=bk,
                          depth=depth, precision=precision) \
                    > VMEM_BUDGET_BYTES:
                continue
            out.append({"block_m": bm, "block_n": bn, "block_k": bk,
                        "depth": depth})
    return out


def _candidate_name(cell: Cell, cfg: dict) -> str:
    bits = [f"{cell.family}_m{cell.m}_n{cell.n}_d{cell.d}",
            f"bm{cfg['block_m']}"]
    if cfg["block_n"] is not None:
        bits.append(f"bn{cfg['block_n']}")
    if cfg["block_k"] is not None:
        bits.append(f"bk{cfg['block_k']}")
    bits.append(f"x{cfg['depth']}")
    return "_".join(bits)


def _make_runner(cell: Cell, precision: str,
                 interpret: bool) -> Callable[[dict], jax.Array]:
    """Build the timed closure for one cell: data is created once, each
    candidate launches through the real ops wrapper with explicit block
    kwargs (never the table)."""
    kern = rbf(gamma=0.5)
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    if cell.family == "gram":
        x = jax.random.normal(keys[0], (cell.m, cell.d), jnp.float32)
        y = jax.random.normal(keys[1], (cell.n, cell.d), jnp.float32)

        def run(cfg):
            return gram(x, y, kern, tm=cfg["block_m"], tn=cfg["block_n"],
                        tk=cfg["block_k"], interpret=interpret,
                        precision=precision)
    elif cell.family == "fupdate":
        x = jax.random.normal(keys[0], (cell.m, cell.d), jnp.float32)
        xsel = x[:cell.n]
        delta = jax.random.normal(keys[1], (cell.n,), jnp.float32) * 0.05
        f = jax.random.normal(keys[2], (cell.m,), jnp.float32)

        def run(cfg):
            return fupdate(x, xsel, delta, f, kern, tm=cfg["block_m"],
                           tk=cfg["block_k"], interpret=interpret,
                           precision=precision)
    elif cell.family == "decision":
        t = jax.random.normal(keys[0], (cell.m, cell.d), jnp.float32)
        q = jax.random.normal(keys[1], (cell.n, cell.d), jnp.float32)
        gv = jax.random.normal(keys[2], (cell.m,), jnp.float32) * 0.05

        def run(cfg):
            return decision(q, t, gv, 0.2, 0.8, kern, tm=cfg["block_m"],
                            tn=cfg["block_n"], interpret=interpret,
                            precision=precision)
    else:
        raise ValueError(f"unknown family {cell.family!r}")
    return run


def _time_best_of(fn: Callable[[], jax.Array], repeats: int) -> float:
    """min-of-N wall time after one untimed compile/warmup call — min is
    far more jitter-stable than mean for the millisecond interpret-mode
    launches the CI gate diffs."""
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def sweep(cells: Optional[Iterable[Cell]] = None, *, mode: str = "quick",
          precisions: Sequence[str] = ("f32",), repeats: int = 3,
          interpret: Optional[bool] = None,
          progress: Optional[Callable[[str], None]] = None) -> dict:
    """Run the autotune sweep; returns the BENCH_autotune.json document.

    ``cells`` defaults to :data:`QUICK_CELLS` / :data:`FULL_CELLS` by
    ``mode``. One winner row is emitted per (cell, precision): the
    candidate with the smallest min-of-``repeats`` wall time.
    """
    if cells is None:
        cells = QUICK_CELLS if mode == "quick" else FULL_CELLS
    if interpret is None:
        interpret = _auto_interpret()
    precisions = tuple(check_precision(p) for p in precisions)
    say = progress or (lambda _msg: None)

    cand_rows: List[dict] = []
    winner_rows: List[dict] = []
    for cell in cells:
        for precision in precisions:
            run = _make_runner(cell, precision, interpret)
            best = None
            for cfg in candidates(cell, precision=precision,
                                  interpret=interpret):
                flops, hbm = cost_model(
                    cell, block_m=cfg["block_m"], block_n=cfg["block_n"],
                    block_k=cfg["block_k"], precision=precision)
                t = _time_best_of(lambda cfg=cfg: run(cfg), repeats)
                row = {
                    "name": _candidate_name(cell, cfg),
                    "family": cell.family,
                    "m": cell.m, "n": cell.n, "d": cell.d,
                    "precision": precision,
                    "time_s": t,
                    "block_m": cfg["block_m"], "block_n": cfg["block_n"],
                    "block_k": cfg["block_k"], "depth": cfg["depth"],
                    "bound": classify(flops, hbm),
                    "flops": flops, "hbm_bytes": hbm,
                }
                cand_rows.append(row)
                say(f"{row['name']},{precision},{t * 1e6:.0f}us,"
                    f"{row['bound']}-bound")
                if best is None or t < best["time_s"]:
                    best = row
            win = dict(best)
            win["name"] = (f"{cell.family}_m{cell.m}_n{cell.n}"
                           f"_d{cell.d}_best")
            win["best_s"] = win.pop("time_s")
            winner_rows.append(win)
            say(f"WINNER {win['name']},{precision},"
                f"bm{win['block_m']}/bn{win['block_n']}/"
                f"bk{win['block_k']}/x{win['depth']},"
                f"{win['best_s'] * 1e6:.0f}us")

    return {
        "mode": mode,
        "backend": backend_name(interpret),
        "interpret": interpret,
        "candidates": cand_rows,
        "winners": winner_rows,
    }


# ---------------------------------------------------------------------------
# committed-table production
# ---------------------------------------------------------------------------

def winners_to_entries(result: dict) -> List[dict]:
    """Winner rows -> tuned-table entries keyed for ``resolve_tiles``."""
    backend = result["backend"]
    out = []
    for w in result["winners"]:
        out.append({
            "family": w["family"],
            "m": w["m"],                  # the table-key row count
            "d": w["d"],
            "precision": w["precision"],
            "backend": backend,
            "block_m": w["block_m"],
            "block_n": w["block_n"],
            "block_k": w["block_k"],
            "depth": w["depth"],
            "bound": w["bound"],
            "best_s": w["best_s"],
        })
    return out


def _entry_key(e: dict) -> tuple:
    return (e["family"], e["m"], e["d"], e["precision"], e["backend"])


def write_table(entries: List[dict], path=TUNED_TABLE_PATH, *,
                merge: bool = True) -> dict:
    """Merge ``entries`` into the committed table at ``path``.

    Same-key entries are replaced, everything else is preserved (so a
    quick sweep refreshes its cells without wiping a full sweep's, and a
    TPU sweep never clobbers the interpret rows). Entries are sorted by
    key so re-runs produce stable diffs.
    """
    path = Path(path)
    merged = {}
    if merge and path.exists():
        with open(path) as fh:
            for e in json.load(fh).get("entries", []):
                merged[_entry_key(e)] = e
    for e in entries:
        merged[_entry_key(e)] = e
    doc = {
        "version": 1,
        "generated_by": "benchmarks/autotune_kernels.py --update-table",
        "entries": [merged[k] for k in sorted(merged)],
    }
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=1)
        fh.write("\n")
    return doc
