"""Jit'd public wrapper for the tiled Gram kernel.

Pads inputs to tile multiples, dispatches to the Pallas kernel (interpret
mode on non-TPU backends so the same code path is exercised on CPU), and
slices the result back. Padding rows/features are zeros: they contribute 0
to dot products and norms, and padded outputs are discarded by the slice.

``precision`` ("f32" default, "bf16", "f16") casts the data tiles to the
low-precision dtype before the kernel — halving the streamed bytes — while
norms are computed in f32 from the rounded values and the dot products
accumulate in f32 on the MXU (see ``repro.kernels.precision``).

Tile sizes are owned by the autotune table: with ``tm``/``tn``/``tk``
left as ``None`` (the default) the launch config comes from
``kernels.tiling.resolve_tiles`` — the committed
``kernels/tuned_configs.json`` keyed on (family="gram", max(M, N), D,
precision, backend) with nearest-shape fallback to the fixed constants
(256, 256, 512). Passing any of them explicitly opts the call out of
the table; ``REPRO_NO_AUTOTUNE=1`` forces the constants everywhere
(docs/kernels.md).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.kernel_fn import KernelFn
from repro.kernels.gram.kernel import gram_pallas
from repro.kernels.precision import tile_dtype
# Re-exported for backward compatibility: these moved to kernels.tiling so
# sibling kernel families stop importing through this module (import-cycle
# hazard when repro.kernels is the first package imported).
from repro.kernels.tiling import (_auto_interpret, _pad_to,  # noqa: F401
                                  backend_name, resolve_tiles)


@partial(jax.jit, static_argnames=("kernel", "tm", "tn", "tk", "interpret",
                                   "precision"))
def gram(x, y, kernel: KernelFn, *, tm: int | None = None,
         tn: int | None = None, tk: int | None = None,
         interpret: bool | None = None, precision: str = "f32"):
    """K[i, j] = k(x_i, y_j) via the tiled Pallas kernel.

    Args:
      x: (M, D) f32 rows (any float dtype; cast to f32 then to the tile
        dtype). Padded internally to tile multiples.
      y: (N, D) rows, same feature dim as ``x``.
      kernel: ``repro.core.KernelFn`` ("rbf" / "linear" / "poly"); its
        name and scalars are static (one executable per kernel fn).
      tm, tn, tk: row / column / feature block sizes (multiples of 128).
        ``None`` (default) resolves from the autotune table; passing any
        opts out of the table (rest fall back to 256/256/512).
      interpret: force Pallas interpret mode on/off; ``None`` auto
        (on for non-TPU backends, overridable via ``REPRO_INTERPRET``).
      precision: tile-input stream dtype ("f32"/"bf16"/"f16").

    Returns:
      (M, N) f32 kernel matrix.
    """
    if interpret is None:
        interpret = _auto_interpret()
    cfg = resolve_tiles("gram", m=max(x.shape[0], y.shape[0]),
                        d=x.shape[1], precision=precision,
                        backend=backend_name(interpret),
                        block_m=tm, block_n=tn, block_k=tk)
    tm, tn, tk = cfg.block_m, cfg.block_n, cfg.block_k
    dt = tile_dtype(precision)
    M, N = x.shape[0], y.shape[0]
    x = _pad_to(_pad_to(x.astype(jnp.float32), tm, 0), tk, 1).astype(dt)
    y = _pad_to(_pad_to(y.astype(jnp.float32), tn, 0), tk, 1).astype(dt)
    # f32 norms of the *rounded* rows: keeps the RBF distance identity
    # exact for the values the MXU actually sees.
    xf = x.astype(jnp.float32)
    yf = y.astype(jnp.float32)
    xn = jnp.sum(xf * xf, axis=-1, keepdims=True)
    yn = jnp.sum(yf * yf, axis=-1, keepdims=True)
    out = gram_pallas(x, y, xn, yn, kind=kernel.name, gamma=kernel.gamma,
                      coef0=kernel.coef0, degree=kernel.degree,
                      tm=tm, tn=tn, tk=tk, interpret=interpret)
    return out[:M, :N]
