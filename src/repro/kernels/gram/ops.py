"""Jit'd public wrapper for the tiled Gram kernel.

Pads inputs to tile multiples, dispatches to the Pallas kernel (interpret
mode on non-TPU backends so the same code path is exercised on CPU), and
slices the result back. Padding rows/features are zeros: they contribute 0
to dot products and norms, and padded outputs are discarded by the slice.

``precision`` ("f32" default, "bf16", "f16") casts the data tiles to the
low-precision dtype before the kernel — halving the streamed bytes — while
norms are computed in f32 from the rounded values and the dot products
accumulate in f32 on the MXU (see ``repro.kernels.precision``).
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.kernel_fn import KernelFn
from repro.kernels.gram.kernel import gram_pallas
from repro.kernels.precision import tile_dtype


def _pad_to(a, mult, axis):
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def _auto_interpret() -> bool:
    """interpret-mode default: REPRO_INTERPRET env override, else backend.

    CI sets REPRO_INTERPRET=1 so the kernels-interpret job is deterministic
    regardless of which backend jax resolves. Read at trace time: flip the
    variable before the first kernel call of the process.
    """
    env = os.environ.get("REPRO_INTERPRET", "").strip().lower()
    if env in ("1", "true", "on"):
        return True
    if env in ("0", "false", "off"):
        return False
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("kernel", "tm", "tn", "tk", "interpret",
                                   "precision"))
def gram(x, y, kernel: KernelFn, *, tm: int = 256, tn: int = 256,
         tk: int = 512, interpret: bool | None = None,
         precision: str = "f32"):
    """K[i, j] = k(x_i, y_j) via the tiled Pallas kernel."""
    if interpret is None:
        interpret = _auto_interpret()
    dt = tile_dtype(precision)
    M, N = x.shape[0], y.shape[0]
    x = _pad_to(_pad_to(x.astype(jnp.float32), tm, 0), tk, 1).astype(dt)
    y = _pad_to(_pad_to(y.astype(jnp.float32), tn, 0), tk, 1).astype(dt)
    # f32 norms of the *rounded* rows: keeps the RBF distance identity
    # exact for the values the MXU actually sees.
    xf = x.astype(jnp.float32)
    yf = y.astype(jnp.float32)
    xn = jnp.sum(xf * xf, axis=-1, keepdims=True)
    yn = jnp.sum(yf * yf, axis=-1, keepdims=True)
    out = gram_pallas(x, y, xn, yn, kind=kernel.name, gamma=kernel.gamma,
                      coef0=kernel.coef0, degree=kernel.degree,
                      tm=tm, tn=tn, tk=tk, interpret=interpret)
    return out[:M, :N]
