"""Tiled Gram-matrix Pallas kernel: K = k(X, Y) block by block.

Grid: (m/TM, n/TN, d/TK), k innermost. The (TM, TN) output tile is revisited
across the k axis and accumulates X_tile @ Y_tile^T on the MXU
(f32 accumulation); the kernel-function epilogue (RBF exponential / poly
power) runs once on the last k step, on the VPU, while the tile is still in
VMEM — no second HBM pass.

VMEM per step ~ TM*TK + TN*TK + TM*TN floats; defaults (256, 256, 512) give
~0.9 MB, comfortably inside the ~16 MB/core v5e VMEM with double buffering.
All tile dims are multiples of 128 to keep MXU matmuls hardware-aligned.

Mixed precision: the x/y data tiles may arrive in bf16/f16 (ops.py casts
them once, halving the HBM stream); ``dot_general`` still accumulates via
``preferred_element_type=jnp.float32``, and the norm operands, accumulator
and epilogue are always f32 — only the streamed bytes shrink.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(xn_ref, yn_ref, x_ref, y_ref, out_ref, *, nk: int,
                 kind: str, gamma: float, coef0: float, degree: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]
    y = y_ref[...]
    out_ref[...] += jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        dot = out_ref[...]
        if kind == "rbf":
            sq = xn_ref[...] + yn_ref[...].T - 2.0 * dot
            out_ref[...] = jnp.exp(-gamma * jnp.maximum(sq, 0.0))
        elif kind == "poly":
            out_ref[...] = (gamma * dot + coef0) ** degree
        # linear: accumulated dot is already the answer.


def gram_pallas(x, y, xn, yn, *, kind: str, gamma: float, coef0: float,
                degree: int, tm: int = 256, tn: int = 256, tk: int = 512,
                interpret: bool = False):
    """x: (M, D), y: (N, D), xn/yn: (M,1)/(N,1) squared norms (RBF only).

    Shapes must already be padded to tile multiples (ops.py does that).
    """
    M, D = x.shape
    N, _ = y.shape
    nk = D // tk
    grid = (M // tm, N // tn, nk)
    kernel = functools.partial(_gram_kernel, nk=nk, kind=kind, gamma=gamma,
                               coef0=coef0, degree=degree)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((tn, 1), lambda i, j, k: (j, 0)),
            pl.BlockSpec((tm, tk), lambda i, j, k: (i, k)),
            pl.BlockSpec((tn, tk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(xn, yn, x, y)
