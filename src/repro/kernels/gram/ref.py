"""Pure-jnp oracle for the tiled Gram kernel."""
from __future__ import annotations

import jax.numpy as jnp


def gram_ref(x, y, *, kind: str, gamma: float = 1.0, coef0: float = 0.0,
             degree: int = 3):
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    dot = x @ y.T
    if kind == "linear":
        return dot
    if kind == "rbf":
        xx = jnp.sum(x * x, axis=-1, keepdims=True)
        yy = jnp.sum(y * y, axis=-1, keepdims=True)
        sq = xx + yy.T - 2.0 * dot
        return jnp.exp(-gamma * jnp.maximum(sq, 0.0))
    if kind == "poly":
        return (gamma * dot + coef0) ** degree
    raise ValueError(kind)
