"""Pure-jnp oracle for the tiled Gram kernel, dtype-parameterized.

``precision`` applies the same tile-input rounding the Pallas kernel's
low-precision stream sees (f32 -> bf16/f16 -> f32) and then computes
everything in f32 — dot products of two 16-bit-mantissa values are exact
in f32, so ref and kernel differ only by accumulation order.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.precision import round_to_tile


def gram_ref(x, y, *, kind: str, gamma: float = 1.0, coef0: float = 0.0,
             degree: int = 3, precision: str = "f32"):
    x = round_to_tile(x, precision)
    y = round_to_tile(y, precision)
    dot = x @ y.T
    if kind == "linear":
        return dot
    if kind == "rbf":
        xx = jnp.sum(x * x, axis=-1, keepdims=True)
        yy = jnp.sum(y * y, axis=-1, keepdims=True)
        sq = xx + yy.T - 2.0 * dot
        return jnp.exp(-gamma * jnp.maximum(sq, 0.0))
    if kind == "poly":
        return (gamma * dot + coef0) ** degree
    raise ValueError(kind)
