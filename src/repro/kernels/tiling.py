"""Shared tile plumbing for the Pallas kernel wrappers.

Lives in its own module (no ``repro.core`` dependency) so every kernel
family — and the engine providers that call them — can import these
helpers from any entry point without touching the
``repro.kernels <-> repro.core`` package boundary: importing
``repro.kernels`` first used to deadlock the partially-initialized
``gram.ops`` module when ``fupdate.ops`` pulled the helpers from it
mid-cycle.

Besides the padding/interpret helpers this module owns **trace-time
tile-config resolution**: each kernel wrapper (``gram/fupdate/decision
ops.py``) calls :func:`resolve_tiles` with its family, problem shape,
precision and backend, and gets back the block sizes to launch with.
Resolution precedence, highest first:

1. explicit ``tm=/tn=/tk=`` kwargs at the call site — passing ANY block
   kwarg opts the call out of the tuned table entirely (the remaining
   fields come from :data:`DEFAULT_CONFIGS`, never from the table, so a
   hand-steered launch is fully predictable);
2. ``REPRO_NO_AUTOTUNE=1`` in the environment — the escape hatch that
   forces :data:`DEFAULT_CONFIGS` everywhere (read at trace time, like
   ``REPRO_INTERPRET``: flip it before the first kernel call of the
   process);
3. the committed tuned table ``tuned_configs.json`` (written by
   ``benchmarks/autotune_kernels.py --update-table``), keyed on
   ``(family, m, d, precision, backend)`` with nearest-shape fallback
   (log-distance over (m, d), capped at :data:`NEAREST_MAX_DIST`);
4. :data:`DEFAULT_CONFIGS` — the pre-autotuner fixed constants.

Resolution happens at trace time (shapes are static under ``jit``), so
a table swap after a shape's first trace does NOT retrace it — the
compiled executable keeps the config it was traced with. Tests that
install a synthetic table (:func:`set_tuned_table`) therefore use fresh
shapes to force a retrace. See docs/kernels.md.
"""
from __future__ import annotations

import json
import math
import os
from dataclasses import dataclass, replace
from functools import lru_cache
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp

# MXU/VPU lane width: every block dimension must be a multiple of this.
LANE = 128

# In-flight buffer depths the autotuner may commit (double / quad
# buffering). Depth is consumed by the autotuner's VMEM-feasibility
# model and recorded in the table for the roofline rows; the Pallas
# pipeline itself is compiler-managed (double-buffered by default).
DEPTHS = (2, 4)

# Nearest-shape fallback cap: |log2(m/m')| + |log2(d/d')| beyond which a
# table entry is considered too far from the requested shape to trust.
NEAREST_MAX_DIST = 2.0

# The committed autotune table, produced by
# ``benchmarks/autotune_kernels.py --quick --update-table``.
TUNED_TABLE_PATH = Path(__file__).resolve().parent / "tuned_configs.json"


@dataclass(frozen=True)
class TileConfig:
    """Block sizes (and buffer depth) for one kernel launch.

    ``block_n`` / ``block_k`` are ``None`` where the family has no such
    axis (fupdate has no n-blocking — the selected block is resident;
    decision keeps the feature dim whole, so no k-blocking). ``source``
    records how the config was chosen: "default", "explicit",
    "table-exact" or "table-nearest".
    """

    block_m: int
    block_n: Optional[int]
    block_k: Optional[int]
    depth: int = 2
    source: str = "default"


# The pre-autotuner fixed constants, still the fallback everywhere the
# table has nothing to say. (gram: (tm, tn, tk); fupdate: (tm, -, tk);
# decision: (tm, tn, -).)
DEFAULT_CONFIGS = {
    "gram": TileConfig(256, 256, 512),
    "fupdate": TileConfig(512, None, 512),
    "decision": TileConfig(256, 512, None),
}
FAMILIES = tuple(DEFAULT_CONFIGS)


def _pad_to(a, mult, axis):
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def _auto_interpret() -> bool:
    """interpret-mode default: REPRO_INTERPRET env override, else backend.

    CI sets REPRO_INTERPRET=1 so the kernels-interpret job is deterministic
    regardless of which backend jax resolves. Read at trace time: flip the
    variable before the first kernel call of the process.
    """
    env = os.environ.get("REPRO_INTERPRET", "").strip().lower()
    if env in ("1", "true", "on"):
        return True
    if env in ("0", "false", "off"):
        return False
    return jax.default_backend() != "tpu"


def _no_autotune() -> bool:
    """REPRO_NO_AUTOTUNE=1 disables the tuned table (trace-time read)."""
    return os.environ.get("REPRO_NO_AUTOTUNE", "").strip().lower() in (
        "1", "true", "on")


def backend_name(interpret: bool) -> str:
    """The backend key a kernel launch tunes under.

    Interpret-mode launches are their own backend ("interpret"): an
    emulated sweep says nothing about MXU timings, so a table produced
    on CPU CI never leaks configs into real TPU launches — those miss
    the table (backend "tpu") and fall back to the defaults until a
    sweep is run on hardware.
    """
    return "interpret" if interpret else jax.default_backend()


# ---------------------------------------------------------------------------
# tuned-table loading + validation
# ---------------------------------------------------------------------------

_REQUIRED_ENTRY_KEYS = ("family", "m", "d", "precision", "backend",
                        "block_m", "depth")

# Test hook: a dict/path installed via set_tuned_table, or None for the
# committed TUNED_TABLE_PATH.
_table_override = None


def _validate_entry(e: dict) -> dict:
    if not all(k in e for k in _REQUIRED_ENTRY_KEYS):
        missing = [k for k in _REQUIRED_ENTRY_KEYS if k not in e]
        raise ValueError(f"tuned-table entry missing keys {missing}: {e}")
    fam = e["family"]
    if fam not in FAMILIES:
        raise ValueError(f"tuned-table entry has unknown family {fam!r} "
                         f"(expected one of {FAMILIES})")
    tmpl = DEFAULT_CONFIGS[fam]
    for key, applicable in (("block_m", True),
                            ("block_n", tmpl.block_n is not None),
                            ("block_k", tmpl.block_k is not None)):
        v = e.get(key)
        if not applicable:
            if v is not None:
                raise ValueError(
                    f"tuned-table entry sets {key}={v} but family {fam!r} "
                    f"has no such axis: {e}")
            continue
        if not isinstance(v, int) or v <= 0 or v % LANE:
            raise ValueError(
                f"tuned-table entry {key}={v!r} must be a positive "
                f"multiple of {LANE}: {e}")
    if e["depth"] not in DEPTHS:
        raise ValueError(f"tuned-table entry depth={e['depth']!r} not in "
                         f"{DEPTHS}: {e}")
    if int(e["m"]) <= 0 or int(e["d"]) <= 0:
        raise ValueError(f"tuned-table entry needs positive m/d: {e}")
    return e


def _entries_from_doc(doc: dict) -> tuple:
    if not isinstance(doc, dict) or "entries" not in doc:
        raise ValueError("tuned table must be a dict with an 'entries' list")
    return tuple(_validate_entry(dict(e)) for e in doc["entries"])


@lru_cache(maxsize=None)
def _load_table_file(path_str: str) -> tuple:
    with open(path_str) as fh:
        return _entries_from_doc(json.load(fh))


def set_tuned_table(table) -> None:
    """Install a tuned table for this process (test hook).

    ``table`` is a dict in the ``tuned_configs.json`` format, a path to
    one, or ``None`` to restore the committed table. Validation happens
    eagerly for dicts (a broken synthetic table fails here, not at the
    first kernel launch). NOTE: already-traced shapes keep the configs
    they were traced with — use fresh shapes after swapping the table.
    """
    global _table_override
    if isinstance(table, dict):
        _entries_from_doc(table)   # eager validation
    _table_override = table
    _load_table_file.cache_clear()


def _table_entries() -> tuple:
    src = _table_override
    if src is None:
        if not TUNED_TABLE_PATH.exists():
            return ()
        return _load_table_file(str(TUNED_TABLE_PATH))
    if isinstance(src, (str, Path)):
        return _load_table_file(str(src))
    return _entries_from_doc(src)


def lookup_tuned(family: str, m: int, d: int, precision: str,
                 backend: str) -> Optional[TileConfig]:
    """Exact (family, m, d, precision, backend) hit, else the nearest
    same-(family, precision, backend) entry by |log2 m ratio| +
    |log2 d ratio| within :data:`NEAREST_MAX_DIST`, else ``None``.
    """
    best = None
    best_dist = None
    for e in _table_entries():
        if (e["family"] != family or e["precision"] != precision
                or e["backend"] != backend):
            continue
        dist = (abs(math.log2(max(m, 1) / e["m"]))
                + abs(math.log2(max(d, 1) / e["d"])))
        if dist > NEAREST_MAX_DIST:
            continue
        # prefer smaller distance; on ties, the larger tuned m (closer
        # to the asymptotic regime)
        if (best is None or dist < best_dist
                or (dist == best_dist and e["m"] > best["m"])):
            best, best_dist = e, dist
    if best is None:
        return None
    return TileConfig(
        block_m=best["block_m"], block_n=best.get("block_n"),
        block_k=best.get("block_k"), depth=best["depth"],
        source="table-exact" if best_dist == 0.0 else "table-nearest")


def resolve_tiles(family: str, *, m: int, d: int, precision: str,
                  backend: str, block_m: Optional[int] = None,
                  block_n: Optional[int] = None,
                  block_k: Optional[int] = None) -> TileConfig:
    """Pick the launch config for one kernel call (trace time).

    ``m``/``d`` are the family's table key: the streamed-majority row
    count (gram: max(M, N); fupdate: the X rows; decision: the support
    rows) and the logical feature dim. ``block_*`` are the wrapper's
    explicit kwargs — any of them being set wins over the table (the
    unset rest come from :data:`DEFAULT_CONFIGS`). See the module
    docstring for the full precedence.
    """
    if family not in FAMILIES:
        raise ValueError(f"unknown kernel family {family!r}; "
                         f"expected one of {FAMILIES}")
    default = DEFAULT_CONFIGS[family]
    if block_m is not None or block_n is not None or block_k is not None:
        return replace(
            default,
            block_m=block_m if block_m is not None else default.block_m,
            block_n=block_n if block_n is not None else default.block_n,
            block_k=block_k if block_k is not None else default.block_k,
            source="explicit")
    if _no_autotune():
        return default
    tuned = lookup_tuned(family, m, d, precision, backend)
    return tuned if tuned is not None else default
