"""Shared tile plumbing for the Pallas kernel wrappers.

Lives in its own module (no ``repro.core`` dependency) so every kernel
family — and the engine providers that call them — can import these
helpers from any entry point without touching the
``repro.kernels <-> repro.core`` package boundary: importing
``repro.kernels`` first used to deadlock the partially-initialized
``gram.ops`` module when ``fupdate.ops`` pulled the helpers from it
mid-cycle.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp


def _pad_to(a, mult, axis):
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def _auto_interpret() -> bool:
    """interpret-mode default: REPRO_INTERPRET env override, else backend.

    CI sets REPRO_INTERPRET=1 so the kernels-interpret job is deterministic
    regardless of which backend jax resolves. Read at trace time: flip the
    variable before the first kernel call of the process.
    """
    env = os.environ.get("REPRO_INTERPRET", "").strip().lower()
    if env in ("1", "true", "on"):
        return True
    if env in ("0", "false", "off"):
        return False
    return jax.default_backend() != "tpu"
