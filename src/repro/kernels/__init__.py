"""Pallas TPU kernels for the paper's compute hot spots.

gram     — tiled Gram-matrix blocks (training-time kernel evaluations)
fupdate  — fused kernel-row evaluation + rank-2P f-cache update (SMO inner loop)
decision — batched slab decision function (serving hot path)

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper, interpret=True on CPU), ref.py (pure-jnp oracle).
"""
from repro.kernels.gram.ops import gram
from repro.kernels.fupdate.ops import fupdate
from repro.kernels.decision.ops import decision

__all__ = ["gram", "fupdate", "decision"]
