"""Pallas TPU kernels for the paper's compute hot spots.

gram     — tiled Gram-matrix blocks (training-time kernel evaluations)
fupdate  — fused kernel-row evaluation + rank-2P f-cache update (SMO inner loop)
decision — batched slab decision function (serving hot path)

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper, interpret=True on CPU), ref.py (pure-jnp oracle). Shared
policy lives beside them: ``tiling`` (padding, interpret detection, and
trace-time tile-config resolution from the committed autotune table
``tuned_configs.json``; ``REPRO_NO_AUTOTUNE=1`` opts out),
``precision`` (the "f32"/"bf16"/"f16" tile-stream knob) and
``autotune`` (the sweep that produces the table — imported by
``benchmarks/autotune_kernels.py``, deliberately not re-exported here).
See docs/kernels.md.
"""
from repro.kernels.gram.ops import gram
from repro.kernels.fupdate.ops import fupdate
from repro.kernels.decision.ops import decision

__all__ = ["gram", "fupdate", "decision"]
