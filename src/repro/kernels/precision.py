"""Mixed-precision policy for the Pallas kernel families.

The ``gram`` / ``fupdate`` / ``decision`` tiles are bytes-bound: every
operand streamed HBM->VMEM is f32 while the MXU natively consumes
bf16/f16 at twice the rate per byte. The ``precision`` knob halves the
tile *input* bytes without moving the math out of f32 anywhere it
matters:

* tile inputs (the data tiles that dominate HBM traffic) are cast to
  the low-precision dtype **once**, outside the kernel, so the stream
  itself is 16-bit;
* every dot product accumulates via
  ``preferred_element_type=jnp.float32`` (the MXU accumulator is f32);
* norms are computed in f32 **from the rounded values** — so the RBF
  distance ``||x||^2 + ||y||^2 - 2 x.y`` is the true squared distance
  of the rounded points and stays >= 0 up to f32 rounding;
* the epilogue (RBF exp, poly powers, the slab rho comparisons) and the
  f-cache / gamma / decision outputs stay f32.

``precision="f32"`` is the default and is a no-op cast: the compute
graph is bit-identical to the pre-knob kernels (tests assert it).

The product of two bf16 (8 mantissa bits) or f16 (11 bits) values is
exactly representable in f32 (<= 22 bits), so the only error sources
are the input rounding and the f32 accumulation order — which is why
the pure-jnp refs, parameterized on the same dtype round-trip, track
the Pallas kernels to tight per-dtype tolerances (``TOLERANCES``).
"""
from __future__ import annotations

import jax.numpy as jnp

# Public knob values, in "fastest-safe first" documentation order.
PRECISIONS = ("f32", "bf16", "f16")

_TILE_DTYPES = {
    "f32": jnp.float32,
    "bf16": jnp.bfloat16,
    "f16": jnp.float16,
}

# Documented low-precision-vs-f32-truth tolerances (the bound
# docs/serving.md advertises and the parity matrix asserts): ``rtol``
# element-wise, plus ``atol`` scaled by the OUTPUT magnitude
# (max |truth|, floored at 1) — dot products cancel, so the absolute
# error floor is set by the operand scale, not the result scale. bf16
# keeps ~2 significant digits (2^-8 ulp), f16 ~3 (2^-11); f32
# differences are accumulation-order only.
TOLERANCES = {
    "f32": dict(rtol=2e-4, atol=2e-4),
    "bf16": dict(rtol=4e-2, atol=2e-2),
    "f16": dict(rtol=6e-3, atol=3e-3),
}


def truth_tolerance(precision: str, truth) -> dict:
    """assert_allclose kwargs for comparing a ``precision`` output against
    f32 truth, with atol scaled to the output magnitude (see TOLERANCES)."""
    import numpy as np
    t = TOLERANCES[check_precision(precision)]
    scale = max(1.0, float(np.max(np.abs(np.asarray(truth, np.float32)))))
    return dict(rtol=t["rtol"], atol=t["atol"] * scale)


def check_precision(precision: str) -> str:
    if precision not in _TILE_DTYPES:
        raise ValueError(f"unknown precision {precision!r}; "
                         f"expected one of {PRECISIONS}")
    return precision


def parse_precisions(spec: str) -> tuple:
    """Parse a CLI comma list ("f32,bf16") into validated precisions.

    Empty/whitespace entries are dropped; an empty spec yields ("f32",)
    so benchmark flags always have a well-defined default.
    """
    out = tuple(check_precision(p.strip()) for p in spec.split(",")
                if p.strip())
    return out or ("f32",)


def tile_dtype(precision: str):
    """The dtype tile inputs are streamed in."""
    return _TILE_DTYPES[check_precision(precision)]


def round_to_tile(a, precision: str):
    """f32 -> tile dtype round-trip, back in f32.

    Used where a pure-jnp path (refs, non-Pallas providers) must see the
    same input rounding the Pallas tiles see. No-op for "f32".
    """
    if precision == "f32":
        return a.astype(jnp.float32)
    return a.astype(jnp.float32).astype(tile_dtype(precision)) \
            .astype(jnp.float32)
