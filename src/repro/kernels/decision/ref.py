"""Pure-jnp oracle for the slab decision kernel, dtype-parameterized."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.gram.ref import gram_ref


def decision_ref(q, t, gamma_vec, rho1, rho2, *, kind: str,
                 gamma: float = 1.0, coef0: float = 0.0, degree: int = 3,
                 precision: str = "f32"):
    s = gram_ref(q, t, kind=kind, gamma=gamma, coef0=coef0,
                 degree=degree,
                 precision=precision) @ gamma_vec.astype(jnp.float32)
    return (s - rho1) * (rho2 - s)
