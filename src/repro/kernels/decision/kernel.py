"""Slab decision-function Pallas kernel (the serving hot path).

For query tile Q (TM, D) and training tiles T_j (TN, D), accumulates
s = sum_j k(Q, T_j) @ gamma_j in VMEM scratch, then applies the slab rule
(s - rho1) * (rho2 - s) in the epilogue. One HBM pass over the support set
per query tile; D is kept resident (the OCSSVM feature dim is small —
d_model-sized at most after the head pooling).

Grid: (NQ/TM, M/TN), j innermost.

Mixed precision: the q / t data tiles may arrive in bf16/f16 (ops.py casts
queries per request; the support block is packed in the serving dtype once
at model-pack time); ``dot_general`` accumulates via
``preferred_element_type=jnp.float32`` and gamma, the norms, the VMEM
accumulator and the slab epilogue stay f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _decision_kernel(rho_ref, qn_ref, tn_ref, gamma_ref, q_ref, t_ref,
                     out_ref, acc_ref, *, nj: int, kind: str, gamma: float,
                     coef0: float, degree: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]          # (TM, D)
    t = t_ref[...]          # (TN, D)
    dot = jax.lax.dot_general(q, t, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    if kind == "rbf":
        sq = qn_ref[...] + tn_ref[...].T - 2.0 * dot
        krows = jnp.exp(-gamma * jnp.maximum(sq, 0.0))
    elif kind == "poly":
        krows = (gamma * dot + coef0) ** degree
    else:
        krows = dot
    acc_ref[...] += krows @ gamma_ref[...]

    @pl.when(j == nj - 1)
    def _epilogue():
        s = acc_ref[...]
        rho1 = rho_ref[0, 0]
        rho2 = rho_ref[0, 1]
        out_ref[...] = (s - rho1) * (rho2 - s)


def decision_pallas(q, t, gamma_vec, rho, qn, tn_, *, kind: str,
                    gamma: float, coef0: float, degree: int,
                    tm: int = 256, tn: int = 512, interpret: bool = False):
    """q: (NQ, D); t: (M, D); gamma_vec: (M, 1); rho: (1, 2);
    qn: (NQ, 1); tn_: (M, 1). Returns slab decision values (NQ, 1)."""
    NQ, D = q.shape
    M, _ = t.shape
    nj = M // tn
    grid = (NQ // tm, nj)
    kernel = functools.partial(_decision_kernel, nj=nj, kind=kind,
                               gamma=gamma, coef0=coef0, degree=degree)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 2), lambda i, j: (0, 0)),      # rho
            pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),     # qn
            pl.BlockSpec((tn, 1), lambda i, j: (j, 0)),     # tn
            pl.BlockSpec((tn, 1), lambda i, j: (j, 0)),     # gamma
            pl.BlockSpec((tm, D), lambda i, j: (i, 0)),     # q
            pl.BlockSpec((tn, D), lambda i, j: (j, 0)),     # t
        ],
        out_specs=pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((NQ, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((tm, 1), jnp.float32)],
        interpret=interpret,
    )(rho, qn, tn_, gamma_vec, q, t)
