"""Jit'd wrapper for the slab decision kernel.

``precision`` casts the query/support data tiles to bf16/f16 before the
kernel (the support set is the serving HBM bill); gamma, the norms, the
accumulator and the slab epilogue ``(s - rho1) * (rho2 - s)`` stay f32
(see ``repro.kernels.precision``). On the packed fast path the support
block is stored in the serving dtype once, at model-pack time.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.kernel_fn import KernelFn
from repro.kernels.tiling import _auto_interpret, _pad_to
from repro.kernels.decision.kernel import decision_pallas
from repro.kernels.precision import tile_dtype


@partial(jax.jit, static_argnames=("kernel", "tm", "tn", "interpret",
                                   "precision"))
def decision(q, t, gamma_vec, rho1, rho2, kernel: KernelFn, *,
             tm: int = 256, tn: int = 512, interpret: bool | None = None,
             precision: str = "f32"):
    """Slab decision values for queries q against support set (t, gamma).

    Padding: extra training rows get gamma = 0 (no contribution); extra
    query rows are sliced away; the feature dim is zero-padded (no effect
    on dot products or norms).
    """
    if interpret is None:
        interpret = _auto_interpret()
    dt = tile_dtype(precision)
    nq = q.shape[0]
    q = _pad_to(_pad_to(q.astype(jnp.float32), tm, 0), 128, 1).astype(dt)
    t = _pad_to(_pad_to(t.astype(jnp.float32), tn, 0), 128, 1).astype(dt)
    gv = _pad_to(gamma_vec.astype(jnp.float32)[:, None], tn, 0)
    qf = q.astype(jnp.float32)
    tf = t.astype(jnp.float32)
    qn = jnp.sum(qf * qf, axis=-1, keepdims=True)
    tn_ = jnp.sum(tf * tf, axis=-1, keepdims=True)
    rho = jnp.stack([jnp.asarray(rho1, jnp.float32),
                     jnp.asarray(rho2, jnp.float32)])[None, :]
    out = decision_pallas(q, t, gv, rho, qn, tn_, kind=kernel.name,
                          gamma=kernel.gamma, coef0=kernel.coef0,
                          degree=kernel.degree, tm=tm, tn=tn,
                          interpret=interpret)
    return out[:nq, 0]


@partial(jax.jit, static_argnames=("kernel", "tm", "tn", "interpret",
                                   "precision"))
def decision_packed(q_pad, t_pad, gamma_pad, t_norms, rho1, rho2,
                    kernel: KernelFn, *, tm: int = 256, tn: int = 512,
                    interpret: bool | None = None, precision: str = "f32"):
    """Decision values against a support set already packed to the tile grid.

    The serving fast path: ``t_pad`` (M_pad, d_pad), ``gamma_pad``
    (M_pad, 1) and ``t_norms`` (M_pad, 1) were padded/precomputed once at
    model-compaction time (gamma is zero on padding rows, so they
    contribute nothing), and the query block arrives pre-padded to a
    bucket shape — the per-request work is one cast + ||q||^2 reduction
    plus the kernel launch. ``t_pad`` is expected already in the serving
    tile dtype (``pack_model`` stores it that way; the cast here is a
    no-op then), ``t_norms`` is always f32 and was computed from the
    rounded rows. Returns all ``q_pad.shape[0]`` values; the caller
    slices its live rows.
    """
    if interpret is None:
        interpret = _auto_interpret()
    dt = tile_dtype(precision)
    if q_pad.shape[0] % tm or t_pad.shape[0] % tn or q_pad.shape[1] % 128:
        raise ValueError(
            f"decision_packed needs pre-padded operands: got q "
            f"{q_pad.shape} (rows % tm={tm}, features % 128) and t "
            f"{t_pad.shape} (rows % tn={tn})")
    if q_pad.shape[1] != t_pad.shape[1]:
        raise ValueError(f"feature-dim mismatch: q {q_pad.shape} vs "
                         f"t {t_pad.shape}")
    q_pad = q_pad.astype(jnp.float32).astype(dt)
    t_pad = t_pad.astype(dt)
    qf = q_pad.astype(jnp.float32)
    qn = jnp.sum(qf * qf, axis=-1, keepdims=True)
    rho = jnp.stack([jnp.asarray(rho1, jnp.float32),
                     jnp.asarray(rho2, jnp.float32)])[None, :]
    out = decision_pallas(q_pad, t_pad, gamma_pad, rho, qn, t_norms,
                          kind=kernel.name, gamma=kernel.gamma,
                          coef0=kernel.coef0, degree=kernel.degree,
                          tm=tm, tn=tn, interpret=interpret)
    return out[:, 0]
