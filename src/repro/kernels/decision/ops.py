"""Jit'd wrapper for the slab decision kernel.

``precision`` casts the query/support data tiles to bf16/f16 before the
kernel (the support set is the serving HBM bill); gamma, the norms, the
accumulator and the slab epilogue ``(s - rho1) * (rho2 - s)`` stay f32
(see ``repro.kernels.precision``). On the packed fast path the support
block is stored in the serving dtype once, at model-pack time.

Tile sizes: the convenience ``decision`` entry point resolves
``tm``/``tn`` from the autotune table when they are left ``None`` (the
committed ``kernels/tuned_configs.json``, keyed on (family="decision",
support rows, D, precision, backend), nearest-shape fallback to the
fixed constants (256, 512); ``REPRO_NO_AUTOTUNE=1`` or explicit kwargs
opt out — docs/kernels.md). ``decision_packed`` does NOT consult the
table: its tile geometry is baked into the packed operands at
model-pack time (``serve.model_cache.pack_model``) and the scorer
passes it explicitly — resolving it per launch could disagree with the
pack and reject the operands.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.kernel_fn import KernelFn
from repro.kernels.tiling import (_auto_interpret, _pad_to, backend_name,
                                  resolve_tiles)
from repro.kernels.decision.kernel import decision_pallas
from repro.kernels.precision import tile_dtype


@partial(jax.jit, static_argnames=("kernel", "tm", "tn", "interpret",
                                   "precision"))
def decision(q, t, gamma_vec, rho1, rho2, kernel: KernelFn, *,
             tm: int | None = None, tn: int | None = None,
             interpret: bool | None = None, precision: str = "f32"):
    """Slab decision values for queries q against support set (t, gamma).

    Args:
      q: (NQ, D) query rows; padded internally to tile multiples (extra
        query rows are sliced away).
      t: (M, D) support rows; extra rows get gamma = 0 (no contribution).
        The feature dim is zero-padded to a lane multiple (no effect on
        dot products or norms).
      gamma_vec: (M,) f32 dual coefficients.
      rho1, rho2: slab offsets (scalars, f32).
      kernel: ``repro.core.KernelFn``; name/scalars static.
      tm, tn: query / support block sizes (multiples of 128). ``None``
        (default) resolves from the autotune table; passing either opts
        out of the table (rest fall back to 256/512). The feature dim is
        kept whole (no k-blocking) — OCSSVM feature dims are small.
      interpret: force Pallas interpret mode; ``None`` auto-detects.
      precision: tile-input stream dtype ("f32"/"bf16"/"f16").

    Returns:
      (NQ,) f32 slab decision values ``(s - rho1) * (rho2 - s)``.
    """
    if interpret is None:
        interpret = _auto_interpret()
    cfg = resolve_tiles("decision", m=t.shape[0], d=t.shape[1],
                        precision=precision,
                        backend=backend_name(interpret),
                        block_m=tm, block_n=tn)
    tm, tn = cfg.block_m, cfg.block_n
    dt = tile_dtype(precision)
    nq = q.shape[0]
    q = _pad_to(_pad_to(q.astype(jnp.float32), tm, 0), 128, 1).astype(dt)
    t = _pad_to(_pad_to(t.astype(jnp.float32), tn, 0), 128, 1).astype(dt)
    gv = _pad_to(gamma_vec.astype(jnp.float32)[:, None], tn, 0)
    qf = q.astype(jnp.float32)
    tf = t.astype(jnp.float32)
    qn = jnp.sum(qf * qf, axis=-1, keepdims=True)
    tn_ = jnp.sum(tf * tf, axis=-1, keepdims=True)
    rho = jnp.stack([jnp.asarray(rho1, jnp.float32),
                     jnp.asarray(rho2, jnp.float32)])[None, :]
    out = decision_pallas(q, t, gv, rho, qn, tn_, kind=kernel.name,
                          gamma=kernel.gamma, coef0=kernel.coef0,
                          degree=kernel.degree, tm=tm, tn=tn,
                          interpret=interpret)
    return out[:nq, 0]


@partial(jax.jit, static_argnames=("kernel", "tm", "tn", "interpret",
                                   "precision"))
def decision_packed(q_pad, t_pad, gamma_pad, t_norms, rho1, rho2,
                    kernel: KernelFn, *, tm: int = 256, tn: int = 512,
                    interpret: bool | None = None, precision: str = "f32"):
    """Decision values against a support set already packed to the tile grid.

    The serving fast path: ``t_pad`` (M_pad, d_pad), ``gamma_pad``
    (M_pad, 1) and ``t_norms`` (M_pad, 1) were padded/precomputed once at
    model-compaction time (gamma is zero on padding rows, so they
    contribute nothing), and the query block arrives pre-padded to a
    bucket shape — the per-request work is one cast + ||q||^2 reduction
    plus the kernel launch. ``t_pad`` is expected already in the serving
    tile dtype (``pack_model`` stores it that way; the cast here is a
    no-op then), ``t_norms`` is always f32 and was computed from the
    rounded rows. Returns all ``q_pad.shape[0]`` values; the caller
    slices its live rows.

    ``tm``/``tn`` here are part of the pack geometry (``pack_model``'s
    ``tn``, the scorer's bucket ``tm``) and are always passed
    explicitly by the serving stack — the autotune table is not
    consulted (see the module docstring).
    """
    if interpret is None:
        interpret = _auto_interpret()
    dt = tile_dtype(precision)
    if q_pad.shape[0] % tm or t_pad.shape[0] % tn or q_pad.shape[1] % 128:
        raise ValueError(
            f"decision_packed needs pre-padded operands: got q "
            f"{q_pad.shape} (rows % tm={tm}, features % 128) and t "
            f"{t_pad.shape} (rows % tn={tn})")
    if q_pad.shape[1] != t_pad.shape[1]:
        raise ValueError(f"feature-dim mismatch: q {q_pad.shape} vs "
                         f"t {t_pad.shape}")
    q_pad = q_pad.astype(jnp.float32).astype(dt)
    t_pad = t_pad.astype(dt)
    qf = q_pad.astype(jnp.float32)
    qn = jnp.sum(qf * qf, axis=-1, keepdims=True)
    rho = jnp.stack([jnp.asarray(rho1, jnp.float32),
                     jnp.asarray(rho2, jnp.float32)])[None, :]
    out = decision_pallas(q_pad, t_pad, gamma_pad, rho, qn, t_norms,
                          kind=kernel.name, gamma=kernel.gamma,
                          coef0=kernel.coef0, degree=kernel.degree,
                          tm=tm, tn=tn, interpret=interpret)
    return out[:, 0]
