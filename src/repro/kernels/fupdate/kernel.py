"""Fused SMO f-cache update Pallas kernel.

Computes   f_new = f + k(X, X_sel) @ delta   in ONE pass over X:
the 2P selected rows and the delta vector live in VMEM for the whole grid;
each (TM, TK) tile of X streams HBM->VMEM once, accumulates the partial
dot X_tile @ X_sel_tile^T into a (TM, 2P) VMEM scratch, and on the last k
step applies the kernel epilogue + the rank-2P matvec into f.

This is the TPU-native replacement for the paper's per-row Gram cache: at
2d FLOPs per d*4 streamed bytes *per selected column*, a 2P = 16..64 block
turns the memory-bound AXPY of scalar SMO into an MXU matmul.

Grid: (M/TM, D/TK), k innermost. VMEM: TM*TK + 2P*TK + TM*2P + TM floats.

Mixed precision: the x / x_sel data tiles may arrive in bf16/f16 (ops.py
casts them once — the X stream is the whole per-iteration HBM bill);
``dot_general`` accumulates via ``preferred_element_type=jnp.float32`` and
the norms, delta/f operands, scratch accumulator and epilogue stay f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fupdate_kernel(xn_ref, seln_ref, delta_ref, f_ref, x_ref, xsel_ref,
                    out_ref, acc_ref, *, nk: int, kind: str, gamma: float,
                    coef0: float, degree: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]          # (TM, TK)
    xs = xsel_ref[...]      # (2P, TK)
    acc_ref[...] += jax.lax.dot_general(
        x, xs, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        dot = acc_ref[...]                          # (TM, 2P)
        if kind == "rbf":
            sq = xn_ref[...] + seln_ref[...].T - 2.0 * dot
            krows = jnp.exp(-gamma * jnp.maximum(sq, 0.0))
        elif kind == "poly":
            krows = (gamma * dot + coef0) ** degree
        else:
            krows = dot
        out_ref[...] = f_ref[...] + krows @ delta_ref[...]


def fupdate_pallas(x, xsel, delta, f, xn, seln, *, kind: str, gamma: float,
                   coef0: float, degree: int, tm: int = 512, tk: int = 512,
                   interpret: bool = False):
    """x: (M, D); xsel: (S, D); delta: (S, 1); f, xn: (M, 1); seln: (S, 1).

    Returns f + k(x, xsel) @ delta, shape (M, 1). Shapes pre-padded.
    """
    M, D = x.shape
    S, _ = xsel.shape
    nk = D // tk
    grid = (M // tm, nk)
    kernel = functools.partial(_fupdate_kernel, nk=nk, kind=kind,
                               gamma=gamma, coef0=coef0, degree=degree)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, 1), lambda i, k: (i, 0)),    # xn
            pl.BlockSpec((S, 1), lambda i, k: (0, 0)),     # seln
            pl.BlockSpec((S, 1), lambda i, k: (0, 0)),     # delta
            pl.BlockSpec((tm, 1), lambda i, k: (i, 0)),    # f
            pl.BlockSpec((tm, tk), lambda i, k: (i, k)),   # x
            pl.BlockSpec((S, tk), lambda i, k: (0, k)),    # xsel
        ],
        out_specs=pl.BlockSpec((tm, 1), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((tm, S), jnp.float32)],
        interpret=interpret,
    )(xn, seln, delta, f, x, xsel)
