"""Jit'd wrapper for the fused SMO f-cache update.

``precision`` casts the streamed data tiles (x and the selected block) to
bf16/f16; the delta/f operands, norms and the rank-2P matvec epilogue stay
f32 (see ``repro.kernels.precision``).

Tile sizes are owned by the autotune table: with ``tm``/``tk`` left as
``None`` (the default) the launch config comes from
``kernels.tiling.resolve_tiles`` — the committed
``kernels/tuned_configs.json`` keyed on (family="fupdate", M, D,
precision, backend) with nearest-shape fallback to the fixed constants
(512, 512). Passing either explicitly opts the call out of the table;
``REPRO_NO_AUTOTUNE=1`` forces the constants everywhere
(docs/kernels.md).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.kernel_fn import KernelFn
from repro.kernels.tiling import (_auto_interpret, _pad_to, backend_name,
                                  resolve_tiles)
from repro.kernels.fupdate.kernel import fupdate_pallas
from repro.kernels.precision import tile_dtype


@partial(jax.jit, static_argnames=("kernel", "tm", "tk", "interpret",
                                   "precision"))
def fupdate(x, xsel, delta, f, kernel: KernelFn, *, tm: int | None = None,
            tk: int | None = None, interpret: bool | None = None,
            precision: str = "f32"):
    """f + k(x, xsel) @ delta — the SMO hot-loop rank-2P update, fused.

    Args:
      x: (m, d) training rows (streamed once per call — the per-iteration
        HBM bill).
      xsel: (s, d) the selected pair block; padded internally to a lane
        multiple (128) with zero rows.
      delta: (s,) dual step; padded deltas are zero, so padding never
        perturbs f (asserted bitwise by tests).
      f: (m,) f32 score cache.
      kernel: ``repro.core.KernelFn``; name/scalars static.
      tm, tk: row / feature block sizes (multiples of 128). ``None``
        (default) resolves from the autotune table; passing either opts
        out of the table (rest fall back to 512/512). The selected block
        has no n-blocking — it is VMEM-resident for the whole grid.
      interpret: force Pallas interpret mode; ``None`` auto-detects.
      precision: tile-input stream dtype ("f32"/"bf16"/"f16").

    Returns:
      (m,) f32 updated score cache.
    """
    if interpret is None:
        interpret = _auto_interpret()
    cfg = resolve_tiles("fupdate", m=x.shape[0], d=x.shape[1],
                        precision=precision,
                        backend=backend_name(interpret),
                        block_m=tm, block_k=tk)
    tm, tk = cfg.block_m, cfg.block_k
    dt = tile_dtype(precision)
    m = x.shape[0]
    x = _pad_to(_pad_to(x.astype(jnp.float32), tm, 0), tk, 1).astype(dt)
    xsel = _pad_to(_pad_to(xsel.astype(jnp.float32), 128, 0),
                   tk, 1).astype(dt)
    s = xsel.shape[0]
    delta = _pad_to(delta.astype(jnp.float32)[:, None], 128, 0)
    f2 = _pad_to(f.astype(jnp.float32)[:, None], tm, 0)
    xf = x.astype(jnp.float32)
    xsf = xsel.astype(jnp.float32)
    xn = jnp.sum(xf * xf, axis=-1, keepdims=True)
    seln = jnp.sum(xsf * xsf, axis=-1, keepdims=True)
    out = fupdate_pallas(x, xsel, delta, f2, xn, seln, kind=kernel.name,
                         gamma=kernel.gamma, coef0=kernel.coef0,
                         degree=kernel.degree, tm=tm, tk=tk,
                         interpret=interpret)
    return out[:m, 0]
