"""Jit'd wrapper for the fused SMO f-cache update.

``precision`` casts the streamed data tiles (x and the selected block) to
bf16/f16; the delta/f operands, norms and the rank-2P matvec epilogue stay
f32 (see ``repro.kernels.precision``).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.kernel_fn import KernelFn
from repro.kernels.tiling import _auto_interpret, _pad_to
from repro.kernels.fupdate.kernel import fupdate_pallas
from repro.kernels.precision import tile_dtype


@partial(jax.jit, static_argnames=("kernel", "tm", "tk", "interpret",
                                   "precision"))
def fupdate(x, xsel, delta, f, kernel: KernelFn, *, tm: int = 512,
            tk: int = 512, interpret: bool | None = None,
            precision: str = "f32"):
    """f + k(x, xsel) @ delta.

    x: (m, d) training rows, xsel: (s, d) the selected pair block,
    delta: (s,) dual step, f: (m,) score cache. The selected-block axis is
    padded to a lane multiple (128); padded deltas are zero so they do not
    perturb f.
    """
    if interpret is None:
        interpret = _auto_interpret()
    dt = tile_dtype(precision)
    m = x.shape[0]
    x = _pad_to(_pad_to(x.astype(jnp.float32), tm, 0), tk, 1).astype(dt)
    xsel = _pad_to(_pad_to(xsel.astype(jnp.float32), 128, 0),
                   tk, 1).astype(dt)
    s = xsel.shape[0]
    delta = _pad_to(delta.astype(jnp.float32)[:, None], 128, 0)
    f2 = _pad_to(f.astype(jnp.float32)[:, None], tm, 0)
    xf = x.astype(jnp.float32)
    xsf = xsel.astype(jnp.float32)
    xn = jnp.sum(xf * xf, axis=-1, keepdims=True)
    seln = jnp.sum(xsf * xsf, axis=-1, keepdims=True)
    out = fupdate_pallas(x, xsel, delta, f2, xn, seln, kind=kernel.name,
                         gamma=kernel.gamma, coef0=kernel.coef0,
                         degree=kernel.degree, tm=tm, tk=tk,
                         interpret=interpret)
    return out[:m, 0]
