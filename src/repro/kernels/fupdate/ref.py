"""Pure-jnp oracle for the fused f-cache update, dtype-parameterized."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.gram.ref import gram_ref


def fupdate_ref(x, xsel, delta, f, *, kind: str, gamma: float = 1.0,
                coef0: float = 0.0, degree: int = 3,
                precision: str = "f32"):
    krows = gram_ref(x, xsel, kind=kind, gamma=gamma, coef0=coef0,
                     degree=degree, precision=precision)
    return f.astype(jnp.float32) + krows @ delta.astype(jnp.float32)
