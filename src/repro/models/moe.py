"""Top-k MoE with sort-based capacity dispatch (GShard semantics,
shape-static, expert-parallel-shardable).

Dispatch pipeline (all static shapes, no ragged ops):

1. router top-k -> (T*k,) flat expert ids + gates,
2. stable argsort by expert id; position-within-expert via running counts,
3. tokens beyond the per-expert capacity C = ceil(T*k*cf / E) are dropped
   (GShard capacity rule),
4. scatter tokens into the (E, C, d) dispatch buffer, run the batched
   expert FFN einsum (experts sharded over the "model" mesh axis => EP;
   GSPMD inserts the all-to-alls at the (T,d)->(E,C,d) boundary),
5. gather + gate-weighted scatter-add back to (T, d).

FLOPs scale with T*k*cf — the *active* parameter count — so roofline terms
stay honest for 128-expert models (a dense all-experts evaluation would
inflate compute 64x on arctic-480b).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.utils.compat import shard_map

Array = jax.Array


def moe_init(key: Array, d_model: int, n_experts: int, ff: int,
             mlp_type: str, dtype) -> dict:
    kr, k1, k2, k3 = jax.random.split(key, 4)
    s_in = d_model ** -0.5
    s_ff = ff ** -0.5
    p = {
        "router": dense_init(kr, (d_model, n_experts), jnp.float32),
        "w1": (jax.random.normal(k1, (n_experts, d_model, ff), jnp.float32)
               * s_in).astype(dtype),
        "w2": (jax.random.normal(k2, (n_experts, ff, d_model), jnp.float32)
               * s_ff).astype(dtype),
    }
    if mlp_type in ("swiglu", "geglu"):
        p["w3"] = (jax.random.normal(k3, (n_experts, d_model, ff),
                                     jnp.float32) * s_in).astype(dtype)
    return p


def _expert_ffn(params: dict, x: Array, mlp_type: str) -> Array:
    """x: (E, C, d) -> (E, C, d), batched over experts."""
    h1 = jnp.einsum("ecd,edf->ecf", x, params["w1"])
    if mlp_type == "swiglu":
        h = jax.nn.silu(h1) * jnp.einsum("ecd,edf->ecf", x, params["w3"])
    elif mlp_type == "geglu":
        h = jax.nn.gelu(h1) * jnp.einsum("ecd,edf->ecf", x, params["w3"])
    elif mlp_type == "relu2":
        h = jnp.square(jax.nn.relu(h1))
    else:
        raise ValueError(mlp_type)
    return jnp.einsum("ecf,efd->ecd", h, params["w2"])


def moe_forward(params: dict, x: Array, *, n_experts: int, top_k: int,
                capacity_factor: float, mlp_type: str,
                router_jitter: bool = False, impl: str = "psum",
                constrain=lambda x, kind: x) -> Tuple[Array, Array]:
    """x: (B, S, d). Returns (output, aux_loss).

    When the constrain hook carries a mesh (production path), dispatch runs
    under an explicit shard_map: impl="a2a" moves tokens to data-sharded
    experts (weights never move); impl="psum" keeps experts model-sharded
    with ZeRO'd weights and an EP-combine psum. Without a mesh (unit
    tests, single device) the global dense path below runs instead.
    """
    ctx = getattr(constrain, "shard_ctx", None)
    if ctx is not None:
        if impl == "a2a":
            mesh = ctx["mesh"]
            dp = 1
            for ax in ctx["data_axes"]:
                dp *= mesh.shape[ax]
            ff = params["w1"].shape[-1]
            if n_experts % dp == 0 and ff % mesh.shape["model"] == 0:
                return _moe_forward_a2a(
                    params, x, ctx, n_experts=n_experts, top_k=top_k,
                    capacity_factor=capacity_factor, mlp_type=mlp_type)
        return _moe_forward_sharded(params, x, ctx, n_experts=n_experts,
                                    top_k=top_k,
                                    capacity_factor=capacity_factor,
                                    mlp_type=mlp_type)
    b, s, d = x.shape
    T = b * s
    xf = constrain(x.reshape(T, d), "moe_tokens")
    E, K = n_experts, top_k
    C = max(1, int((T * K * capacity_factor) / E + 0.999))

    logits = (xf.astype(jnp.float32) @ params["router"])        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)             # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)

    # Load-balancing aux loss (Switch-style).
    me = probs.mean(axis=0)                                     # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[expert_ids.reshape(-1)].add(
        1.0 / (T * K))
    aux = E * jnp.sum(me * ce)

    flat_expert = expert_ids.reshape(-1)                        # (T*K,)
    flat_gate = gate_vals.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(T), K)

    order = jnp.argsort(flat_expert, stable=True)
    se = flat_expert[order]
    st = flat_token[order]
    sg = flat_gate[order]

    counts = jnp.bincount(flat_expert, length=E)                # (E,)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * K) - starts[se]                        # rank in expert
    keep = pos < C
    slot = jnp.where(keep, se * C + pos, 0)

    # Dispatch: (E*C, d) buffer; each kept slot receives exactly one token.
    # The (E, C, d) buffers are constrained to the EP layout (experts over
    # "model", capacity over the data axes) — without this GSPMD leaves
    # them replicated and a 128-expert layer eats tens of GB per device.
    xb = jnp.where(keep[:, None], xf[st], 0.0)
    xdisp = jnp.zeros((E * C, d), x.dtype).at[slot].add(
        xb.astype(x.dtype), mode="drop")
    xdisp = constrain(xdisp.reshape(E, C, d), "moe_dispatch")

    yexp = _expert_ffn(params, xdisp, mlp_type)
    yexp = constrain(yexp, "moe_dispatch").reshape(E * C, d)

    # Combine: gather each kept token's expert output, gate, scatter-add.
    contrib = yexp[slot] * (sg[:, None] * keep[:, None]).astype(x.dtype)
    y = jnp.zeros((T, d), x.dtype).at[st].add(contrib, mode="drop")
    y = constrain(y, "moe_tokens")
    return y.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# production path: explicit shard_map dispatch
# ---------------------------------------------------------------------------

def _local_dispatch_compute(xf, router, w1, w2, w3, *, E: int, top_k: int,
                            C_loc: int, mlp_type: str, e0, e_loc: int):
    """Device-local token-choice dispatch + expert FFN for experts
    [e0, e0+e_loc). xf: (T_loc, d); weights already gathered/local.
    Returns (partial y (T_loc, d), aux-loss numerator pieces)."""
    T_loc, d = xf.shape
    K = top_k
    logits = xf.astype(jnp.float32) @ router
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)

    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[expert_ids.reshape(-1)].add(
        1.0 / (T_loc * K))
    aux = E * jnp.sum(me * ce)

    flat_expert = expert_ids.reshape(-1)
    flat_gate = gate_vals.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(T_loc), K)
    order = jnp.argsort(flat_expert, stable=True)
    se, st, sg = flat_expert[order], flat_token[order], flat_gate[order]
    counts = jnp.bincount(flat_expert, length=E)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T_loc * K) - starts[se]
    keep = pos < C_loc
    slot = jnp.where(keep, se * C_loc + pos, 0)

    xb = jnp.where(keep[:, None], xf[st], 0.0)
    xdisp = jnp.zeros((E * C_loc, d), xf.dtype).at[slot].add(
        xb.astype(xf.dtype), mode="drop").reshape(E, C_loc, d)
    # Each model shard computes only its expert slice (EP) — or all
    # experts on a TP-on-ff slice (expert-TP when E < model axis).
    xslice = jax.lax.dynamic_slice_in_dim(xdisp, e0, e_loc, axis=0) \
        if e_loc != E else xdisp

    p = {"w1": w1, "w2": w2}
    if w3 is not None:
        p["w3"] = w3
    yexp = _expert_ffn(p, xslice, mlp_type)               # (e_loc, C_loc, d)
    if e_loc != E:
        pad = ((0, 0),) * 0
        yfull = jnp.zeros((E, C_loc, d), yexp.dtype)
        yfull = jax.lax.dynamic_update_slice_in_dim(yfull, yexp, e0, axis=0)
    else:
        yfull = yexp
    yflat = yfull.reshape(E * C_loc, d)
    contrib = yflat[slot] * (sg[:, None] * keep[:, None]).astype(xf.dtype)
    y = jnp.zeros((T_loc, d), xf.dtype).at[st].add(contrib, mode="drop")
    return y, aux


def _moe_forward_a2a(params: dict, x: Array, ctx, *, n_experts: int,
                     top_k: int, capacity_factor: float,
                     mlp_type: str) -> Tuple[Array, Array]:
    """Canonical expert parallelism: experts sharded over the DATA axis,
    tokens moved to experts with all-to-all, expert-ff TP over "model".

    Weight layout (w1: P("data", None, "model")) is fully 256-way sharded
    and never gathered — per layer the only comms are two (E, C_loc, d)
    all-to-alls (~T_loc*k*cf tokens) plus one TP psum of the same size.
    Replaces the psum-mode's per-microbatch ZeRO-3 expert-weight
    all-gathers, which dominated arctic-480b training at 58 GB/device
    per pass (hillclimb 2, EXPERIMENTS.md §Perf).
    """
    from jax.sharding import PartitionSpec as P

    mesh = ctx["mesh"]
    baxes = ctx["data_axes"]
    model_size = mesh.shape["model"]
    dp = 1
    for ax in baxes:
        dp *= mesh.shape[ax]

    b, s, d = x.shape
    E, K = n_experts, top_k
    e_loc = E // dp
    batch_shardable = b % dp == 0
    T_loc = (b // dp if batch_shardable else b) * s
    if T_loc * K <= 4096:
        C_loc = T_loc * K
    else:
        C_loc = max(1, int(T_loc * K * capacity_factor / E + 0.999))

    gated = mlp_type in ("swiglu", "geglu")
    xspec = P(baxes if batch_shardable else None, None, None)
    wspec = P(baxes, None, "model")     # (E, d, ff)
    w2spec = P(baxes, "model", None)    # (E, ff, d)
    has_w3 = "w3" in params

    def local_fn(xl, router, *ws):
        w1, w2 = ws[0], ws[1]
        w3 = ws[2] if has_w3 else None
        tb, ts, _ = xl.shape
        xf = xl.reshape(tb * ts, d)

        # Local routing + dispatch into (E, C_loc, d) — all experts.
        logits = xf.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = jax.lax.top_k(probs, K)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(axis=-1, keepdims=True), 1e-9)
        me = probs.mean(axis=0)
        ce = jnp.zeros((E,), jnp.float32).at[expert_ids.reshape(-1)].add(
            1.0 / (T_loc * K))
        aux = E * jnp.sum(me * ce)

        flat_expert = expert_ids.reshape(-1)
        flat_gate = gate_vals.reshape(-1)
        flat_token = jnp.repeat(jnp.arange(T_loc), K)
        order = jnp.argsort(flat_expert, stable=True)
        se, st, sg = flat_expert[order], flat_token[order], flat_gate[order]
        counts = jnp.bincount(flat_expert, length=E)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(T_loc * K) - starts[se]
        keep = pos < C_loc
        slot = jnp.where(keep, se * C_loc + pos, 0)
        xb = jnp.where(keep[:, None], xf[st], 0.0)
        xdisp = jnp.zeros((E * C_loc, d), xf.dtype).at[slot].add(
            xb.astype(xf.dtype), mode="drop").reshape(E, C_loc, d)

        # Tokens -> expert owners (dp groups of e_loc experts each).
        xexp = jax.lax.all_to_all(xdisp, baxes, split_axis=0,
                                  concat_axis=1, tiled=True)
        # (e_loc, C_loc * dp, d): this shard's experts, everyone's tokens.
        h1 = jnp.einsum("ecd,edf->ecf", xexp, w1)
        if mlp_type == "swiglu":
            h = jax.nn.silu(h1) * jnp.einsum("ecd,edf->ecf", xexp, w3)
        elif mlp_type == "geglu":
            h = jax.nn.gelu(h1) * jnp.einsum("ecd,edf->ecf", xexp, w3)
        elif mlp_type == "relu2":
            h = jnp.square(jax.nn.relu(h1))
        else:
            h = jax.nn.gelu(h1)
        ypart = jnp.einsum("ecf,efd->ecd", h, w2).astype(xf.dtype)
        yexp = jax.lax.psum(ypart, "model")          # ff-TP combine (bf16)

        # Results -> token owners (reverse all-to-all).
        ylocal = jax.lax.all_to_all(yexp, baxes, split_axis=1,
                                    concat_axis=0, tiled=True)
        yflat = ylocal.reshape(E * C_loc, d)
        contrib = yflat[slot] * (sg[:, None] * keep[:, None]).astype(xf.dtype)
        y = jnp.zeros((T_loc, d), xf.dtype).at[st].add(contrib, mode="drop")
        aux = jax.lax.pmean(aux, baxes + ("model",))
        return y.reshape(tb, ts, d), aux

    w_in = [params["w1"], params["w2"]]
    w_specs = [wspec, w2spec]
    if has_w3:
        w_in.append(params["w3"])
        w_specs.append(wspec)
    out, aux = shard_map(
        local_fn, mesh=mesh,
        in_specs=(xspec, P(None, None), *w_specs),
        out_specs=(xspec, P()),
        check_vma=False,
    )(x, params["router"], *w_in)
    return out, aux


def _moe_forward_sharded(params: dict, x: Array, ctx, *, n_experts: int,
                         top_k: int, capacity_factor: float,
                         mlp_type: str) -> Tuple[Array, Array]:
    from jax.sharding import PartitionSpec as P

    mesh = ctx["mesh"]
    baxes = ctx["data_axes"]
    fsdp = ctx["fsdp"]
    model_size = mesh.shape["model"]
    dp = 1
    for ax in baxes:
        dp *= mesh.shape[ax]

    b, s, d = x.shape
    E, K = n_experts, top_k
    ep = E % model_size == 0                 # expert-parallel vs expert-TP
    e_loc = E // model_size if ep else E
    batch_shardable = b % dp == 0
    T_loc = (b // dp if batch_shardable else b) * s
    if T_loc * K <= 4096:
        C_loc = T_loc * K                    # dropless (decode/serving)
    else:
        C_loc = max(1, int(T_loc * K * capacity_factor / E + 0.999))

    gated = mlp_type in ("swiglu", "geglu")
    xspec = P(baxes if batch_shardable else None, None, None)
    # weight specs must mirror sharding/specs.py rules
    if ep:
        wspec = (P("model", "data", None) if fsdp
                 else P("model", None, None))
        w2spec = (P("model", None, "data") if fsdp
                  else P("model", None, None))
    else:
        wspec = P(None, "data" if fsdp else None, "model")
        w2spec = P(None, "model", "data" if fsdp else None)

    has_w3 = "w3" in params

    def local_fn(xl, router, *ws):
        w1, w2 = ws[0], ws[1]
        w3 = ws[2] if has_w3 else None
        tb, ts, _ = xl.shape
        xf = xl.reshape(tb * ts, d)
        if fsdp:
            # ZeRO-3: un-shard the weights' FSDP axis at use.
            w1 = jax.lax.all_gather(w1, "data", axis=1, tiled=True)
            if w3 is not None:
                w3 = jax.lax.all_gather(w3, "data", axis=1, tiled=True)
            w2 = jax.lax.all_gather(w2, "data", axis=2, tiled=True)
        e0 = jax.lax.axis_index("model") * e_loc if ep else 0
        y, aux = _local_dispatch_compute(
            xf, router, w1, w2, w3, E=E, top_k=K, C_loc=C_loc,
            mlp_type=mlp_type, e0=e0, e_loc=e_loc)
        # EP combine: each token's expert lives on one model shard (EP) or
        # every shard holds a partial-ff sum (expert-TP) — psum either way.
        y = jax.lax.psum(y, "model")
        aux = jax.lax.pmean(aux, baxes + ("model",))
        return y.reshape(tb, ts, d), aux

    w_in = [params["w1"], params["w2"]]
    w_specs = [wspec, w2spec]
    if has_w3:
        w_in.append(params["w3"])
        w_specs.append(wspec)
    out, aux = shard_map(
        local_fn, mesh=mesh,
        in_specs=(xspec, P(None, None), *w_specs),
        out_specs=(xspec, P()),
        check_vma=False,
    )(x, params["router"], *w_in)
    return out, aux
