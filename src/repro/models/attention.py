"""Causal GQA attention with full / sliding-window variants and KV caches.

Sharding strategy (the part that decides whether the compiler inserts a
50 MB all-reduce or a 50 GB one): KV heads are REPEATED up to the query
heads before the score einsum, so the whole attention computation carries a
single head axis Hq. The ``constrain`` hook then places that axis:

  * Hq % model == 0  -> heads sharded over "model" (zero-comm attention)
  * else             -> query-chunk SEQUENCE sharding over "model"
                        (k/v replicated inside the layer; scores stay local)

Without this, GQA einsums with kv=8 heads on a 16-way model axis make
GSPMD emit partial-sum all-reduces over the (B, H, S, S) score tensors —
measured at 270 GB/device/step on llama3.2-3b before the fix.

Train & prefill scan over query chunks so the score tensor is never
(B, H, S, S) — peak is (B, H, qc, S) per chunk. Decode reads a cache: full
layers keep (B, S, Hkv, Dh) buffers; SWA layers keep a ring buffer of
``window`` slots (keys RoPE'd at insert, the ring never re-rotates).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init

Array = jax.Array
NEG_INF = -1e30
def _id(x, kind):
    return x


class KVCache(NamedTuple):
    k: Array  # (B, S_buf, Hkv, Dh)
    v: Array  # (B, S_buf, Hkv, Dh)


def attn_init(key: Array, d_model: int, n_heads: int, n_kv_heads: int,
              head_dim: int, dtype) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, (d_model, n_heads * head_dim), dtype),
        "wk": dense_init(kk, (d_model, n_kv_heads * head_dim), dtype),
        "wv": dense_init(kv, (d_model, n_kv_heads * head_dim), dtype),
        "wo": dense_init(ko, (n_heads * head_dim, d_model), dtype),
    }


def _split_heads(x: Array, n_heads: int, head_dim: int) -> Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, head_dim)


def _repeat_kv(x: Array, n_heads: int) -> Array:
    """(B, S, Hkv, Dh) -> (B, S, Hq, Dh)."""
    hkv = x.shape[2]
    if hkv == n_heads:
        return x
    return jnp.repeat(x, n_heads // hkv, axis=2)


def chunked_causal_attention(q: Array, k: Array, v: Array, *,
                             window: int = 0, q_chunk: int = 2048,
                             q_offset: int = 0,
                             constrain: Callable = _id) -> Array:
    """Causal (optionally windowed) attention, scanned over query chunks.

    q: (B, S, H, Dh); k, v: (B, T, H, Dh) — kv already head-repeated.
    q_offset: absolute position of q[0] relative to k[0].
    """
    b, s, h, dh = q.shape
    t = k.shape[1]
    scale = dh ** -0.5
    qc = min(q_chunk, s)
    n_chunks = (s + qc - 1) // qc
    pad = n_chunks * qc - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    # Constrain the STACKED chunk tensor once, before the scan: every
    # sliced chunk then inherits the layout, instead of being resharded
    # per iteration (which shows up as involuntary rematerialization).
    qs = q.reshape(b, n_chunks, qc, h, dh).transpose(1, 0, 2, 3, 4)
    qs = constrain(qs, "attn_q5")

    k = constrain(k, "attn_kv")
    v = constrain(v, "attn_kv")
    kpos = jnp.arange(t)

    def chunk(carry, args):
        ci, qb = args
        qpos = q_offset + ci * qc + jnp.arange(qc)
        scores = jnp.einsum("bqhd,bkhd->bhqk", qb, k,
                            preferred_element_type=jnp.float32) * scale
        mask = kpos[None, :] <= qpos[:, None]
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", w, v)
        return carry, out

    _, outs = jax.lax.scan(chunk, None, (jnp.arange(n_chunks), qs))
    outs = constrain(outs, "attn_q5")
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, n_chunks * qc, h, dh)
    return out[:, :s]


def attention_forward(params: dict, x: Array, cfg, mixer: str, *,
                      positions: Array,
                      cache: Optional[KVCache] = None,
                      cache_pos: Optional[Array] = None,
                      q_chunk: int = 2048,
                      constrain: Callable = _id
                      ) -> Tuple[Array, Optional[KVCache]]:
    """Unified train/prefill/decode attention.

    * train:   cache=None                       -> (out, None)
    * prefill: cache=empty buffers, cache_pos=0 -> (out, filled cache)
    * decode:  x is (B, 1, d), cache_pos=pos    -> (out, updated cache)
    """
    b, s, _ = x.shape
    window = cfg.window if mixer == "swa" else 0

    q = _split_heads(x @ params["wq"], cfg.n_heads, cfg.head_dim)
    k = _split_heads(x @ params["wk"], cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(x @ params["wv"], cfg.n_kv_heads, cfg.head_dim)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        out = chunked_causal_attention(
            q, _repeat_kv(k, cfg.n_heads), _repeat_kv(v, cfg.n_heads),
            window=window, q_chunk=q_chunk, constrain=constrain)
    elif s > 1:
        # Prefill: attend over the fresh sequence, then write the (roped)
        # keys/values into the cache buffers.
        out = chunked_causal_attention(
            q, _repeat_kv(k, cfg.n_heads), _repeat_kv(v, cfg.n_heads),
            window=window, q_chunk=q_chunk, constrain=constrain)
        s_buf = cache.k.shape[1]
        if window and s_buf == window:
            kw = k[:, -window:]
            vw = v[:, -window:]
            start = jnp.maximum(s - window, 0)
            idx = (start + jnp.arange(window)) % window
            cache = KVCache(k=cache.k.at[:, idx].set(kw),
                            v=cache.v.at[:, idx].set(vw))
        else:
            cache = KVCache(
                k=jax.lax.dynamic_update_slice_in_dim(cache.k, k, 0, 1),
                v=jax.lax.dynamic_update_slice_in_dim(cache.v, v, 0, 1))
    else:
        # Decode: append one token, attend over the cache.
        s_buf = cache.k.shape[1]
        if window and s_buf == window:
            slot = cache_pos % window
        else:
            slot = cache_pos
        ck = jax.lax.dynamic_update_slice_in_dim(cache.k, k, slot, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache.v, v, slot, 1)
        cache = KVCache(k=ck, v=cv)

        # Decode keeps the GROUPED einsum (no kv repeat): the cache is
        # sequence-sharded over "model" (flash-decoding layout) and the
        # softmax reductions over the sharded axis are tiny stats
        # all-reduces; materializing kv at Hq would cost Hq/Hkv x cache.
        g = cfg.n_heads // cfg.n_kv_heads
        qg = q.reshape(b, 1, cfg.n_kv_heads, g, cfg.head_dim)
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, ck,
                            preferred_element_type=jnp.float32) \
            * (cfg.head_dim ** -0.5)               # (B, Hkv, g, 1, S_buf)
        kpos = jnp.arange(s_buf)
        if window and s_buf == window:
            valid = (kpos <= cache_pos) | (cache_pos >= window)
        else:
            valid = kpos <= cache_pos
            if window:
                valid &= kpos > cache_pos - window
        scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1).astype(cv.dtype)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", w, cv).reshape(
            b, 1, cfg.n_heads, cfg.head_dim)

    out = out.reshape(b, s, cfg.n_heads * cfg.head_dim)
    return out @ params["wo"], cache


def make_kv_cache(cfg, mixer: str, batch: int, seq_len: int, dtype) -> KVCache:
    s_buf = min(cfg.window, seq_len) if mixer == "swa" and cfg.window else seq_len
    shape = (batch, s_buf, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))
