"""Selective SSM (Mamba) block — time-step scan formulation.

The recurrence h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * u_t is run with
``lax.scan`` over time carrying only (B, inner, N) state — never the
(B, S, inner, N) tensor — which keeps jamba-scale prefill (inner=16384)
inside HBM. Decode is the same step function applied once.

This is the TPU adaptation choice: the original CUDA kernel fuses the scan
in SRAM; on TPU the sequential-scan-with-small-carry form compiles to a
tight while loop whose body is VPU element-wise work + small matmuls, and
the d_model-sized projections around it stay MXU matmuls.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

Array = jax.Array


class MambaState(NamedTuple):
    conv: Array  # (B, K-1, inner) last conv inputs
    h: Array     # (B, inner, N) SSM state


def mamba_init(key: Array, cfg, dtype) -> dict:
    d, inner = cfg.d_model, cfg.ssm_inner
    N, K, R = cfg.ssm_state, cfg.ssm_conv, cfg.dt_rank_actual
    keys = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(keys[0], (d, 2 * inner), dtype),
        "conv_w": (jax.random.normal(keys[1], (K, inner), jnp.float32)
                   * (K ** -0.5)).astype(dtype),
        "x_proj": dense_init(keys[2], (inner, R + 2 * N), dtype),
        "dt_proj": dense_init(keys[3], (R, inner), dtype),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32),
                                  (inner, 1))),
        "D": jnp.ones((inner,), jnp.float32),
        "out_proj": dense_init(keys[4], (inner, d), dtype),
    }


def _ssm_scan(u: Array, dt: Array, B: Array, C: Array, A: Array, D: Array,
              h0: Array, chunk: int = 128) -> Tuple[Array, Array]:
    """u, dt: (Bt, S, inner); B, C: (Bt, S, N); A: (inner, N); h0: (Bt, inner, N).

    Nested chunked scan: the outer scan saves one (Bt, inner, N) carry per
    chunk; the inner per-step scan is rematerialized in the backward pass.
    Without this, scan-bwd residuals are (S, Bt, inner, N) — terabytes at
    jamba scale (the same problem the CUDA selective-scan kernel solves
    with SRAM recomputation; this is the XLA-native equivalent).

    Returns (y: (Bt, S, inner), h_final)."""
    bt, S, inner = u.shape

    def step(h, xs):
        u_t, dt_t, B_t, C_t = xs           # (Bt, inner), (Bt, inner), (Bt, N)x2
        dA = jnp.exp(dt_t[..., None] * A[None])            # (Bt, inner, N)
        dBu = (dt_t * u_t)[..., None] * B_t[:, None, :]    # (Bt, inner, N)
        h = dA * h + dBu
        y = jnp.einsum("bin,bn->bi", h, C_t) + D[None] * u_t
        return h, y

    ck = min(chunk, S)
    pad = (-S) % ck
    nc = (S + pad) // ck

    def to_chunks(x):
        x = jnp.pad(x.transpose(1, 0, 2), ((0, pad), (0, 0), (0, 0)))
        return x.reshape(nc, ck, *x.shape[1:])

    xs = tuple(to_chunks(t) for t in (u, dt, B, C))

    @jax.checkpoint
    def chunk_step(h, xs_c):
        return jax.lax.scan(step, h, xs_c)

    h, ys = jax.lax.scan(chunk_step, h0, xs)     # ys: (nc, ck, Bt, inner)
    ys = ys.reshape(nc * ck, bt, inner)[:S]
    return ys.transpose(1, 0, 2), h


def mamba_forward(params: dict, x: Array, cfg, *,
                  state: Optional[MambaState] = None
                  ) -> Tuple[Array, Optional[MambaState]]:
    """x: (B, S, d). state carries (conv tail, SSM h) for decode."""
    b, s, d = x.shape
    inner, N = cfg.ssm_inner, cfg.ssm_state
    K, R = cfg.ssm_conv, cfg.dt_rank_actual

    xz = x @ params["in_proj"]                       # (B, S, 2*inner)
    u, z = jnp.split(xz, 2, axis=-1)

    # Depthwise causal conv over time (kernel K).
    if state is None:
        pad = jnp.zeros((b, K - 1, inner), u.dtype)
        new_conv = None
    else:
        pad = state.conv
        new_conv = jnp.concatenate([pad, u], axis=1)[:, -(K - 1):]
    upad = jnp.concatenate([pad, u], axis=1)         # (B, S+K-1, inner)
    conv_w = params["conv_w"].astype(u.dtype)        # (K, inner)
    uc = sum(upad[:, i:i + s] * conv_w[i][None, None] for i in range(K))
    uc = jax.nn.silu(uc)

    proj = uc @ params["x_proj"]                     # (B, S, R+2N)
    dt_r, Bc, Cc = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(dt_r @ params["dt_proj"]).astype(jnp.float32)
    A = -jnp.exp(params["A_log"])                    # (inner, N)

    h0 = (state.h if state is not None
          else jnp.zeros((b, inner, N), jnp.float32))
    y, h = _ssm_scan(uc.astype(jnp.float32), dt, Bc.astype(jnp.float32),
                     Cc.astype(jnp.float32), A, params["D"], h0)
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ params["out_proj"]

    if state is None:
        return y, None
    return y, MambaState(conv=new_conv, h=h)


def make_mamba_state(cfg, batch: int, dtype) -> MambaState:
    return MambaState(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, cfg.ssm_inner), dtype),
        h=jnp.zeros((batch, cfg.ssm_inner, cfg.ssm_state), jnp.float32))
