"""RWKV6 "Finch" block: time-mix with data-dependent decay + channel-mix.

Per head h with dim D, the wkv state S in R^{DxD} evolves as

    S_t = diag(w_t) S_{t-1} + k_t v_t^T,
    y_t = r_t (S_{t-1} + diag(u) k_t v_t^T)

with the decay w_t = exp(-exp(wbase + lora(x_t))) *data-dependent* — the
Finch upgrade over RWKV5's static decay. Token-shift interpolation feeds
each projection a mix of x_t and x_{t-1}.

Train/prefill run a ``lax.scan`` over time carrying (B, H, D, D); decode is
one step. The state is O(1) in sequence length — this is the arch that
makes the 500k-token decode cell trivial.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

Array = jax.Array
LORA_R = 64


class RWKVState(NamedTuple):
    x_tm: Array   # (B, d) previous token for time-mix shift
    x_cm: Array   # (B, d) previous token for channel-mix shift
    wkv: Array    # (B, H, D, D) state matrix


def rwkv_init(key: Array, cfg, dtype) -> dict:
    d = cfg.d_model
    H, D = cfg.rwkv_heads, cfg.rwkv_head_dim
    keys = jax.random.split(key, 10)
    return {
        # time-mix interpolation factors per projection (r, k, v, g, w).
        "mu": (jax.random.uniform(keys[0], (5, d), jnp.float32)).astype(dtype),
        "wr": dense_init(keys[1], (d, d), dtype),
        "wk": dense_init(keys[2], (d, d), dtype),
        "wv": dense_init(keys[3], (d, d), dtype),
        "wg": dense_init(keys[4], (d, d), dtype),
        "wo": dense_init(keys[5], (d, d), dtype),
        # data-dependent decay LoRA: d -> LORA_R -> d, plus base decay.
        "w_base": jnp.zeros((d,), jnp.float32) - 6.0,
        "w_lora_a": dense_init(keys[6], (d, LORA_R), dtype),
        "w_lora_b": dense_init(keys[7], (LORA_R, d), dtype),
        "u": (jax.random.normal(keys[8], (H, D), jnp.float32) * 0.1),
        "ln_x": jnp.zeros((d,), jnp.float32),
    }


def channel_mix_init(key: Array, cfg, dtype) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mu": jax.random.uniform(k1, (2, d), jnp.float32).astype(dtype),
        "wk": dense_init(k2, (d, ff), dtype),
        "wv": dense_init(k3, (ff, d), dtype),
    }


def _shift(x: Array, x_prev: Optional[Array]) -> Array:
    """x: (B, S, d) -> previous-token tensor (B, S, d)."""
    if x_prev is None:
        x_prev = jnp.zeros_like(x[:, :1])
    else:
        x_prev = x_prev[:, None, :]
    return jnp.concatenate([x_prev, x[:, :-1]], axis=1)


def rwkv_time_mix(params: dict, x: Array, cfg, *,
                  state: Optional[RWKVState] = None
                  ) -> Tuple[Array, Optional[Array], Optional[Array]]:
    """Returns (out, new_x_tm, new_wkv)."""
    b, s, d = x.shape
    H, D = cfg.rwkv_heads, cfg.rwkv_head_dim

    xs = _shift(x, state.x_tm if state is not None else None)
    mu = params["mu"].astype(x.dtype)
    mix = [x * mu[i][None, None] + xs * (1 - mu[i][None, None])
           for i in range(5)]
    r = (mix[0] @ params["wr"]).reshape(b, s, H, D)
    k = (mix[1] @ params["wk"]).reshape(b, s, H, D)
    v = (mix[2] @ params["wv"]).reshape(b, s, H, D)
    g = jax.nn.silu(mix[3] @ params["wg"])
    # Data-dependent decay (Finch): w_t in (0, 1).
    w_raw = params["w_base"].astype(jnp.float32) + \
        ((mix[4] @ params["w_lora_a"]) @ params["w_lora_b"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w_raw)).reshape(b, s, H, D)
    u = params["u"]                                    # (H, D)

    def step(S, xs_t):
        r_t, k_t, v_t, w_t = xs_t                      # (B, H, D) each
        kv = k_t[..., None] * v_t[..., None, :]        # (B, H, D, D)
        y = jnp.einsum("bhd,bhde->bhe", r_t,
                       S + u[None, :, :, None] * kv)
        S = w_t[..., None] * S + kv
        return S, y

    S0 = (state.wkv if state is not None
          else jnp.zeros((b, H, D, D), jnp.float32))

    # Chunked scan with inner remat: outer carries one (B, H, D, D) state
    # per chunk; scan-bwd residuals stay O(S/chunk) instead of O(S).
    ck = min(128, s)
    pad = (-s) % ck
    nc = (s + pad) // ck

    def to_chunks(t):
        t = jnp.pad(t.transpose(1, 0, 2, 3).astype(jnp.float32),
                    ((0, pad), (0, 0), (0, 0), (0, 0)))
        return t.reshape(nc, ck, *t.shape[1:])

    seq = tuple(to_chunks(t) for t in (r, k, v, w))

    @jax.checkpoint
    def chunk_step(S, xs_c):
        return jax.lax.scan(step, S, xs_c)

    S, ys = jax.lax.scan(chunk_step, S0, seq)       # (nc, ck, B, H, D)
    ys = ys.reshape(nc * ck, b, H, D)[:s]
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)

    # Group norm over heads (ln_x), then gate and output-project.
    yh = y.reshape(b, s, H, D).astype(jnp.float32)
    yh = yh * jax.lax.rsqrt(jnp.mean(yh * yh, axis=-1, keepdims=True) + 1e-5)
    y = (yh.reshape(b, s, d) * (1.0 + params["ln_x"])[None, None]).astype(x.dtype)
    out = (y * g) @ params["wo"]

    new_x_tm = x[:, -1] if state is not None else None
    return out, new_x_tm, (S if state is not None else None)


def rwkv_channel_mix(params: dict, x: Array, *,
                     x_prev: Optional[Array] = None
                     ) -> Tuple[Array, Optional[Array]]:
    xs = _shift(x, x_prev)
    mu = params["mu"].astype(x.dtype)
    xk = x * mu[0][None, None] + xs * (1 - mu[0][None, None])
    h = jnp.square(jax.nn.relu(xk @ params["wk"]))
    out = h @ params["wv"]
    return out, (x[:, -1] if x_prev is not None else None)


def make_rwkv_state(cfg, batch: int, dtype) -> RWKVState:
    return RWKVState(
        x_tm=jnp.zeros((batch, cfg.d_model), dtype),
        x_cm=jnp.zeros((batch, cfg.d_model), dtype),
        wkv=jnp.zeros((batch, cfg.rwkv_heads, cfg.rwkv_head_dim,
                       cfg.rwkv_head_dim), jnp.float32))
