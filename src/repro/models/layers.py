"""Shared neural building blocks (pure JAX, pjit/GSPMD-friendly)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.utils.compat import shard_map

Array = jax.Array


def rms_norm(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def rope_freqs(head_dim: int, theta: float) -> Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, S, H, Dh); positions: (B, S) absolute token positions."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                       # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, Dh/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def mlp_forward(params: dict, x: Array, mlp_type: str) -> Array:
    """Gated / plain MLP. params: w1 (d, ff)[, w3 (d, ff)], w2 (ff, d)."""
    if mlp_type == "swiglu":
        h = jax.nn.silu(x @ params["w1"]) * (x @ params["w3"])
    elif mlp_type == "geglu":
        h = jax.nn.gelu(x @ params["w1"]) * (x @ params["w3"])
    elif mlp_type == "relu2":
        h = jnp.square(jax.nn.relu(x @ params["w1"]))
    elif mlp_type == "gelu":
        h = jax.nn.gelu(x @ params["w1"])
    else:
        raise ValueError(mlp_type)
    return h @ params["w2"]


def mlp_forward_tp(params: dict, x: Array, mlp_type: str, ctx) -> Array:
    """Explicit megatron-TP MLP under shard_map.

    Why not let GSPMD do it (hillclimb iter 2, EXPERIMENTS.md §Perf):
    GSPMD all-reduces the f32 dot *accumulator* of the row-parallel matmul
    — 2x the bytes of the bf16 activation. Under shard_map the psum
    operand is explicitly cast to the activation dtype first. Backward
    inherits the same property (dx psum in bf16 at the col-parallel side).
    """
    mesh = ctx["mesh"]
    baxes = ctx["data_axes"]
    fsdp = ctx["fsdp"]
    dp = 1
    for ax in baxes:
        dp *= mesh.shape[ax]
    b = x.shape[0]
    bspec = baxes if b % dp == 0 else None
    xspec = P(bspec, None, None)
    gated = mlp_type in ("swiglu", "geglu")
    w1spec = P("data" if fsdp else None, "model")
    w2spec = P("model", "data" if fsdp else None)

    def local_fn(xl, w1, w2, *rest):
        w3 = rest[0] if gated else None
        if fsdp:
            w1 = jax.lax.all_gather(w1, "data", axis=0, tiled=True)
            w2 = jax.lax.all_gather(w2, "data", axis=1, tiled=True)
            if w3 is not None:
                w3 = jax.lax.all_gather(w3, "data", axis=0, tiled=True)
        h1 = xl @ w1
        if mlp_type == "swiglu":
            h = jax.nn.silu(h1) * (xl @ w3)
        elif mlp_type == "geglu":
            h = jax.nn.gelu(h1) * (xl @ w3)
        elif mlp_type == "relu2":
            h = jnp.square(jax.nn.relu(h1))
        else:
            h = jax.nn.gelu(h1)
        part = (h @ w2).astype(xl.dtype)      # bf16 BEFORE the all-reduce
        return jax.lax.psum(part, "model")

    args = [x, params["w1"], params["w2"]]
    specs = [xspec, w1spec, w2spec]
    if gated:
        args.append(params["w3"])
        specs.append(w1spec)
    return shard_map(local_fn, mesh=mesh, in_specs=tuple(specs),
                     out_specs=xspec, check_vma=False)(*args)


def mlp_init(key: Array, d_model: int, d_ff: int, mlp_type: str,
             dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_model ** -0.5
    s_ff = d_ff ** -0.5
    p = {
        "w1": (jax.random.normal(k1, (d_model, d_ff), jnp.float32)
               * s_in).astype(dtype),
        "w2": (jax.random.normal(k2, (d_ff, d_model), jnp.float32)
               * s_ff).astype(dtype),
    }
    if mlp_type in ("swiglu", "geglu"):
        p["w3"] = (jax.random.normal(k3, (d_model, d_ff), jnp.float32)
                   * s_in).astype(dtype)
    return p


def embed_init(key: Array, vocab: int, d_model: int, dtype) -> Array:
    return (jax.random.normal(key, (vocab, d_model), jnp.float32)
            * (d_model ** -0.5)).astype(dtype)


def dense_init(key: Array, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0]
    s = (fan_in ** -0.5) if scale is None else scale
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)
