"""Unified decoder stack over the heterogeneous layer pattern.

Params for the repeating pattern period are stacked (R, ...) and the stack
is traversed with ``lax.scan`` (period unrolled inside the body, remat
around it), so HLO size is O(period), not O(n_layers) — mandatory for the
62/72-layer configs. A partial tail period is unrolled after the scan.

Modes (one code path, cache optionality decides):
  * train:   cache=None, full sequence
  * prefill: cache=zero buffers, full sequence, returns filled cache
  * decode:  cache=filled, single-token step, pos0 = current length

Modality frontends are STUBS per the assignment: audio supplies precomputed
frame embeddings (replacing the token embedding), vision supplies patch
embeddings that are prepended to the text embeddings.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ArchConfig, LayerSpec
from repro.models.attention import (attn_init, attention_forward,
                                    make_kv_cache)
from repro.models.layers import (dense_init, embed_init, mlp_forward,
                                 mlp_forward_tp, mlp_init, rms_norm)
from repro.models.mamba import (make_mamba_state, mamba_forward,
                                mamba_init)
from repro.models.moe import moe_forward, moe_init
from repro.models.rwkv import (RWKVState, channel_mix_init, make_rwkv_state,
                               rwkv_channel_mix, rwkv_init, rwkv_time_mix)

Array = jax.Array
Constrain = Callable[[Array, str], Array]
_id_constrain: Constrain = lambda x, kind: x


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _layer_init(key: Array, cfg: ArchConfig, spec: LayerSpec) -> dict:
    kmix, kmlp, kres = jax.random.split(key, 3)
    dtype = cfg.dtype
    p: dict = {
        "norm1": jnp.zeros((cfg.d_model,), jnp.float32),
        "norm2": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if spec.mixer in ("full", "swa"):
        p["attn"] = attn_init(kmix, cfg.d_model, cfg.n_heads,
                              cfg.n_kv_heads, cfg.head_dim, dtype)
    elif spec.mixer == "mamba":
        p["mamba"] = mamba_init(kmix, cfg, dtype)
    elif spec.mixer == "rwkv":
        p["rwkv"] = rwkv_init(kmix, cfg, dtype)
    else:
        raise ValueError(spec.mixer)

    if spec.mixer == "rwkv":
        p["cmix"] = channel_mix_init(kmlp, cfg, dtype)
    elif spec.moe:
        p["moe"] = moe_init(kmlp, cfg.d_model, cfg.n_experts, cfg.moe_ff,
                            cfg.mlp_type, dtype)
        if cfg.dense_residual_ff:
            p["dense_res"] = mlp_init(kres, cfg.d_model,
                                      cfg.dense_residual_ff, cfg.mlp_type,
                                      dtype)
    else:
        p["mlp"] = mlp_init(kmlp, cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype)
    return p


def _period_init(key: Array, cfg: ArchConfig) -> list:
    keys = jax.random.split(key, cfg.period)
    return [_layer_init(keys[i], cfg, cfg.layer_pattern[i])
            for i in range(cfg.period)]


def init_params(cfg: ArchConfig, key: Array) -> dict:
    ke, ks, kt, ku = jax.random.split(key, 4)
    params: dict = {
        "embed": embed_init(ke, cfg.padded_vocab, cfg.d_model, cfg.dtype),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        "unembed": dense_init(ku, (cfg.d_model, cfg.padded_vocab), cfg.dtype),
    }
    if cfg.n_repeats > 0:
        seg_keys = jax.random.split(ks, cfg.n_repeats)
        params["segments"] = jax.vmap(
            lambda k: _period_init_tree(k, cfg))(seg_keys)
    if cfg.n_tail > 0:
        tail_keys = jax.random.split(kt, cfg.n_tail)
        params["tail"] = [_layer_init(tail_keys[i], cfg, cfg.layer_spec(i))
                          for i in range(cfg.n_tail)]
    return params


def _period_init_tree(key: Array, cfg: ArchConfig) -> dict:
    return {f"l{i}": p for i, p in enumerate(_period_init(key, cfg))}


# ---------------------------------------------------------------------------
# per-layer state (KV cache / SSM state)
# ---------------------------------------------------------------------------

def _layer_state(cfg: ArchConfig, spec: LayerSpec, batch: int, seq_len: int,
                 dtype):
    if spec.mixer in ("full", "swa"):
        return make_kv_cache(cfg, spec.mixer, batch, seq_len, dtype)
    if spec.mixer == "mamba":
        return make_mamba_state(cfg, batch, dtype)
    if spec.mixer == "rwkv":
        return make_rwkv_state(cfg, batch, dtype)
    raise ValueError(spec.mixer)


def init_cache(cfg: ArchConfig, batch: int, seq_len: int,
               dtype=None) -> dict:
    dtype = dtype or cfg.dtype
    cache: dict = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.n_repeats > 0:
        def one(_r):
            return {f"l{i}": _layer_state(cfg, cfg.layer_pattern[i], batch,
                                          seq_len, dtype)
                    for i in range(cfg.period)}
        cache["segments"] = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[one(r) for r in range(cfg.n_repeats)]) if cfg.n_repeats > 1 \
            else jax.tree.map(lambda x: x[None], one(0))
    if cfg.n_tail > 0:
        cache["tail"] = [_layer_state(cfg, cfg.layer_spec(i), batch, seq_len,
                                      dtype) for i in range(cfg.n_tail)]
    return cache


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _apply_layer(p: dict, x: Array, cfg: ArchConfig, spec: LayerSpec, *,
                 positions: Array, state, cache_pos, q_chunk: int,
                 constrain: Constrain):
    """One decoder layer. Returns (x, new_state, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if spec.mixer in ("full", "swa"):
        out, new_mix_state = attention_forward(
            p["attn"], h, cfg, spec.mixer, positions=positions,
            cache=state, cache_pos=cache_pos, q_chunk=q_chunk,
            constrain=constrain)
    elif spec.mixer == "mamba":
        out, new_mix_state = mamba_forward(p["mamba"], h, cfg, state=state)
    elif spec.mixer == "rwkv":
        out, new_x_tm, new_wkv = rwkv_time_mix(p["rwkv"], h, cfg, state=state)
        new_mix_state = state
    else:
        raise ValueError(spec.mixer)
    out = checkpoint_name(out, "mixer_out")
    x = x + out
    x = constrain(x, "activations")

    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    if spec.mixer == "rwkv":
        out, new_x_cm = rwkv_channel_mix(
            p["cmix"], h, x_prev=(state.x_cm if state is not None else None))
        if state is not None:
            new_mix_state = RWKVState(x_tm=new_x_tm, x_cm=new_x_cm,
                                      wkv=new_wkv)
    elif spec.moe:
        out, aux = moe_forward(p["moe"], h, n_experts=cfg.n_experts,
                               top_k=cfg.top_k,
                               capacity_factor=cfg.capacity_factor,
                               mlp_type=cfg.mlp_type, impl=cfg.moe_impl,
                               constrain=constrain)
        if cfg.dense_residual_ff:
            out = out + mlp_forward(p["dense_res"], h, cfg.mlp_type)
    else:
        ctx = getattr(constrain, "shard_ctx", None)
        if cfg.tp_mlp and ctx is not None:
            out = mlp_forward_tp(p["mlp"], h, cfg.mlp_type, ctx)
        else:
            out = mlp_forward(p["mlp"], h, cfg.mlp_type)
    out = checkpoint_name(out, "mlp_out")
    x = x + out
    x = constrain(x, "activations")
    return x, new_mix_state, aux


def _remat_wrap(fn, mode: str):
    """Per-LAYER remat: bounds backward-pass liveness to one layer's
    internals (a whole-period checkpoint holds every layer of the period
    alive during its backward recompute — measured +12 GB/device on
    jamba's 8-layer period)."""
    if mode == "none":
        return fn
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    if mode == "boundaries":
        # Save the post-all-reduce mixer/MLP outputs: the backward pass
        # then re-uses them instead of re-running the TP partial-sum
        # all-reduces during recompute (-1/3 of AR traffic for +2
        # activation-sized saves per layer).
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.save_only_these_names(
                "mixer_out", "mlp_out"))
    return jax.checkpoint(fn)  # "full": save nothing


def forward(params: dict, cfg: ArchConfig, *,
            tokens: Optional[Array] = None,
            embeds: Optional[Array] = None,
            vision_embeds: Optional[Array] = None,
            cache: Optional[dict] = None,
            q_chunk: int = 2048,
            return_hidden: bool = False,
            constrain: Constrain = _id_constrain
            ) -> Tuple[Array, Optional[dict], Array]:
    """Returns (logits_or_hidden, new_cache_or_None, aux_loss).

    return_hidden skips the unembedding: the caller fuses it into the
    loss (fused_unembed_ce) so huge-vocab logits are never materialized.
    """
    if embeds is not None:                       # audio frontend stub
        x = embeds.astype(cfg.dtype)
    else:
        x = params["embed"][tokens]
    if vision_embeds is not None:                # vision frontend stub
        x = jnp.concatenate([vision_embeds.astype(cfg.dtype), x], axis=1)
    b, s, _ = x.shape

    pos0 = cache["pos"] if cache is not None else jnp.zeros((), jnp.int32)
    positions = jnp.broadcast_to(pos0 + jnp.arange(s)[None, :], (b, s))
    x = constrain(x, "activations")

    aux_total = jnp.zeros((), jnp.float32)
    new_cache: Optional[dict] = {"pos": pos0 + s} if cache is not None else None

    # Per-layer application, remat'd individually in training mode.
    def layer_fns():
        fns = {}
        for i in range(cfg.period):
            spec = cfg.layer_pattern[i]

            def fn(p, x, st, _spec=spec):
                return _apply_layer(p, x, cfg, _spec, positions=positions,
                                    state=st, cache_pos=pos0,
                                    q_chunk=q_chunk, constrain=constrain)

            fns[i] = _remat_wrap(fn, cfg.remat) if cache is None else fn
        return fns

    fns = layer_fns()

    def period_body(carry, xs):
        x, aux_sum = carry
        seg_params, seg_state = xs
        new_states = {}
        for i in range(cfg.period):
            st = seg_state[f"l{i}"] if seg_state is not None else None
            x, nst, aux = fns[i](seg_params[f"l{i}"], x, st)
            new_states[f"l{i}"] = nst
            aux_sum = aux_sum + aux
        if seg_state is None:
            return (x, aux_sum), None
        return (x, aux_sum), new_states

    if cfg.n_repeats > 0:
        seg_params = params["segments"]
        seg_states = cache.get("segments") if cache is not None else None
        if seg_states is None:
            (x, aux_total), _ = jax.lax.scan(
                lambda c, sp: period_body(c, (sp, None)),
                (x, aux_total), seg_params)
        else:
            (x, aux_total), new_seg_states = jax.lax.scan(
                period_body, (x, aux_total), (seg_params, seg_states))
            new_cache["segments"] = new_seg_states

    if cfg.n_tail > 0:
        new_tail = []
        for i in range(cfg.n_tail):
            st = cache["tail"][i] if cache is not None else None
            x, nst, aux = fns[i](params["tail"][i], x, st)
            aux_total = aux_total + aux
            new_tail.append(nst)
        if cache is not None:
            new_cache["tail"] = new_tail

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x, new_cache, aux_total
    logits = x @ params["unembed"]
    if cfg.padded_vocab != cfg.vocab_size:
        # Megatron-style vocab padding: pad columns never win.
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    logits = constrain(logits, "logits")
    return logits, new_cache, aux_total
