"""Batched scoring engine: padding buckets over the Pallas decision kernel.

Every request is padded up to one of ``BUCKETS`` row counts before it
reaches the kernel, so the whole service compiles at most one executable
per (bucket, model) pair — a request of 63, 64 or 65 rows never triggers
a fresh trace. Requests larger than the top bucket are chunked through
it (each chunk reuses the same cached executable).

Two execution paths share the packing:

* local  — ``decision_packed`` (jit; Pallas on TPU, interpret on CPU),
* sharded — the same call inside ``shard_map`` over a mesh data axis:
  queries are row-sharded, the packed support set is replicated, and no
  collective is needed (each shard owns its output rows) — pod-scale
  batches cost one kernel launch per shard.

Both paths score at the model's packed ``precision``: the support block
is already stored in the serving tile dtype, queries are cast per launch,
and the accumulate/epilogue stays f32 (``repro.kernels.precision``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.decision.ops import decision_packed
from repro.serve.model_cache import ServingModel
from repro.utils.compat import shard_map

Array = jax.Array

# Request row-counts are padded up to one of these; the top bucket is also
# the chunk size for larger batches. Powers of 4: adjacent buckets stay a
# small constant factor apart, so padding waste is bounded by 4x rows (and
# by far less wall-clock — the kernel is support-set bound).
BUCKETS = (64, 256, 1024, 4096)


def bucket_for(n: int) -> int:
    """Smallest bucket >= n (the top bucket for anything larger)."""
    if n < 1:
        raise ValueError(f"need at least one query row, got {n}")
    for b in BUCKETS:
        if n <= b:
            return b
    return BUCKETS[-1]


class BatchScorer:
    """Scores query batches against one ``ServingModel``.

    ``mesh`` switches on the sharded path: queries are padded to
    ``bucket * mesh.shape[data_axis]`` rows and ``shard_map``-ed so each
    device scores its own slice against the replicated support set.
    """

    def __init__(self, model: ServingModel, *, interpret: bool | None = None,
                 mesh=None, data_axis: str = "data"):
        self.model = model
        self.interpret = interpret
        self.mesh = mesh
        self.data_axis = data_axis
        self._d_pad = int(model.t_pad.shape[1])
        # Buckets whose executable warmup() has pre-compiled: the service
        # reads this to avoid recording a warmed bucket's first launch as
        # a cold (compile-laden) observation.
        self.warmed_buckets: set = set()
        if mesh is not None and data_axis not in mesh.shape:
            raise ValueError(f"mesh has no axis {data_axis!r}: "
                             f"{tuple(mesh.shape)}")

    # -- padding ------------------------------------------------------------
    def _pad_queries(self, q, rows: int) -> Array:
        """(n, d) -> (rows, d_pad) f32 with zero padding.

        numpy inputs (the service boundary) are padded host-side into one
        bucket-shaped buffer — no per-request-shape device programs at
        all; jax-array inputs stay on device via jnp.pad (the pad op
        itself is trivial to compile).
        """
        if isinstance(q, np.ndarray):
            out = np.zeros((rows, self._d_pad), np.float32)
            out[:q.shape[0], :q.shape[1]] = q
            return jnp.asarray(out)
        q = q.astype(jnp.float32)
        return jnp.pad(q, ((0, rows - q.shape[0]),
                           (0, self._d_pad - q.shape[1])))

    @staticmethod
    def _tm(bucket: int) -> int:
        # Query tile: whole bucket when it fits the default tile, else the
        # default (grid over the bucket). Keeps bucket 64 a 1-tile launch.
        return min(bucket, 256)

    def _check(self, q):
        if q.ndim != 2:
            raise ValueError(f"queries must be (n, d), got {q.shape}")
        if q.shape[1] != self.model.d:
            raise ValueError(f"query feature dim {q.shape[1]} != model "
                             f"feature dim {self.model.d}")

    # -- local path ---------------------------------------------------------
    def _score_bucket(self, q_pad: Array) -> Array:
        m = self.model
        return decision_packed(q_pad, m.t_pad, m.gamma_pad, m.t_norms,
                               m.rho1, m.rho2, m.spec.kernel,
                               tm=self._tm(q_pad.shape[0]), tn=m.tn,
                               interpret=self.interpret,
                               precision=m.precision)

    def chunk_rows(self) -> int:
        """Rows one launch can take: the top bucket, times the data-axis
        size on the sharded path (each shard gets a top-bucket slice)."""
        nd = int(self.mesh.shape[self.data_axis]) if self.mesh is not None \
            else 1
        return BUCKETS[-1] * nd

    def bucket_used(self, n: int) -> int:
        """The padding bucket one single-launch n-row request lands in —
        the per-shard bucket on the sharded path (that is what keys the
        compiled executable and therefore the stats)."""
        if self.mesh is not None:
            nd = int(self.mesh.shape[self.data_axis])
            return bucket_for(max(1, -(-n // nd)))
        return bucket_for(n)

    def launch_plan(self, n: int):
        """(rows, bucket) per kernel launch for an n-row request — full
        top-capacity chunks first, then the remainder in its own (often
        smaller) bucket. Single source for the service's stats keys."""
        cap = self.chunk_rows()
        sizes = [cap] * (n // cap) + ([n % cap] if n % cap else [])
        return [(rows, self.bucket_used(rows)) for rows in sizes]

    def score(self, q) -> Array:
        """Slab decision values (n, d) -> (n,); every shape hits a cached
        bucket executable. Batches beyond one launch's capacity are
        chunked (each chunk reuses its cached executable). numpy inputs
        (the service boundary) come back as numpy — see ``_unpad``."""
        self._check(q)
        n = int(q.shape[0])
        cap = self.chunk_rows()
        if n > cap:
            chunks = [self._score_once(q[i:i + cap])
                      for i in range(0, n, cap)]
            xp = np if isinstance(chunks[0], np.ndarray) else jnp
            # only the last chunk carries padding rows
            return xp.concatenate(chunks)[:n]
        return self._score_once(q)

    def _unpad(self, out: Array, n: int, host: bool):
        """Drop the padding rows of one launch's output.

        The device slice ``out[:n]`` compiles one slice program per
        DISTINCT (n, bucket) pair — under a coalescing service the
        window row count varies freely, so that is a fresh ~10-30ms
        trace+compile on nearly every flush, an order of magnitude over
        the launch it trims. numpy requests (the service boundary)
        therefore unpad host-side, completing ``_pad_queries``'s
        no-per-request-shape-device-programs promise on the way out;
        jax-array requests keep a device result.
        """
        if host:
            return np.asarray(out)[:n]
        return out[:n]

    def _score_once(self, q) -> Array:
        n = int(q.shape[0])
        host = isinstance(q, np.ndarray)
        if self.mesh is not None:
            return self._score_sharded(q, n)
        out = self._score_bucket(self._pad_queries(q, bucket_for(n)))
        return self._unpad(out, n, host)

    # -- sharded path -------------------------------------------------------
    def _score_sharded(self, q, n: int) -> Array:
        mesh = self.mesh
        nd = int(mesh.shape[self.data_axis])
        per_shard = bucket_for(max(1, -(-n // nd)))
        q_pad = self._pad_queries(q, per_shard * nd)
        m = self.model
        P = jax.sharding.PartitionSpec

        def shard_fn(qs):
            return decision_packed(qs, m.t_pad, m.gamma_pad, m.t_norms,
                                   m.rho1, m.rho2, m.spec.kernel,
                                   tm=self._tm(per_shard), tn=m.tn,
                                   interpret=self.interpret,
                                   precision=m.precision)

        fn = shard_map(shard_fn, mesh=mesh,
                       in_specs=(P(self.data_axis, None),),
                       out_specs=P(self.data_axis))
        with mesh:
            out = fn(q_pad)
        return self._unpad(out, n, isinstance(q, np.ndarray))

    def warmup(self) -> None:
        """Pre-compile every bucket executable the scorer will serve with.

        Warms the path ``score()`` actually takes: with ``mesh`` set that
        is the ``shard_map``'d executable (one per per-shard bucket) —
        warming the local bucket programs instead would leave exactly the
        pod-scale path cold on its first real request. Each warm request
        is sized so ``_score_once`` lands on per-shard bucket ``b``
        (``b * n_devices`` rows sharded == ``b`` rows local).
        """
        nd = int(self.mesh.shape[self.data_axis]) if self.mesh is not None \
            else 1
        for b in BUCKETS:
            q = jnp.zeros((b * nd, self.model.d), jnp.float32)
            jax.block_until_ready(self._score_once(q))
            self.warmed_buckets.add(b)
