"""Warm-model cache: fit once per (SlabSpec, data) fingerprint, then serve.

The deployed artifact of the paper is the slab decision function, and its
cost is dominated by the support set (PAPERS.md, ensemble-decomposition
line) — so a cache miss does the expensive work exactly once:

1. ``repro.fit`` trains with the requested engine composition,
2. the model is compacted to its support vectors (``compact_support``),
3. the SV block is padded to the Pallas decision kernel's tile grid and
   its row norms precomputed,

and every later request for the same (spec, data, precision, fit-kwargs)
key gets the prepared ``ServingModel`` back without touching the solver.
Keys use a content fingerprint of X (sampled above ``_HASH_SAMPLE_BYTES``
so fingerprinting a million-row set stays O(MB)), never object identity.

``precision`` ("f32" default / "bf16" / "f16") is threaded down through
both the fit (Gram tile inputs) and the pack: the support block is stored
in the serving tile dtype ONCE here, so the decision kernel streams
16-bit support bytes with no per-request cast; norms are f32 of the
rounded rows. Models packed at different precisions are different cache
entries.

The cache is process-local and thread-safe; concurrent misses on the
same key coalesce onto one fit (per-key in-flight locks — the losers
block until the winner's model is ready instead of re-running the
solve). The multi-model registry (``repro.serve.registry``) layers
name -> recipe routing on top of exactly these keys; cross-process
sharing is a ROADMAP follow-on.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
from collections import OrderedDict
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ocssvm import (OCSSVMModel, SlabSpec, compact_support,
                               concrete_spec, with_quantile_offsets)
from repro.kernels.precision import check_precision, tile_dtype

Array = jax.Array

# Fingerprint at most this many bytes of X: above it, hash an evenly
# strided row sample plus the exact shape/dtype (collisions would need two
# same-shape sets agreeing on every sampled row).
_HASH_SAMPLE_BYTES = 1 << 24


@dataclasses.dataclass
class ServingModel:
    """A fitted slab packed for the decision kernel, ready to score.

    ``model`` is the compacted reference (support rows only) whose
    ``decision_function`` the scorer must match exactly (within the
    documented precision tolerance when serving below f32); ``t_pad`` /
    ``gamma_pad`` / ``t_norms`` are the kernel operands, padded once to a
    multiple of ``tn`` rows and 128 features (zero-gamma padding rows
    contribute nothing, so a zero-SV model still serves — every query
    scores ``(0 - rho1) * (rho2 - 0)``). ``t_pad`` is stored in the
    serving tile dtype (f32 / bf16 / f16 per ``precision``); gamma and
    the precomputed norms are always f32.
    """

    model: OCSSVMModel
    t_pad: Array        # (M_pad, d_pad) support rows, serving tile dtype
    gamma_pad: Array    # (M_pad, 1) f32, zero beyond n_sv
    t_norms: Array      # (M_pad, 1) f32 precomputed ||t||^2 (rounded rows)
    n_sv: int
    tn: int
    spec: SlabSpec      # concretized (hashable) spec
    precision: str = "f32"
    fit_iters: int = 0
    # The full solver state (`engine.SolverArtifact`) behind this packed
    # model — gamma/f over ALL training rows, not just SVs. It is what
    # makes a served model restartable: `ModelRegistry.refresh` hands it
    # to `repro.fit_update` so a data delta warm-starts instead of
    # cold-fitting. None when the fit path could not supply one.
    artifact: Optional[object] = dataclasses.field(default=None, repr=False)
    _scorer: Optional[object] = dataclasses.field(default=None, repr=False)

    @property
    def rho1(self) -> Array:
        return self.model.rho1

    @property
    def rho2(self) -> Array:
        return self.model.rho2

    @property
    def d(self) -> int:
        return int(self.model.X.shape[1])

    def scorer(self, **kwargs):
        """The batched scoring engine for this model.

        No kwargs -> one memoized default ``BatchScorer`` (so repeated
        ``score`` calls share its cached executables); with kwargs a fresh
        scorer is built (e.g. ``mesh=...`` for the sharded path).
        """
        from repro.serve.scorer import BatchScorer
        if kwargs:
            return BatchScorer(self, **kwargs)
        if self._scorer is None:
            self._scorer = BatchScorer(self)
        return self._scorer

    def score(self, q: Array, **kwargs) -> Array:
        """Slab decision values for queries (n, d) -> (n,)."""
        return self.scorer(**kwargs).score(q)

    def predict(self, q: Array, **kwargs) -> Array:
        """+1 inside the slab, -1 outside."""
        return jnp.where(self.score(q, **kwargs) >= 0, 1, -1)


def _pad_rows_cols(a: np.ndarray, row_mult: int) -> np.ndarray:
    rows = max(row_mult, -(-a.shape[0] // row_mult) * row_mult)
    cols = -(-a.shape[1] // 128) * 128
    out = np.zeros((rows, cols), np.float32)
    out[:a.shape[0], :a.shape[1]] = a
    return out


def pack_model(model: OCSSVMModel, *, sv_threshold: float = 1e-7,
               tn: int = 512, precision: str = "f32") -> ServingModel:
    """Compact a fitted model to SVs and pack it for ``decision_packed``.

    ``precision`` picks the serving tile dtype: the SV block is cast to
    it HERE, once (numpy has no bfloat16, so the cast happens on the jnp
    side), and the f32 norms are computed from the *rounded* rows so the
    kernel's RBF distance identity holds exactly for the bytes it streams.
    """
    check_precision(precision)
    spec = concrete_spec(model.spec)
    compact = compact_support(model._replace(spec=spec),
                              threshold=sv_threshold)
    n_sv = int(compact.X.shape[0])
    sv = np.asarray(compact.X, np.float32)
    t_pad = jnp.asarray(_pad_rows_cols(sv, tn)).astype(tile_dtype(precision))
    tf = t_pad.astype(jnp.float32)
    t_norms = jnp.sum(tf * tf, axis=-1, keepdims=True)
    gamma_pad = np.zeros((t_pad.shape[0], 1), np.float32)
    gamma_pad[:n_sv, 0] = np.asarray(compact.gamma, np.float32)
    return ServingModel(model=compact, t_pad=t_pad,
                        gamma_pad=jnp.asarray(gamma_pad),
                        t_norms=t_norms, n_sv=n_sv, tn=tn,
                        spec=spec, precision=precision)


def fingerprint_array(X) -> Tuple:
    """Content key for a training set: (shape, dtype, sha1 of a sample).

    Layout-invariant: ``tobytes()`` serializes the *logical* (C-order)
    contents, so a Fortran-ordered or strided view fingerprints equal to
    its contiguous copy — and no explicit contiguous copy is ever made.
    0-d arrays are hashed whole (sampling needs an axis to stride);
    above ``_HASH_SAMPLE_BYTES`` an evenly strided leading-axis sample
    is hashed instead, with ``stride = ceil(nbytes / budget)`` so the
    sampled bytes stay within budget regardless of row width.
    """
    a = np.asarray(X)
    sample = a
    if a.ndim >= 1 and a.nbytes > _HASH_SAMPLE_BYTES:
        stride = -(-a.nbytes // _HASH_SAMPLE_BYTES)   # ceil division
        sample = a[::stride]
    digest = hashlib.sha1(sample.tobytes()).hexdigest()
    return (a.shape, str(a.dtype), digest)


class ExtendableFingerprint:
    """Incremental ``fingerprint_array``: O(Δ rows) keying for appends.

    A registry refresh that appends Δm rows would otherwise re-hash the
    whole training set to compute the new recipe key. sha1 is a
    streaming hash, so as long as the WHOLE array is what gets hashed
    (nbytes within ``_HASH_SAMPLE_BYTES`` — above it ``fingerprint_array``
    switches to a strided row sample and the prefix property breaks),
    hashing the appended rows into a copy of the saved sha1 state yields
    exactly ``fingerprint_array(concat([X, X_app]))`` without touching
    the prefix bytes again.

    ``extend`` returns the extended fingerprint, or None when the
    incremental path is unavailable (sampled regime, dtype/width
    mismatch) — callers fall back to ``fingerprint_array`` on the full
    array, which they hold anyway.
    """

    __slots__ = ("shape", "dtype", "nbytes", "_h", "_key")

    def __init__(self, X):
        a = np.asarray(X)
        self.shape = a.shape
        self.dtype = str(a.dtype)
        self.nbytes = a.nbytes
        self._h = (hashlib.sha1(a.tobytes())
                   if a.ndim >= 1 and a.nbytes <= _HASH_SAMPLE_BYTES
                   else None)
        # hexdigest() does not finalize: _h stays extendable.
        self._key = ((self.shape, self.dtype, self._h.hexdigest())
                     if self._h is not None else fingerprint_array(a))

    @property
    def key(self) -> Tuple:
        """== ``fingerprint_array`` of the array this fingerprint covers."""
        return self._key

    def extend(self, X_app) -> Optional["ExtendableFingerprint"]:
        """Fingerprint of ``concat([X, X_app], axis=0)``, hashing only
        ``X_app`` — or None when only a full re-hash can be exact."""
        a = np.asarray(X_app)
        if (self._h is None or str(a.dtype) != self.dtype
                or a.shape[1:] != self.shape[1:]
                or self.nbytes + a.nbytes > _HASH_SAMPLE_BYTES):
            return None
        out = object.__new__(ExtendableFingerprint)
        out.shape = (self.shape[0] + a.shape[0],) + self.shape[1:]
        out.dtype = self.dtype
        out.nbytes = self.nbytes + a.nbytes
        out._h = self._h.copy()
        out._h.update(a.tobytes())
        out._key = (out.shape, out.dtype, out._h.hexdigest())
        return out


def spec_key(spec: SlabSpec) -> Tuple:
    spec = concrete_spec(spec)
    k = spec.kernel
    return (spec.nu1, spec.nu2, spec.eps, k.name, k.gamma, k.coef0,
            k.degree)


def _kwarg_key(v) -> Tuple:
    """Hashable key for one fit kwarg. Arrays (gamma0/f_offset warm
    starts) are content-fingerprinted — their repr truncates with '...'
    and would collide."""
    if isinstance(v, (np.ndarray, jax.Array)):
        return ("array",) + fingerprint_array(v)
    return ("repr", repr(v))


def recipe_key(X, spec: Optional[SlabSpec] = None, *,
               offsets: str = "paper", sv_threshold: float = 1e-7,
               tn: int = 512, precision: str = "f32",
               _fingerprint: Optional[Tuple] = None,
               **fit_kwargs) -> Tuple:
    """The full cache key for one serve recipe.

    Everything that changes the fitted model or its packing takes part:
    the concretized spec, the data fingerprint, the offset policy, the
    pack shape, the precision, and every fit kwarg. ``get_or_fit`` keys
    its entries with this, and the multi-model registry uses the same
    tuple as recipe identity — so "same recipe" means "same cache entry"
    by construction, and ``ModelCache.evict`` can drop exactly the entry
    a registry name resolves to.

    ``_fingerprint`` substitutes a precomputed data fingerprint (e.g.
    an ``ExtendableFingerprint.key`` extended by O(Δm) appended rows)
    for the O(bytes) ``fingerprint_array(X)`` — it MUST equal what
    ``fingerprint_array`` would return or cache identity breaks.
    """
    if spec is None:
        spec = SlabSpec()
    if offsets not in ("paper", "quantile"):
        raise ValueError(f"unknown offsets {offsets!r}; "
                         "expected 'paper' or 'quantile'")
    check_precision(precision)
    fp = fingerprint_array(X) if _fingerprint is None else _fingerprint
    return (spec_key(spec), fp, offsets, sv_threshold,
            tn, precision,
            tuple(sorted((k, _kwarg_key(v)) for k, v in
                         fit_kwargs.items())))


class _InFlight:
    """One in-progress fit: losers of the miss race block on ``done``."""

    __slots__ = ("done", "result", "exc")

    def __init__(self):
        self.done = threading.Event()
        self.result: Optional[ServingModel] = None
        self.exc: Optional[BaseException] = None


class ModelCache:
    """LRU warm-model cache: key = (spec, X fingerprint, precision,
    fit/pack kwargs).

    ``get_or_fit`` is the only entry point; misses fit + pack under the
    per-key cost, hits return the prepared ``ServingModel`` (with its
    memoized scorer and therefore its already-compiled bucket
    executables). Concurrent misses on the SAME key coalesce: the first
    caller runs the fit, later callers block on its in-flight entry and
    get the same model (counted as hits — they never touched the
    solver). If the fit raises, waiters retry the race so the next
    caller becomes the fitter instead of caching the failure.
    ``hits`` / ``misses`` feed the serving benchmark.
    """

    def __init__(self, maxsize: int = 8):
        self.maxsize = maxsize
        self._entries: OrderedDict = OrderedDict()
        self._inflight: dict = {}
        self._gen = 0           # bumped by clear(): stale fits don't insert
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    def lookup(self, key: Tuple) -> Optional[ServingModel]:
        """Warm-path getter by precomputed ``recipe_key``: the cached
        model (counted as a hit, LRU recency refreshed) or None.

        The registry stores each recipe's key at registration, so its
        warm lookups skip ``get_or_fit``'s key recomputation — and with
        it the O(bytes) re-fingerprint of the training data that would
        otherwise tax every routed request. A miss counts nothing;
        callers fall back to ``get_or_fit`` (which coalesces the fit).
        """
        with self._lock:
            served = self._entries.get(key)
            if served is not None:
                self.hits += 1
                self._entries.move_to_end(key)
            return served

    def evict(self, key: Tuple) -> bool:
        """Drop one entry by its ``recipe_key``; True iff it was cached.

        A fit already in flight for the key is not cancelled — its
        waiters still get a model, and it will complete into the cache
        (the key wasn't invalidated, only its current entry dropped).
        Models handed out earlier stay valid: eviction forgets the
        cache's reference, it does not mutate the ``ServingModel``.
        """
        with self._lock:
            return self._entries.pop(key, None) is not None

    def clear(self) -> None:
        """Empty the cache and counters. Fits already in flight cannot be
        cancelled, but they complete into the PRE-clear generation: their
        waiters still get a model, and nothing re-appears in the cleared
        cache."""
        with self._lock:
            self._entries.clear()
            self._inflight.clear()
            self._gen += 1
            self.hits = 0
            self.misses = 0

    def get_or_fit(self, X, spec: Optional[SlabSpec] = None, *,
                   offsets: str = "paper", sv_threshold: float = 1e-7,
                   tn: int = 512, precision: str = "f32",
                   warm_start=None, warm_stats_out: Optional[dict] = None,
                   _key: Optional[Tuple] = None,
                   **fit_kwargs) -> ServingModel:
        """Return a warm ``ServingModel``, fitting on miss.

        offsets: "paper" keeps the solver's margin-SV rho recovery;
        "quantile" applies ``with_quantile_offsets`` (the usable-slab
        variant) before compaction. precision: the one knob for the
        whole pipeline — forwarded to ``repro.fit`` (training Gram
        tiles) AND used to pack the support block for serving; part of
        the cache key. Extra kwargs flow to ``repro.fit`` and take part
        in the cache key.

        ``warm_start`` (a ``SolverArtifact`` from an earlier fit — e.g.
        ``served.artifact``) routes a miss through ``repro.fit_update``:
        the solve is seeded from the prior state over overlapping rows
        instead of starting cold. It is deliberately NOT part of the
        cache key — the seed changes how fast the optimum is reached,
        not (within tolerance) which model comes out, so the same
        (data, spec) must resolve to the same entry however it was
        reached. ``warm_stats_out`` receives ``fit_update``'s overlap /
        mode stats when the warm path actually fits. ``_key`` substitutes
        a precomputed ``recipe_key`` (registry delta-refresh keying).
        """
        if spec is None:
            spec = SlabSpec()
        key = _key if _key is not None else recipe_key(
            X, spec, offsets=offsets, sv_threshold=sv_threshold, tn=tn,
            precision=precision, **fit_kwargs)

        while True:
            with self._lock:
                if key in self._entries:
                    self.hits += 1
                    self._entries.move_to_end(key)
                    return self._entries[key]
                flight = self._inflight.get(key)
                if flight is None:
                    flight = self._inflight[key] = _InFlight()
                    self.misses += 1
                    gen = self._gen
                    break   # this thread owns the fit
            flight.done.wait()
            if flight.exc is None and flight.result is not None:
                with self._lock:
                    self.hits += 1
                return flight.result
            # the fitter failed: loop and race to become the next fitter

        try:
            from repro.api import fit, fit_update
            from repro.core.engine import artifact_from_result
            if warm_start is not None:
                res = fit_update(warm_start, X, spec, precision=precision,
                                 stats_out=warm_stats_out, **fit_kwargs)
            else:
                res = fit(X, spec, precision=precision, **fit_kwargs)
            model = res.model
            if offsets == "quantile":
                model = with_quantile_offsets(model)
            served = pack_model(model, sv_threshold=sv_threshold, tn=tn,
                                precision=precision)
            served.fit_iters = int(res.iters)
            served.artifact = artifact_from_result(res, precision=precision)
        except BaseException as e:
            with self._lock:
                if self._inflight.get(key) is flight:
                    self._inflight.pop(key)
            flight.exc = e
            flight.done.set()
            raise

        with self._lock:
            if self._gen == gen:   # clear() since the miss -> don't insert
                self._entries[key] = served
                self._entries.move_to_end(key)
                while len(self._entries) > self.maxsize:
                    self._entries.popitem(last=False)
            if self._inflight.get(key) is flight:
                self._inflight.pop(key)
        flight.result = served
        flight.done.set()
        return served


_DEFAULT_CACHE = ModelCache()


def default_cache() -> ModelCache:
    """The process-wide cache behind ``repro.serve(...)``."""
    return _DEFAULT_CACHE


def serve(X, spec: Optional[SlabSpec] = None, *,
          cache: Optional[ModelCache] = None, **kwargs) -> ServingModel:
    """Train-then-serve in one engine composition: a warm ``ServingModel``.

    ``repro.serve(X, spec).score(q)`` is the whole serving story; kwargs
    flow to ``ModelCache.get_or_fit`` (offsets/sv_threshold/tn/precision)
    and on to ``repro.fit`` (strategy, gram_mode, interpret, tol, ...).
    ``precision="bf16"`` halves both the training and the serving kernel
    HBM streams (see docs/serving.md, "Precision").
    """
    if cache is None:   # not `or`: an empty cache is len()==0 falsy
        cache = _DEFAULT_CACHE
    return cache.get_or_fit(X, spec, **kwargs)
