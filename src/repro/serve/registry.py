"""Multi-model serving registry: name -> recipe -> warm ``ServingModel``.

The paper's fast SMO makes a slab model cheap enough that the natural
serving unit is a *fleet* of them — one per tenant, stream, or feature
view (the OCSVM-ensemble decomposition line in PAPERS.md routes across
many per-tenant one-class models the same way). The registry is the
name layer of that fleet:

* operators ``register`` a **recipe** — training data + ``SlabSpec`` +
  serve kwargs (precision, offsets, fit kwargs) + an optional per-model
  admission ``quota`` — without paying for a fit;
* callers route by name: ``get(name)`` fits on first use through the
  existing warm ``ModelCache`` and returns the packed ``ServingModel``
  on every later call. Recipe identity IS the cache key
  (``model_cache.recipe_key``), so the cache's per-key in-flight locks
  give the registry its concurrency story for free: N threads racing on
  an unregistered-but-recipe'd name run exactly one fit;
* ``evict`` / ``refresh`` are the lifecycle hooks: evict drops the
  cached model (the next ``get`` re-fits), refresh does it eagerly and
  hands back the re-fitted model. Models already handed out keep
  scoring — eviction forgets a reference, it never mutates a model.

``refresh`` is the streaming hook (docs/streaming.md): called with
``append=`` (new rows for the same name) it updates the recipe in place
— same quota, same serve kwargs, recipe key re-derived in O(Δm) through
an ``ExtendableFingerprint`` instead of re-hashing the whole set — and
routes the re-fit through the cached model's ``SolverArtifact`` as a
warm delta-solve. The warm route is gated by the score-distribution
drift detector (``repro.serve.drift``): appended rows that score far
from the cached slab force a full cold refit instead (a warm seed from
the wrong distribution is misdirection, not a head start). Every
refresh records which way it went in the per-model ``refresh_modes``
counters.

The registry owns *names and recipes only*. Admission — quota
enforcement, deadline-aware window flushing — lives in
``repro.serve.admission`` and reads the per-model ``quota`` recorded
here, so one registry can back any number of admission front-ends.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.ocssvm import SlabSpec
from repro.serve.drift import DEFAULT_THRESHOLD, DriftReport, score_drift
from repro.serve.model_cache import (ExtendableFingerprint, ModelCache,
                                     ServingModel, recipe_key)


class RegistryError(Exception):
    """Base of the registry's typed errors."""


class UnknownModelError(RegistryError, KeyError):
    """Routing to a name no recipe was registered under."""

    def __init__(self, name: str, known: Tuple[str, ...] = ()):
        self.name = name
        self.known = known
        super().__init__(f"no model registered as {name!r}"
                         + (f" (registered: {', '.join(known)})"
                            if known else " (registry is empty)"))


class DuplicateModelError(RegistryError, ValueError):
    """Re-registering a name with a *different* recipe without
    ``replace=True`` — the guard against silently respec'ing a tenant's
    model out from under its traffic."""

    def __init__(self, name: str):
        self.name = name
        super().__init__(
            f"model {name!r} is already registered with a different "
            "recipe; pass replace=True to swap it")


@dataclasses.dataclass(frozen=True)
class ModelRecipe:
    """Everything needed to (re)build one named model, fit deferred.

    ``key`` is the ``ModelCache`` entry this recipe resolves to —
    computed once at registration, reused for identity checks and
    eviction. ``quota`` is the per-model admission budget (rows a
    controller may hold queued for this name; ``None`` = unlimited) —
    recorded here, enforced by ``AdmissionController``.
    """

    name: str
    X: object
    spec: SlabSpec
    quota: Optional[int]
    serve_kwargs: Tuple[Tuple[str, object], ...]
    key: Tuple

    def kwargs(self) -> dict:
        return dict(self.serve_kwargs)


class ModelRegistry:
    """Thread-safe name -> recipe map over one warm ``ModelCache``."""

    def __init__(self, cache: Optional[ModelCache] = None):
        # not `or`: an empty cache is len()==0 falsy. When the registry
        # owns its cache it grows maxsize with the fleet (every recipe
        # is one cache key, so an LRU smaller than the fleet would turn
        # round-robin warm traffic into a fit per request).
        self._own_cache = cache is None
        self.cache = cache if cache is not None else ModelCache()
        self._recipes: Dict[str, ModelRecipe] = {}
        # Per-name lifecycle counter: bumped whenever the model behind a
        # name may change (evict/refresh/replace/unregister), never
        # reset — admission controllers compare it to know when their
        # memoized per-model services went stale.
        self._versions: Dict[str, int] = {}
        # Per-name refresh routing counters ({"warm": n, "cold": n}) and
        # the evidence behind the latest routing decision — operators
        # audit why a refresh refit cold via refresh_stats(name).
        self.refresh_modes: Dict[str, Dict[str, int]] = {}
        self._last_drift: Dict[str, Optional[DriftReport]] = {}
        self._last_warm_stats: Dict[str, Optional[dict]] = {}
        # Per-name extendable data fingerprint: lets an append-refresh
        # re-key the recipe in O(Δm) (built lazily on first append).
        self._fps: Dict[str, ExtendableFingerprint] = {}
        # RLock: register's replace path consults _key_shared under it
        self._lock = threading.RLock()

    # -- registration -------------------------------------------------------
    def register(self, name: str, X, spec: Optional[SlabSpec] = None, *,
                 quota: Optional[int] = None, replace: bool = False,
                 **serve_kwargs) -> ModelRecipe:
        """Record a recipe under ``name``; no fit happens here.

        Registering the same name with an identical recipe is an
        idempotent no-op (so routing entry points may re-register on
        every call); a *different* recipe raises ``DuplicateModelError``
        unless ``replace=True``, which also evicts the old cached model.
        ``quota=None`` on a re-register keeps the existing quota; an
        explicit quota updates it. serve_kwargs flow to
        ``ModelCache.get_or_fit`` (offsets/sv_threshold/tn/precision and
        every fit kwarg) and are part of recipe identity.
        """
        if not name:
            raise ValueError("model name must be a non-empty string")
        if quota is not None and quota < 1:
            raise ValueError(f"quota must be >= 1 rows, got {quota}")
        key = recipe_key(X, spec, **serve_kwargs)
        with self._lock:
            old = self._recipes.get(name)
            if old is not None:
                if old.key == key:
                    if quota is None or quota == old.quota:
                        return old
                    recipe = dataclasses.replace(old, quota=quota)
                    self._recipes[name] = recipe
                    return recipe
                if not replace:
                    raise DuplicateModelError(name)
                if not self._key_shared(old.key, name):
                    self.cache.evict(old.key)
                self._versions[name] = self._versions.get(name, 0) + 1
                self._fps.pop(name, None)   # new data, new fingerprint
                if quota is None:     # replace keeps the quota too
                    quota = old.quota
            recipe = ModelRecipe(
                name=name, X=X,
                spec=spec if spec is not None else SlabSpec(),
                quota=quota,
                serve_kwargs=tuple(sorted(serve_kwargs.items())), key=key)
            self._recipes[name] = recipe
            if self._own_cache and len(self._recipes) > self.cache.maxsize:
                self.cache.maxsize = len(self._recipes)
            return recipe

    def unregister(self, name: str, *, evict: bool = True) -> None:
        """Forget ``name`` (and by default its cached model — unless
        another registered name shares the identical recipe, whose warm
        model must survive)."""
        recipe = self._recipe(name)
        with self._lock:
            self._recipes.pop(name, None)
        if evict and not self._key_shared(recipe.key, name):
            self.cache.evict(recipe.key)
        with self._lock:
            self._versions[name] = self._versions.get(name, 0) + 1
            self._fps.pop(name, None)
            self.refresh_modes.pop(name, None)
            self._last_drift.pop(name, None)
            self._last_warm_stats.pop(name, None)

    # -- routing ------------------------------------------------------------
    def get(self, name: str) -> ServingModel:
        """The warm model for ``name`` — fit-on-first-use via the cache.

        Concurrent first requests coalesce onto one fit through the
        cache's per-key in-flight locks; every later call is a cache hit
        returning the same packed model (and its memoized scorer with
        the already-compiled bucket executables). Warm hits go through
        the precomputed ``recipe.key`` — no per-lookup re-fingerprint
        of the training data.
        """
        recipe = self._recipe(name)
        served = self.cache.lookup(recipe.key)
        if served is not None:
            return served
        return self.cache.get_or_fit(recipe.X, recipe.spec,
                                     **recipe.kwargs())

    def recipe(self, name: str) -> ModelRecipe:
        return self._recipe(name)

    def quota(self, name: str) -> Optional[int]:
        """Per-model admission quota in rows (None = unlimited)."""
        return self._recipe(name).quota

    def set_quota(self, name: str, quota: Optional[int]) -> ModelRecipe:
        """Update the admission quota of an already registered name
        (``None`` lifts it). Quota is operational state, not recipe
        identity — no refit, no version bump."""
        if quota is not None and quota < 1:
            raise ValueError(f"quota must be >= 1 rows, got {quota}")
        with self._lock:
            recipe = self._recipes.get(name)
            if recipe is None:
                raise UnknownModelError(name, tuple(sorted(self._recipes)))
            recipe = dataclasses.replace(recipe, quota=quota)
            self._recipes[name] = recipe
            return recipe

    # -- lifecycle hooks ----------------------------------------------------
    def _key_shared(self, key: Tuple, excluding: str) -> bool:
        """Another registered name resolves to the same cache entry?"""
        with self._lock:
            return any(r.key == key for n, r in self._recipes.items()
                       if n != excluding)

    def evict(self, name: str) -> bool:
        """Drop ``name``'s cached model; the recipe stays and the next
        ``get`` re-fits. True iff a model was dropped. In-flight scores
        against the old model object are unaffected — they hold their
        own reference.

        When another name shares the identical recipe the cache entry
        is NOT dropped (identical recipe == identical model by
        construction, and cold-starting the other name would buy
        nothing); the version still bumps so consumers re-resolve.
        The version bump happens AFTER the cache eviction — a consumer
        racing in between memoizes (old model, old version) at worst,
        which the bump then invalidates; the reverse order could pin
        (old model, new version) forever.
        """
        recipe = self._recipe(name)
        dropped = False
        if not self._key_shared(recipe.key, name):
            dropped = self.cache.evict(recipe.key)
        with self._lock:
            self._versions[name] = self._versions.get(name, 0) + 1
        return dropped

    def version(self, name: str) -> int:
        """Lifecycle counter for ``name`` — changes whenever the model a
        ``get`` would return may differ from earlier (evict, refresh,
        replace, unregister). Consumers that memoize per-model state
        (the admission controller's services) rebuild when it moves."""
        with self._lock:
            return self._versions.get(name, 0)

    def refresh(self, name: str, append=None, *, X=None,
                mode: str = "auto",
                drift_threshold: float = DEFAULT_THRESHOLD) -> ServingModel:
        """Re-fit ``name`` now — warm delta-solve by default; returns
        the fresh model.

        ``append`` adds rows to the recipe's training set (cast to its
        dtype); ``X`` replaces the set outright; neither re-fits on the
        recipe's current data. Either way the recipe is updated in
        place — same name, same ``quota``, same serve kwargs — and the
        admission state layered on top (open windows, observed bucket
        latencies) survives the version bump untouched. Append-refresh
        re-keys the recipe in O(Δm): the cached
        ``ExtendableFingerprint`` hashes only the appended rows.

        Routing: when the cached model carries a ``SolverArtifact``,
        ``mode="auto"`` runs the score-distribution drift detector on
        the candidate set and warm-starts the re-fit from the artifact
        (``fit_update`` through the cache) unless it drifted past
        ``drift_threshold`` — then, and for ``mode="cold"`` or a
        missing artifact, the re-fit runs cold. ``mode="warm"`` skips
        the detector. The decision lands in ``refresh_modes[name]``
        and ``refresh_stats(name)``.
        """
        if mode not in ("auto", "warm", "cold"):
            raise ValueError(f"unknown refresh mode {mode!r}; "
                             "expected 'auto', 'warm' or 'cold'")
        if append is not None and X is not None:
            raise ValueError("pass append= (delta rows) or X= (full "
                             "replacement), not both")
        recipe = self._recipe(name)
        old_key = recipe.key

        fp_new = None
        if append is not None:
            base = np.asarray(recipe.X)
            app = np.asarray(append, base.dtype)
            if app.ndim != base.ndim or app.shape[1:] != base.shape[1:]:
                raise ValueError(
                    f"append rows {app.shape} do not extend the recipe's "
                    f"training set {base.shape}")
            X_new = np.concatenate([base, app])
            with self._lock:
                fp_old = self._fps.get(name)
            if fp_old is None or fp_old.shape != base.shape:
                fp_old = ExtendableFingerprint(base)   # first append: O(m)
            fp_new = fp_old.extend(app)                # O(Δm) from here on
            if fp_new is None:                         # sampled regime
                fp_new = ExtendableFingerprint(X_new)
        elif X is not None:
            X_new = X
            fp_new = ExtendableFingerprint(X_new)
        else:
            X_new = recipe.X

        new_key = old_key if fp_new is None else recipe_key(
            X_new, recipe.spec, _fingerprint=fp_new.key, **recipe.kwargs())

        # The warm seed is the OLD entry's artifact — read it before the
        # eviction below forgets the entry.
        prev = self.cache.lookup(old_key)
        artifact = getattr(prev, "artifact", None)

        report = None
        route = mode
        if artifact is None:
            route = "cold"
        elif mode == "auto":
            # For an append, test the appended rows alone: a strided
            # sample of the full set would dilute a small shifted delta
            # below any threshold. What is new is what can have drifted.
            cand = app if append is not None else X_new
            report = score_drift(artifact, cand, threshold=drift_threshold)
            route = "cold" if report.drifted else "warm"

        with self._lock:
            self._recipes[name] = recipe = dataclasses.replace(
                recipe, X=X_new, key=new_key)
            if fp_new is not None:
                self._fps[name] = fp_new
        # Same ordering contract as evict(): drop the entry, THEN bump —
        # a consumer racing in between memoizes (old model, old version)
        # at worst, which the bump invalidates.
        if not self._key_shared(old_key, name):
            self.cache.evict(old_key)
        with self._lock:
            self._versions[name] = self._versions.get(name, 0) + 1

        warm_stats: Optional[dict] = {} if route == "warm" else None
        served = self.cache.get_or_fit(
            X_new, recipe.spec,
            warm_start=artifact if route == "warm" else None,
            warm_stats_out=warm_stats, _key=new_key, **recipe.kwargs())
        # fit_update falls back cold below its overlap floor — count
        # what actually ran, not what the gate asked for.
        if warm_stats and warm_stats.get("mode") == "cold":
            route = "cold"
        with self._lock:
            counts = self.refresh_modes.setdefault(
                name, {"warm": 0, "cold": 0})
            counts[route] += 1
            self._last_drift[name] = report
            self._last_warm_stats[name] = warm_stats
        return served

    def refresh_stats(self, name: str) -> dict:
        """How this name's refreshes were routed: the ``refresh_modes``
        counters plus the latest drift report and warm-solve stats."""
        self._recipe(name)                  # typed error for unknown names
        with self._lock:
            return {
                "modes": dict(self.refresh_modes.get(
                    name, {"warm": 0, "cold": 0})),
                "last_drift": self._last_drift.get(name),
                "last_warm": self._last_warm_stats.get(name),
            }

    # -- introspection ------------------------------------------------------
    def names(self) -> Tuple[str, ...]:
        with self._lock:
            return tuple(sorted(self._recipes))

    def __contains__(self, name: str) -> bool:
        with self._lock:
            return name in self._recipes

    def __len__(self) -> int:
        with self._lock:
            return len(self._recipes)

    def _recipe(self, name: str) -> ModelRecipe:
        with self._lock:
            recipe = self._recipes.get(name)
        if recipe is None:
            raise UnknownModelError(name, self.names())
        return recipe


_DEFAULT_REGISTRY = ModelRegistry()


def default_registry() -> ModelRegistry:
    """The process-wide registry behind ``repro.serve(..., model=...)``.

    Note it wraps its own ``ModelCache``, separate from
    ``model_cache.default_cache()`` — registry traffic and anonymous
    ``repro.serve(X, spec)`` traffic never evict each other.
    """
    return _DEFAULT_REGISTRY


def serve(X=None, spec: Optional[SlabSpec] = None, *,
          model: Optional[str] = None,
          registry: Optional[ModelRegistry] = None,
          quota: Optional[int] = None, **kwargs):
    """Routed ``repro.serve``: by name through a registry, or anonymous.

    * ``serve(X, spec)`` — the PR-2 path, unchanged: warm-cache
      train-then-serve (kwargs may include ``cache=``).
    * ``serve(X, spec, model="tenant-a")`` — register-or-route: records
      the recipe under the name on first call (idempotent afterwards;
      a *different* recipe under the same name raises
      ``DuplicateModelError``) and returns the registry's warm model.
    * ``serve(model="tenant-a")`` — pure routing to an already
      registered name (``UnknownModelError`` if absent); ``quota=``
      updates the registered recipe's quota, and passing spec/fit
      kwargs here is an error rather than a silent drop (they only
      mean something with ``X``).
    """
    if model is None:
        if X is None:
            raise TypeError("serve() needs X, or model= to route by name")
        if registry is not None or quota is not None:
            raise TypeError("registry=/quota= only apply with model=")
        from repro.serve.model_cache import serve as cache_serve
        return cache_serve(X, spec, **kwargs)
    if "cache" in kwargs:
        raise TypeError("cache= does not apply with model=: the "
                        "registry owns its cache (pass registry=)")
    reg = registry if registry is not None else _DEFAULT_REGISTRY
    if X is not None:
        reg.register(model, X, spec, quota=quota, **kwargs)
        return reg.get(model)
    if spec is not None or kwargs:
        raise TypeError("spec/fit kwargs need X: without data this is a "
                        "pure name lookup, and dropping them silently "
                        "would hide a mis-specified recipe")
    if quota is not None:
        reg.set_quota(model, quota)
    return reg.get(model)
