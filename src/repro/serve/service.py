"""Micro-batching request loop over a ``BatchScorer``.

Scoring cost is dominated by the support-set pass, not the query rows —
so the service coalesces queued requests into one kernel launch: submit
enqueues and returns a handle, ``flush`` concatenates queued rows up to
the top padding bucket, scores the group once, and scatters each slice
back to its handle. Per-bucket latency/throughput counters expose where
the traffic actually lands (the launch CLI and the serving benchmark
print them).

Synchronous by design: this loop is the deterministic core the
admission layer (``repro.serve.admission``) wraps — it decides *when*
to flush, this class decides *what one flush does*. Time enters only
through the injectable ``clock`` (default ``time.monotonic``), so
every latency counter — and every policy built on top of them — is
unit-testable with a fake clock and zero sleeps.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.serve.scorer import BUCKETS, BatchScorer


@dataclasses.dataclass
class BucketStats:
    """Counters for one padding bucket.

    A launch recorded ``cold=True`` (the bucket's first launch on an
    un-warmed executable, which pays trace + compile) is counted in the
    throughput totals but EXCLUDED from ``mean_latency_s`` once any warm
    observation exists — the admission layer's deadline estimates read
    that mean, and one compile-laden sample would make every window
    after a model refresh flush pathologically early.
    """

    batches: int = 0          # kernel launches (cold included)
    queries: int = 0          # live (unpadded) rows scored
    requests: int = 0         # handles served
    total_s: float = 0.0      # summed launch wall-clock (cold included)
    last_s: float = 0.0
    cold_batches: int = 0     # compile-laden launches
    cold_s: float = 0.0       # their summed wall-clock

    def record(self, queries: int, requests: int, dt: float,
               cold: bool = False) -> None:
        """One launch's worth of accounting — flush records each kernel
        launch individually, so a record IS a launch."""
        self.batches += 1
        self.queries += queries
        self.requests += requests
        self.total_s += dt
        self.last_s = dt
        if cold:
            self.cold_batches += 1
            self.cold_s += dt

    @property
    def warm_batches(self) -> int:
        return self.batches - self.cold_batches

    @property
    def mean_latency_s(self) -> float:
        """Mean launch latency for ESTIMATES: warm launches only, unless
        cold launches are all we have (then the cold mean — which
        over-estimates and therefore flushes early, the safe side)."""
        if self.warm_batches > 0:
            return (self.total_s - self.cold_s) / self.warm_batches
        return self.total_s / self.batches if self.batches else 0.0

    @property
    def throughput_qps(self) -> float:
        return self.queries / self.total_s if self.total_s > 0 else 0.0


class Pending:
    """Handle for a submitted request; ``result()`` flushes if needed."""

    def __init__(self, service: "ScoringService", n: int):
        self._service = service
        self.n = n
        self._result = None
        self._done = False
        self._done_cbs: List[Callable[[], None]] = []

    def _set(self, scores) -> None:
        self._result = scores
        self._done = True
        cbs, self._done_cbs = self._done_cbs, []
        for cb in cbs:
            cb()

    def add_done_callback(self, cb: Callable[[], None]) -> None:
        """Run ``cb`` when the scores land (immediately if they already
        have). Callbacks fire on the flushing thread — the async
        admission layer uses this to resolve awaitables without polling."""
        if self._done:
            cb()
        else:
            self._done_cbs.append(cb)

    @property
    def done(self) -> bool:
        return self._done

    def result(self):
        if not self._done:
            self._service.flush()
        return self._result


class ScoringService:
    """Coalesces queued scoring requests into bucket-sized launches."""

    def __init__(self, scorer: BatchScorer, *,
                 max_batch: int = BUCKETS[-1],
                 clock: Callable[[], float] = time.monotonic):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.scorer = scorer
        self.max_batch = max_batch
        # All BucketStats timing goes through this: inject a fake to make
        # latency counters (and the admission policies fed by them)
        # deterministic in tests.
        self.clock = clock
        # deque: flush pops from the head per group — list.pop(0) is
        # O(queue) per pop, O(n^2) to drain a deep queue.
        self._queue: Deque[Tuple] = deque()   # [(q, Pending)]
        self.stats: Dict[int, BucketStats] = {}
        # Guards stats dict *shape* changes vs concurrent iteration: a
        # monitoring thread scraping stats_dict() while a flush files a
        # first-seen bucket must not hit "dict changed size". Single
        # .get() reads stay lock-free (atomic under the GIL).
        self._stats_lock = threading.Lock()
        # Buckets this service has already launched: the FIRST launch of
        # a bucket neither here nor pre-warmed on the scorer pays trace +
        # compile and is recorded cold (excluded from deadline estimates).
        self._launched: set = set()
        # Per-group flush overhead: wall-clock spent OUTSIDE the kernel
        # launches (concat, host transfer, scatter, done callbacks).
        # Roughly fixed per window, so for fast models it dominates the
        # launches — an estimate built from launch means alone would
        # have the admission layer flush too late no matter the safety
        # factor (a multiplier cannot cover an additive cost).
        self.flush_groups: int = 0
        self.flush_overhead_s: float = 0.0

    @property
    def mean_flush_overhead_s(self) -> float:
        """Observed mean non-launch cost of serving one coalesced group
        (0.0 until a flush has run) — the additive term the admission
        layer's deadline estimate charges per window."""
        if self.flush_groups == 0:
            return 0.0
        return self.flush_overhead_s / self.flush_groups

    def warmup(self) -> None:
        """Pre-compile every bucket executable on the path this service
        serves with; launches after a warmup are never recorded cold."""
        self.scorer.warmup()

    @property
    def queued_rows(self) -> int:
        return sum(p.n for _, p in self._queue)

    def submit(self, q) -> Pending:
        """Enqueue one request (n, d), n >= 1; returns its handle."""
        self.scorer._check(q)
        if int(q.shape[0]) < 1:
            raise ValueError("need at least one query row per request")
        p = Pending(self, int(q.shape[0]))
        self._queue.append((q, p))
        return p

    def score(self, q):
        """Submit + flush convenience for a single request."""
        return self.submit(q).result()

    def flush(self) -> int:
        """Drain the queue: group -> one launch per group -> scatter.

        Requests are grouped in arrival order until adding the next one
        would cross ``max_batch`` rows (an oversized single request forms
        its own group; the service scores it chunk by chunk so each
        launch is timed and filed under the bucket it actually used —
        full chunks land in the top bucket, the remainder in its own,
        possibly smaller, bucket). Returns the number of kernel
        launches. Group rows are concatenated host-side (requests arrive
        as host arrays at the service boundary).
        """
        launches = 0
        while self._queue:
            group = [self._queue.popleft()]
            rows = group[0][1].n
            while (self._queue
                   and rows + self._queue[0][1].n <= self.max_batch):
                item = self._queue.popleft()
                group.append(item)
                rows += item[1].n

            t_group = self.clock()
            launch_s = 0.0
            if len(group) == 1:
                batch = np.asarray(group[0][0], np.float32)
            else:
                batch = np.concatenate(
                    [np.asarray(q, np.float32) for q, _ in group])

            # One scorer call per planned launch so every launch's
            # wall-clock and rows are credited to the bucket that really
            # served it (an oversized group spans several; the remainder
            # chunk's bucket can be smaller than the top one). The
            # group's request count is filed with the first launch — a
            # request belongs to one group. The per-chunk sync is the
            # price of honest per-launch timing: an oversized group pays
            # one host-device round-trip per extra chunk, on a path that
            # is already multiple full-bucket kernel launches deep.
            plan = self.scorer.launch_plan(rows)
            launches += len(plan)
            parts = []
            off = 0
            for i, (chunk_rows, bucket) in enumerate(plan):
                cold = (bucket not in self._launched
                        and bucket not in getattr(self.scorer,
                                                  "warmed_buckets", ()))
                self._launched.add(bucket)
                t0 = self.clock()
                part = self.scorer.score(batch[off:off + chunk_rows])
                jax.block_until_ready(part)
                dt = self.clock() - t0
                launch_s += dt
                with self._stats_lock:
                    self.stats.setdefault(bucket, BucketStats()).record(
                        chunk_rows, len(group) if i == 0 else 0, dt,
                        cold=cold)
                # Host-side from here: the launch is already synced (the
                # timing above blocks), and scattering device arrays
                # compiles one slice executable per DISTINCT (offset,
                # length) — under continuous admission the window
                # composition always varies, so that is a fresh compile
                # on nearly every flush, dwarfing the launch it scatters.
                # numpy slices are O(1) views; results are host arrays,
                # symmetric with the host-array request boundary.
                parts.append(np.asarray(part))
                off += chunk_rows
            scores = parts[0] if len(parts) == 1 else np.concatenate(parts)

            off = 0
            for _, p in group:
                p._set(scores[off:off + p.n])
                off += p.n
            with self._stats_lock:
                self.flush_groups += 1
                self.flush_overhead_s += max(
                    0.0, (self.clock() - t_group) - launch_s)
        return launches

    def stats_lines(self) -> List[str]:
        """Human/CSV-ready per-bucket counter lines."""
        with self._stats_lock:
            stats = dict(self.stats)
        lines = []
        for b in sorted(stats):
            s = stats[b]
            lines.append(
                f"bucket={b},batches={s.batches},requests={s.requests},"
                f"queries={s.queries},mean_ms={s.mean_latency_s*1e3:.2f},"
                f"last_ms={s.last_s*1e3:.2f},qps={s.throughput_qps:.0f},"
                f"cold={s.cold_batches}")
        return lines

    def stats_dict(self) -> Dict[int, Dict[str, float]]:
        with self._stats_lock:
            return {b: dataclasses.asdict(s) for b, s in self.stats.items()}


def run_request_stream(service: ScoringService, requests,
                       coalesce: Optional[int] = None) -> List:
    """Feed a request iterable through the service in coalesced windows.

    ``coalesce`` requests are submitted before each flush (default: let
    the queue grow to one full window per flush ~ the micro-batching
    sweet spot). Returns the scores in request order.
    """
    window = coalesce if coalesce is not None else 16
    handles = []
    for i, q in enumerate(requests):
        handles.append(service.submit(q))
        if (i + 1) % window == 0:
            service.flush()
    service.flush()
    return [h.result() for h in handles]
