"""Micro-batching request loop over a ``BatchScorer``.

Scoring cost is dominated by the support-set pass, not the query rows —
so the service coalesces queued requests into one kernel launch: submit
enqueues and returns a handle, ``flush`` concatenates queued rows up to
the top padding bucket, scores the group once, and scatters each slice
back to its handle. Per-bucket latency/throughput counters expose where
the traffic actually lands (the launch CLI and the serving benchmark
print them).

Synchronous by design: this loop is the deterministic core the
admission layer (``repro.serve.admission``) wraps — it decides *when*
to flush, this class decides *what one flush does*. Time enters only
through the injectable ``clock`` (default ``time.monotonic``), so
every latency counter — and every policy built on top of them — is
unit-testable with a fake clock and zero sleeps.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.serve.scorer import BUCKETS, BatchScorer


@dataclasses.dataclass
class BucketStats:
    """Counters for one padding bucket."""

    batches: int = 0          # kernel launches
    queries: int = 0          # live (unpadded) rows scored
    requests: int = 0         # handles served
    total_s: float = 0.0      # summed launch wall-clock
    last_s: float = 0.0

    def record(self, queries: int, requests: int, dt: float) -> None:
        """One launch's worth of accounting — flush records each kernel
        launch individually, so a record IS a launch."""
        self.batches += 1
        self.queries += queries
        self.requests += requests
        self.total_s += dt
        self.last_s = dt

    @property
    def mean_latency_s(self) -> float:
        return self.total_s / self.batches if self.batches else 0.0

    @property
    def throughput_qps(self) -> float:
        return self.queries / self.total_s if self.total_s > 0 else 0.0


class Pending:
    """Handle for a submitted request; ``result()`` flushes if needed."""

    def __init__(self, service: "ScoringService", n: int):
        self._service = service
        self.n = n
        self._result = None
        self._done = False

    def _set(self, scores) -> None:
        self._result = scores
        self._done = True

    @property
    def done(self) -> bool:
        return self._done

    def result(self):
        if not self._done:
            self._service.flush()
        return self._result


class ScoringService:
    """Coalesces queued scoring requests into bucket-sized launches."""

    def __init__(self, scorer: BatchScorer, *,
                 max_batch: int = BUCKETS[-1],
                 clock: Callable[[], float] = time.monotonic):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.scorer = scorer
        self.max_batch = max_batch
        # All BucketStats timing goes through this: inject a fake to make
        # latency counters (and the admission policies fed by them)
        # deterministic in tests.
        self.clock = clock
        # deque: flush pops from the head per group — list.pop(0) is
        # O(queue) per pop, O(n^2) to drain a deep queue.
        self._queue: Deque[Tuple] = deque()   # [(q, Pending)]
        self.stats: Dict[int, BucketStats] = {}
        # Guards stats dict *shape* changes vs concurrent iteration: a
        # monitoring thread scraping stats_dict() while a flush files a
        # first-seen bucket must not hit "dict changed size". Single
        # .get() reads stay lock-free (atomic under the GIL).
        self._stats_lock = threading.Lock()

    @property
    def queued_rows(self) -> int:
        return sum(p.n for _, p in self._queue)

    def submit(self, q) -> Pending:
        """Enqueue one request (n, d), n >= 1; returns its handle."""
        self.scorer._check(q)
        if int(q.shape[0]) < 1:
            raise ValueError("need at least one query row per request")
        p = Pending(self, int(q.shape[0]))
        self._queue.append((q, p))
        return p

    def score(self, q):
        """Submit + flush convenience for a single request."""
        return self.submit(q).result()

    def flush(self) -> int:
        """Drain the queue: group -> one launch per group -> scatter.

        Requests are grouped in arrival order until adding the next one
        would cross ``max_batch`` rows (an oversized single request forms
        its own group; the service scores it chunk by chunk so each
        launch is timed and filed under the bucket it actually used —
        full chunks land in the top bucket, the remainder in its own,
        possibly smaller, bucket). Returns the number of kernel
        launches. Group rows are concatenated host-side (requests arrive
        as host arrays at the service boundary).
        """
        launches = 0
        while self._queue:
            group = [self._queue.popleft()]
            rows = group[0][1].n
            while (self._queue
                   and rows + self._queue[0][1].n <= self.max_batch):
                item = self._queue.popleft()
                group.append(item)
                rows += item[1].n

            if len(group) == 1:
                batch = np.asarray(group[0][0], np.float32)
            else:
                batch = np.concatenate(
                    [np.asarray(q, np.float32) for q, _ in group])

            # One scorer call per planned launch so every launch's
            # wall-clock and rows are credited to the bucket that really
            # served it (an oversized group spans several; the remainder
            # chunk's bucket can be smaller than the top one). The
            # group's request count is filed with the first launch — a
            # request belongs to one group. The per-chunk sync is the
            # price of honest per-launch timing: an oversized group pays
            # one host-device round-trip per extra chunk, on a path that
            # is already multiple full-bucket kernel launches deep.
            plan = self.scorer.launch_plan(rows)
            launches += len(plan)
            parts = []
            off = 0
            for i, (chunk_rows, bucket) in enumerate(plan):
                t0 = self.clock()
                part = self.scorer.score(batch[off:off + chunk_rows])
                jax.block_until_ready(part)
                dt = self.clock() - t0
                with self._stats_lock:
                    self.stats.setdefault(bucket, BucketStats()).record(
                        chunk_rows, len(group) if i == 0 else 0, dt)
                parts.append(part)
                off += chunk_rows
            scores = (parts[0] if len(parts) == 1
                      else jax.numpy.concatenate(parts))

            off = 0
            for _, p in group:
                p._set(scores[off:off + p.n])
                off += p.n
        return launches

    def stats_lines(self) -> List[str]:
        """Human/CSV-ready per-bucket counter lines."""
        with self._stats_lock:
            stats = dict(self.stats)
        lines = []
        for b in sorted(stats):
            s = stats[b]
            lines.append(
                f"bucket={b},batches={s.batches},requests={s.requests},"
                f"queries={s.queries},mean_ms={s.mean_latency_s*1e3:.2f},"
                f"last_ms={s.last_s*1e3:.2f},qps={s.throughput_qps:.0f}")
        return lines

    def stats_dict(self) -> Dict[int, Dict[str, float]]:
        with self._stats_lock:
            return {b: dataclasses.asdict(s) for b, s in self.stats.items()}


def run_request_stream(service: ScoringService, requests,
                       coalesce: Optional[int] = None) -> List:
    """Feed a request iterable through the service in coalesced windows.

    ``coalesce`` requests are submitted before each flush (default: let
    the queue grow to one full window per flush ~ the micro-batching
    sweet spot). Returns the scores in request order.
    """
    window = coalesce if coalesce is not None else 16
    handles = []
    for i, q in enumerate(requests):
        handles.append(service.submit(q))
        if (i + 1) % window == 0:
            service.flush()
    service.flush()
    return [h.result() for h in handles]
