"""repro.serve — the serving subsystem: train-then-serve, one composition.

Five layers, each usable on its own:

* ``model_cache`` — warm-model cache keyed on (SlabSpec, data
  fingerprint); a miss fits via ``repro.fit`` and packs the support set
  for the decision kernel once (``ServingModel``).
* ``scorer``      — ``BatchScorer``: padding buckets (64/256/1024/4096)
  over the Pallas ``decision`` kernel so every request shape hits a
  cached executable; ``mesh=`` flips on the shard_map'd pod-scale path.
* ``service``     — ``ScoringService``: micro-batching request loop with
  per-bucket latency/throughput counters on an injectable clock.
* ``registry``    — ``ModelRegistry``: name -> recipe -> warm model
  routing over the cache, with per-model admission quotas and
  drift-gated streaming ``refresh`` (``drift`` holds the KS detector).
* ``admission``   — ``AdmissionController``: deadline-aware coalescing
  windows in front of ``ScoringService.flush``, typed quota rejection;
  continuous (a flush re-opens the window) with awaitable admission.
* ``async_driver``— ``AsyncDriver``: the background event-loop driver
  that wakes on the earliest pending deadline and polls, plus the
  ``serve_async`` coroutine front door.
* ``shm_registry``— cross-process fleet: packed models published to
  ``multiprocessing.shared_memory`` (refcounted, liveness-pruned) so N
  workers attach — bitwise-identically — to one warm fleet.

The package itself is callable — ``repro.serve(X, spec)`` returns a warm
``ServingModel`` from the default cache, and ``repro.serve(X, spec,
model="tenant-a")`` routes through the default registry — so the
one-line entry point and the subsystem share a single name (see
``_CallableModule`` below).
"""
from __future__ import annotations

import sys as _sys
import types as _types

# model_cache must load first: it pulls repro.core (and through it the
# kernel packages) in the one order that does not trip the
# core <-> kernels import cycle — scorer/admission start from
# repro.kernels directly, which only works once core is fully loaded.
from repro.serve.model_cache import (ExtendableFingerprint, ModelCache,
                                     ServingModel, default_cache,
                                     fingerprint_array, pack_model,
                                     recipe_key, spec_key)
from repro.serve.admission import (AdmissionController, AdmissionHandle,
                                   QuotaExceededError)
from repro.serve.async_driver import (AsyncDriver, DriverCrashed,
                                      default_driver, reset_default_driver,
                                      serve_async)
from repro.serve.shm_registry import (ShmKeyError, ShmLease, attach,
                                      attach_or_publish, live_refs, publish)
from repro.serve.drift import DriftReport, ks_statistic, score_drift
from repro.serve.registry import (DuplicateModelError, ModelRecipe,
                                  ModelRegistry, RegistryError,
                                  UnknownModelError, default_registry, serve)
from repro.serve.scorer import BUCKETS, BatchScorer, bucket_for
from repro.serve.service import (BucketStats, Pending, ScoringService,
                                 run_request_stream)

__all__ = [
    "ExtendableFingerprint", "ModelCache", "ServingModel", "default_cache",
    "fingerprint_array", "pack_model", "recipe_key", "serve", "spec_key",
    "DriftReport", "ks_statistic", "score_drift",
    "BUCKETS", "BatchScorer", "bucket_for",
    "BucketStats", "Pending", "ScoringService", "run_request_stream",
    "DuplicateModelError", "ModelRecipe", "ModelRegistry", "RegistryError",
    "UnknownModelError", "default_registry",
    "AdmissionController", "AdmissionHandle", "QuotaExceededError",
    "AsyncDriver", "DriverCrashed", "default_driver",
    "reset_default_driver", "serve_async",
    "ShmKeyError", "ShmLease", "attach", "attach_or_publish", "live_refs",
    "publish",
]


class _CallableModule(_types.ModuleType):
    """Lets ``repro.serve(X, spec)`` keep working after any
    ``import repro.serve.<submodule>`` binds this module object onto the
    parent package (shadowing the lazy function ``repro.__getattr__``
    would otherwise return)."""

    def __call__(self, X=None, spec=None, **kwargs):
        return serve(X, spec, **kwargs)


_sys.modules[__name__].__class__ = _CallableModule
