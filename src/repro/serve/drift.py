"""Score-distribution drift detector gating warm vs. cold refresh.

A registry refresh with appended data has two routes: a warm delta-solve
(``repro.fit_update`` seeded from the cached ``SolverArtifact``) or a
full cold refit. The warm route is only a shortcut when the new rows
come from roughly the distribution the cached model learned — warm-start
from a model of the *wrong* distribution spends its iteration budget
un-learning the stale support set, and the 25%-of-cold convergence claim
(docs/streaming.md) quietly inverts.

The detector is the cheapest signal that correlates with that failure
mode: score a strided sample of the incoming rows through the cached
support-vector slab (the same expansion the served model scores with —
non-SV rows carry ~zero coefficient, so ``k(q, X_sv) @ gamma_sv`` equals
the full-expansion raw score) and compare the resulting distribution
against the cached f-cache scores of the training rows the model was fit
on, with a two-sample Kolmogorov-Smirnov statistic. In-distribution
appends land inside the cached score distribution (KS small); a shifted
stream scores far from the slab (KS -> 1).

No scipy: the KS statistic is a sort + running-CDF diff in numpy.
Thresholding at ``DEFAULT_THRESHOLD`` is deliberately blunt — the
detector routes a refresh, it does not test a hypothesis; the registry
records which way every refresh went (``refresh_stats``) so an operator
can audit the routing.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["DriftReport", "ks_statistic", "score_drift",
           "DEFAULT_THRESHOLD"]

# KS distance above which a refresh refits cold. Two samples from the
# same continuous distribution at n=512 sit around 0.03-0.12; a mean
# shift of one bandwidth pushes past 0.5. 0.35 splits those regimes
# with slack for small SV slabs.
DEFAULT_THRESHOLD = 0.35


@dataclasses.dataclass(frozen=True)
class DriftReport:
    """One drift decision, with the evidence that produced it."""

    statistic: float    # two-sample KS distance in [0, 1]
    threshold: float
    n_ref: int          # cached-score sample size
    n_new: int          # incoming-row sample size

    @property
    def drifted(self) -> bool:
        return bool(self.statistic > self.threshold)


def ks_statistic(a, b) -> float:
    """Two-sample Kolmogorov-Smirnov distance sup_x |F_a(x) - F_b(x)|.

    Pure numpy: pool both samples, sort once, and take the max gap
    between the two empirical CDFs evaluated over the pooled points.
    """
    a = np.asarray(a, np.float64).ravel()
    b = np.asarray(b, np.float64).ravel()
    if a.size == 0 or b.size == 0:
        raise ValueError("ks_statistic needs non-empty samples")
    pooled = np.concatenate([a, b])
    order = np.argsort(pooled, kind="stable")
    # +1/na steps where the pooled point came from a, -1/nb where from b:
    # the running sum IS F_a - F_b over the pooled support.
    steps = np.where(order < a.size, 1.0 / a.size, -1.0 / b.size)
    return float(np.abs(np.cumsum(steps)).max())


def _strided(x: np.ndarray, cap: int) -> np.ndarray:
    """Deterministic <=cap evenly-strided sample along axis 0."""
    if x.shape[0] <= cap:
        return x
    return x[:: -(-x.shape[0] // cap)]


def score_drift(artifact, X_new, *, threshold: float = DEFAULT_THRESHOLD,
                max_sample: int = 512,
                sv_threshold: float = 1e-7) -> DriftReport:
    """Compare incoming rows' scores against the cached score slab.

    ``artifact`` is the ``SolverArtifact`` of the cached fit; ``X_new``
    the candidate training set of the refresh (typically old rows plus
    a delta — sampling is strided over the whole thing, so a delta big
    enough to matter is big enough to be sampled). Both samples are
    capped at ``max_sample`` rows, so one detector call is O(sample *
    n_sv * d) kernel work — far below even the warm re-solve it guards.
    """
    f = np.asarray(artifact.f, np.float64)
    ref = _strided(f, max_sample)

    sv = artifact.support_mask(sv_threshold)
    if not sv.any():            # degenerate fit: every score is constant
        sv = np.ones_like(sv)
    X_sv = np.asarray(artifact.X, np.float32)[sv]
    g_sv = np.asarray(artifact.gamma, np.float32)[sv]

    q = _strided(np.asarray(X_new, np.float32), max_sample)
    k = artifact.spec.kernel.cross(q, X_sv)
    new_scores = np.asarray(k, np.float64) @ g_sv.astype(np.float64)

    return DriftReport(statistic=ks_statistic(ref, new_scores),
                       threshold=threshold, n_ref=int(ref.shape[0]),
                       n_new=int(new_scores.shape[0]))
