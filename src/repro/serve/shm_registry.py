"""Cross-process model registry: one warm fleet, N attached workers.

A ``ServingModel`` is immutable once packed — exactly the shape POSIX
shared memory serves well. ``publish`` lays the packed kernel operands
(``t_pad``/``gamma_pad``/``t_norms``) and the compacted reference model
(SV rows, dual coefficients, slab offsets) into ONE
``multiprocessing.shared_memory`` segment, keyed by the caller's string
key (``model_cache.recipe_key`` in the registry flow); ``attach``
rebuilds a ``ServingModel`` from the segment without refitting — the
reconstructed arrays are byte-for-byte the published ones, so an
attached worker's scores are **bitwise identical** to the publisher's
(same bytes into the same ``decision_packed`` program).

Beside the segment live two small files in a spool directory
(``$REPRO_SHM_DIR`` or ``<tmp>/repro_shm``), both named by the key's
digest:

* ``<digest>.json``  — the manifest: segment name, per-array
  offset/shape/dtype, and the model metadata (spec, precision, tn, ...);
* ``<digest>.refs``  — the refcount: one pid entry per open lease.

Every mutation of the pair runs under an ``flock`` on ``<digest>.lock``
— advisory file locks are the one primitive that is correct across
unrelated processes and evaporates with its holder. Refcounts are
**liveness-pruned**: every attach/detach drops entries whose pid no
longer exists, so a leader (or any worker) that died without detaching
cannot strand the segment's count — the last LIVE detacher unlinks the
segment and both files. Segments are unregistered from Python's
``resource_tracker`` precisely so they may outlive the process that
created them; the refcount file is what stands in for the tracker.

``attach_or_publish`` is the worker entry point: attach if the fleet is
warm, else build (fit) under a cross-process build lock — so N workers
racing on a cold key pay ONE fit, and the other N-1 block briefly and
attach. POSIX only (flock, pid liveness probes); Windows is out of
scope for this serving stack.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
from contextlib import contextmanager
from pathlib import Path
from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.kernel_fn import KernelFn
from repro.core.ocssvm import OCSSVMModel, SlabSpec
from repro.serve.model_cache import ServingModel

_FORMAT = 1
_ALIGN = 64     # array offsets aligned for clean typed views


class ShmKeyError(KeyError):
    """No published fleet entry for the key (or only a stale manifest
    whose segment is gone — cleaned up on the way out)."""


# -- spool-dir plumbing -------------------------------------------------------
def _spool_dir(dir: Optional[str]) -> Path:
    d = Path(dir or os.environ.get("REPRO_SHM_DIR")
             or Path(tempfile.gettempdir()) / "repro_shm")
    d.mkdir(parents=True, exist_ok=True)
    return d


def _digest(key: str) -> str:
    return hashlib.sha256(key.encode()).hexdigest()[:24]


@contextmanager
def _flock(path: Path):
    import fcntl
    while True:
        f = open(path, "a+")
        try:
            fcntl.flock(f, fcntl.LOCK_EX)
            # The previous holder may have unlinked the lock file after
            # releasing it (last-lease cleanup): a lock held on that
            # dead inode excludes nobody who opens the path fresh.
            # Proceed only if the locked fd still IS the path; retry on
            # the new inode otherwise.
            try:
                st = os.stat(path)
            except FileNotFoundError:
                continue
            fst = os.fstat(f.fileno())
            if (st.st_dev, st.st_ino) != (fst.st_dev, fst.st_ino):
                continue
            yield
            return
        finally:
            try:
                fcntl.flock(f, fcntl.LOCK_UN)
            except OSError:
                pass
            f.close()


def _atomic_write(path: Path, text: str) -> None:
    tmp = path.with_suffix(path.suffix + f".tmp{os.getpid()}")
    tmp.write_text(text)
    os.replace(tmp, path)


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True     # exists, just not ours
    return True


def _read_refs(path: Path) -> list:
    try:
        return [int(p) for p in json.loads(path.read_text())["pids"]]
    except (FileNotFoundError, ValueError, KeyError, TypeError):
        return []


def _tracker_name(shm) -> str:
    return getattr(shm, "_name", "/" + shm.name)


def _untrack(shm) -> None:
    # The resource_tracker unlinks registered segments when the
    # REGISTERING process exits — correct for scratch, fatal for a fleet
    # meant to outlive its publisher. The refcount file replaces it.
    # On POSIX CPython 3.8-3.12 ``SharedMemory.__init__`` registers
    # unconditionally — for ATTACH too, not just create (3.13 added
    # ``track=False``) — so EVERY open path must untrack, or any
    # attached worker's tracker unlinks the segment out from under the
    # surviving leaseholders when that worker's process tree exits.
    try:
        from multiprocessing import resource_tracker
        resource_tracker.unregister(_tracker_name(shm), "shared_memory")
    except Exception:
        pass


def _unlink_segment(shm) -> None:
    # ``SharedMemory.unlink()`` also sends an UNREGISTER to the tracker
    # daemon; every segment here was untracked at open, so the
    # unmatched message would make the daemon print KeyError
    # tracebacks. Re-register just before unlinking so the pair
    # balances (on 3.13+ ``track=False`` handles would skip both).
    if getattr(shm, "_track", True):
        try:
            from multiprocessing import resource_tracker
            resource_tracker.register(_tracker_name(shm), "shared_memory")
        except Exception:
            pass
    try:
        shm.unlink()
    except FileNotFoundError:
        _untrack(shm)   # nothing was unlinked: take the registration back
        raise


# -- leases -------------------------------------------------------------------
@dataclasses.dataclass
class ShmLease:
    """One process's handle on a published fleet entry.

    Holding a lease is what keeps the segment alive: ``close()`` (or the
    context manager) drops this pid's refcount entry and — if no live
    holder remains — unlinks the segment and its manifest/refcount
    files. Safe to close twice.
    """

    key: str
    digest: str
    spool: Path
    _shm: object = dataclasses.field(repr=False)
    closed: bool = False

    def close(self) -> None:
        if self.closed:
            return
        self.closed = True
        man = self.spool / f"{self.digest}.json"
        refs = self.spool / f"{self.digest}.refs"
        with _flock(self.spool / f"{self.digest}.lock"):
            pids = _read_refs(refs)
            me = os.getpid()
            if me in pids:
                pids.remove(me)     # ONE occurrence: leases count
            pids = [p for p in pids if _pid_alive(p)]
            if pids:
                _atomic_write(refs, json.dumps({"pids": pids}))
                self._shm.close()
                return
            # last live holder out turns off the lights
            try:
                _unlink_segment(self._shm)
            except FileNotFoundError:
                pass
            self._shm.close()
            refs.unlink(missing_ok=True)
            man.unlink(missing_ok=True)
            # The lock file goes INSIDE the lock: retiring the inode
            # while holding it is what makes _flock's revalidation
            # sound — a contender that flocked the dying inode sees the
            # path changed under it and retries on the fresh file, so
            # no two holders ever pass revalidation concurrently.
            (self.spool / f"{self.digest}.lock").unlink(missing_ok=True)

    def __enter__(self) -> "ShmLease":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):      # best effort; explicit close is the API
        try:
            self.close()
        except Exception:
            pass


# -- pack / unpack ------------------------------------------------------------
def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes     # bfloat16 et al. (ships with jax)
        return np.dtype(getattr(ml_dtypes, name))


def _host_arrays(sm: ServingModel) -> Dict[str, np.ndarray]:
    """The byte-carrying views of a packed model, in manifest order."""
    return {
        "t_pad": np.asarray(sm.t_pad),
        "gamma_pad": np.asarray(sm.gamma_pad, np.float32),
        "t_norms": np.asarray(sm.t_norms, np.float32),
        "sv_gamma": np.asarray(sm.model.gamma, np.float32),
        "sv_X": np.asarray(sm.model.X, np.float32),
        "rho": np.stack([np.asarray(sm.model.rho1, np.float32),
                         np.asarray(sm.model.rho2, np.float32)]),
    }


def _manifest_meta(sm: ServingModel) -> dict:
    k = sm.spec.kernel
    return {
        "n_sv": int(sm.n_sv), "tn": int(sm.tn),
        "precision": sm.precision, "fit_iters": int(sm.fit_iters),
        "spec": {"nu1": float(sm.spec.nu1), "nu2": float(sm.spec.nu2),
                 "eps": float(sm.spec.eps),
                 "kernel": {"name": k.name, "gamma": float(k.gamma),
                            "coef0": float(k.coef0),
                            "degree": int(k.degree)}},
    }


def _model_from(manifest: dict, buf) -> ServingModel:
    arrs: Dict[str, jnp.ndarray] = {}
    for name, a in manifest["arrays"].items():
        dt = _np_dtype(a["dtype"])
        count = int(np.prod(a["shape"])) if a["shape"] else 1
        view = np.frombuffer(buf, dtype=dt, count=count,
                             offset=a["offset"]).reshape(a["shape"])
        # .copy() is load-bearing: on CPU jnp.asarray can ALIAS a numpy
        # buffer, which would pin exported pointers into the mmap and
        # make the lease's close() raise BufferError. The bytes land
        # verbatim either way (same dtype, no cast) — the bitwise-parity
        # guarantee.
        arrs[name] = jnp.asarray(view.copy())
    meta = manifest["meta"]
    spec = SlabSpec(nu1=meta["spec"]["nu1"], nu2=meta["spec"]["nu2"],
                    eps=meta["spec"]["eps"],
                    kernel=KernelFn(**meta["spec"]["kernel"]))
    model = OCSSVMModel(gamma=arrs["sv_gamma"], rho1=arrs["rho"][0],
                        rho2=arrs["rho"][1], X=arrs["sv_X"], spec=spec)
    return ServingModel(model=model, t_pad=arrs["t_pad"],
                        gamma_pad=arrs["gamma_pad"],
                        t_norms=arrs["t_norms"], n_sv=meta["n_sv"],
                        tn=meta["tn"], spec=spec,
                        precision=meta["precision"],
                        fit_iters=meta["fit_iters"])


# -- the store ----------------------------------------------------------------
def publish(sm: ServingModel, key: str, *,
            dir: Optional[str] = None) -> ShmLease:
    """Lay ``sm`` into shared memory under ``key``; returns the
    publisher's lease. Idempotent: publishing an already-published key
    just takes another lease on the existing segment (first writer
    wins — the key is a content fingerprint in the registry flow, so
    "same key" means "same bytes")."""
    from multiprocessing import shared_memory

    spool = _spool_dir(dir)
    dig = _digest(key)
    man_path = spool / f"{dig}.json"
    refs_path = spool / f"{dig}.refs"
    with _flock(spool / f"{dig}.lock"):
        existing = _attach_segment(man_path)
        if existing is not None:
            shm = existing
        else:
            arrays = _host_arrays(sm)
            offsets, total = {}, 0
            for name, a in arrays.items():
                total = -(-total // _ALIGN) * _ALIGN
                offsets[name] = total
                total += a.nbytes
            try:
                shm = shared_memory.SharedMemory(
                    create=True, size=max(total, 1), name=f"repro_{dig}")
            except FileExistsError:
                # orphan segment with no (usable) manifest — a publisher
                # crashed between shm_open and the manifest write.
                # Reclaim: unlink the corpse and recreate.
                stale = shared_memory.SharedMemory(name=f"repro_{dig}")
                stale.unlink()
                stale.close()
                shm = shared_memory.SharedMemory(
                    create=True, size=max(total, 1), name=f"repro_{dig}")
            _untrack(shm)
            for name, a in arrays.items():
                o = offsets[name]
                shm.buf[o:o + a.nbytes] = a.tobytes()
            manifest = {
                "format": _FORMAT, "key": key, "segment": shm.name,
                "nbytes": total, "meta": _manifest_meta(sm),
                "arrays": {n: {"offset": offsets[n],
                               "shape": list(a.shape),
                               "dtype": str(a.dtype)}
                           for n, a in arrays.items()},
            }
            _atomic_write(man_path, json.dumps(manifest, indent=1))
        _add_ref(refs_path)
    return ShmLease(key=key, digest=dig, spool=spool, _shm=shm)


def attach(key: str, *,
           dir: Optional[str] = None) -> Tuple[ServingModel, ShmLease]:
    """Rebuild the ``ServingModel`` published under ``key`` from shared
    memory (no fit). Raises ``ShmKeyError`` when nothing (healthy) is
    published. Hold the returned lease for the worker's lifetime."""
    spool = _spool_dir(dir)
    dig = _digest(key)
    man_path = spool / f"{dig}.json"
    refs_path = spool / f"{dig}.refs"
    with _flock(spool / f"{dig}.lock"):
        shm = _attach_segment(man_path)
        if shm is None:
            # stale manifest (segment gone: publisher machine-rebooted
            # or unlinked out-of-band) — clean up so publish can retry
            man_path.unlink(missing_ok=True)
            refs_path.unlink(missing_ok=True)
            raise ShmKeyError(key)
        manifest = json.loads(man_path.read_text())
        model = _model_from(manifest, shm.buf)
        _add_ref(refs_path)
    return model, ShmLease(key=key, digest=dig, spool=spool, _shm=shm)


def attach_or_publish(key: str, build: Callable[[], ServingModel], *,
                      dir: Optional[str] = None
                      ) -> Tuple[ServingModel, ShmLease]:
    """Attach if warm, else ``build()`` (the fit) and publish.

    The build runs under a separate cross-process lock, so N workers
    racing on a cold key pay exactly one fit: the winner fits while the
    rest block on the lock, then attach. The build lock is distinct
    from the store lock — a fit is seconds-long and must not block
    attaches/detaches of OTHER keys' leases (the store lock is per-key
    anyway) or health probes of this one.
    """
    spool = _spool_dir(dir)
    dig = _digest(key)
    try:
        return attach(key, dir=dir)
    except ShmKeyError:
        pass
    with _flock(spool / f"{dig}.build.lock"):
        try:        # a racer may have published while we waited
            return attach(key, dir=dir)
        except ShmKeyError:
            sm = build()
            lease = publish(sm, key, dir=dir)
            return sm, lease


def live_refs(key: str, *, dir: Optional[str] = None) -> int:
    """How many LIVE processes hold leases on ``key`` (dead pids are
    pruned from the count but only rewritten by attach/detach)."""
    spool = _spool_dir(dir)
    refs = _read_refs(spool / f"{_digest(key)}.refs")
    return sum(1 for p in refs if _pid_alive(p))


def _add_ref(refs_path: Path) -> None:
    # caller holds the store flock
    pids = [p for p in _read_refs(refs_path) if _pid_alive(p)]
    pids.append(os.getpid())
    _atomic_write(refs_path, json.dumps({"pids": pids}))


def _attach_segment(man_path: Path):
    """The manifest's segment, attached and untracked — or None when
    there is no (usable) publication. Caller holds the store flock."""
    from multiprocessing import shared_memory

    try:
        manifest = json.loads(man_path.read_text())
    except (FileNotFoundError, ValueError):
        return None
    try:
        shm = shared_memory.SharedMemory(name=manifest["segment"])
    except FileNotFoundError:
        return None
    _untrack(shm)   # attach REGISTERS on 3.8-3.12 too — see _untrack
    return shm
