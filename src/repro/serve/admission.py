"""Deadline-aware admission: coalescing windows in front of the service.

The synchronous ``ScoringService`` loop is the deterministic core —
it decides what one flush does. This layer decides *when* a flush
happens, per model, from three signals:

* **bucket fill** — a model's open window reaching ``max_batch`` rows
  flushes immediately at submit time (more coalescing can't help: the
  next row would start a second launch anyway);
* **deadline pressure** — ``poll()`` flushes a window when waiting any
  longer would miss its earliest deadline, *given the observed
  per-bucket latency* from that model's ``BucketStats``: the window is
  due once ``now + estimated_flush_latency >= earliest_deadline``.
  Buckets never observed cost ``fallback_latency_s`` (default 0.0 =
  coalesce maximally until evidence arrives);
* **explicit** — ``flush_model`` / ``drain`` / ``handle.result()``;
* **dead deadline** — a submit onto a window whose earliest deadline has
  ALREADY passed (or whose own deadline passed while the model's
  first-use fit ran) flushes inline at submit time: queueing behind a
  dead deadline would otherwise wait for the next ``poll()``, which
  under real traffic may never come (the event-loop driver in
  ``repro.serve.async_driver`` exists so one does, but correctness must
  not depend on it).

Windows are **continuous**: a flush pops the model's window and a
concurrent submit immediately opens the next one — late arrivals join
the next launch instead of blocking on the in-flight one (admission
takes only the short state lock once the model's service is warm; the
per-model lock serializes the launches, not the queueing). Per-model
window occupancy counters (``windows opened/flushed``, rows and
requests per flush) ride ``stats_dict``.

Requests carry ``(model, deadline)``; over-quota traffic (the
registry's per-model ``quota``, in rows held queued) is rejected at
submit with the typed ``QuotaExceededError`` — a full window sheds load
instead of growing an unbounded backlog.

Awaitable admission: ``submit_async`` resolves an ``asyncio`` future
when the batch lands (no busy-wait on ``Pending``); the background
``AsyncDriver`` wakes on ``next_due_time()`` via the ``add_waker`` hook
and calls ``poll()`` so deadlines are honored with nobody polling.

Time enters ONLY through the injected ``clock`` (default
``time.monotonic``), shared with every per-model ``ScoringService`` the
controller builds — so every policy decision (``due``, latency
estimates, deadline ordering) is unit-testable with a fake clock and no
sleeps. Deadlines are absolute times on that clock.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time
import warnings
from typing import Callable, Dict, List, Optional, Tuple

from repro.serve.scorer import BUCKETS
from repro.serve.service import Pending, ScoringService


class QuotaExceededError(RuntimeError):
    """Typed rejection: admitting the request would hold more rows
    queued for the model than its registered quota allows."""

    def __init__(self, model: str, quota: int, queued_rows: int,
                 requested_rows: int):
        self.model = model
        self.quota = quota
        self.queued_rows = queued_rows
        self.requested_rows = requested_rows
        super().__init__(
            f"model {model!r}: admitting {requested_rows} rows onto "
            f"{queued_rows} already queued would exceed the quota of "
            f"{quota} rows")


class AdmissionHandle:
    """Handle for one admitted request.

    ``result()`` forces the owning model's window if the controller has
    not flushed it yet — the synchronous escape hatch, mirroring
    ``Pending.result``.
    """

    def __init__(self, controller: "AdmissionController", model: str,
                 n: int, deadline: Optional[float]):
        self._controller = controller
        self.model = model
        self.n = n
        self.deadline = deadline
        self._pending: Optional[Pending] = None
        self._error: Optional[BaseException] = None
        self._cb_lock = threading.Lock()
        self._done_cbs: List[Callable[["AdmissionHandle"], None]] = []

    # -- completion plumbing (flush thread side) ----------------------------
    def _bind(self, pending: Pending) -> None:
        # chains the service handle's completion to ours, so a flush —
        # whoever runs it — resolves awaitables without any polling
        self._pending = pending
        pending.add_done_callback(self._fire)

    def _fail(self, exc: BaseException) -> None:
        self._error = exc
        self._fire()

    def _fire(self) -> None:
        with self._cb_lock:
            cbs, self._done_cbs = self._done_cbs, []
        for cb in cbs:
            cb(self)

    def add_done_callback(
            self, cb: Callable[["AdmissionHandle"], None]) -> None:
        """Run ``cb(handle)`` once the request resolves — with scores or
        with a flush-time error (immediately if it already has).
        Callbacks fire on whichever thread completes the flush."""
        with self._cb_lock:
            if not self.done:
                self._done_cbs.append(cb)
                return
        cb(self)

    @property
    def flushed(self) -> bool:
        """The request has left the admission window for the service."""
        return self._pending is not None

    @property
    def done(self) -> bool:
        """Resolved — with scores, or with a flush-time error that
        ``result()`` will raise (e.g. the recipe was replaced with an
        incompatible feature dim after this request was admitted)."""
        if self._error is not None:
            return True
        return self._pending is not None and self._pending.done

    def result(self):
        # Route through the controller (model lock) whenever the score
        # isn't ready — not only when un-flushed. If another thread is
        # mid-flush (_pending bound, launches still running), going
        # straight to Pending.result() would re-enter the non-thread-
        # safe service flush; flush_model instead blocks on the model
        # lock until that flush completes.
        if not self.done:
            self._controller.flush_model(self.model)
        if self._error is not None:
            raise self._error
        return self._pending.result()


class _Window:
    """One model's open coalescing window."""

    __slots__ = ("items", "rows", "earliest_deadline", "opened_at")

    def __init__(self, now: float):
        self.items: List[Tuple[object, AdmissionHandle]] = []
        self.rows = 0
        self.earliest_deadline = math.inf
        self.opened_at = now


@dataclasses.dataclass
class _WindowStats:
    """Per-model window occupancy: how full launches actually run.

    ``opened``/``flushed`` count windows; ``flushed_rows`` over
    ``flushed`` gives the mean fill a flush ships (against ``max_batch``
    that is the coalescing efficiency). ``inline_flushes`` counts
    dead-deadline submits (window flushed at submit time because its
    earliest deadline had already passed); ``aborted`` counts requests
    failed by ``abort_pending`` (driver crash surfacing).
    """

    opened: int = 0
    flushed: int = 0
    flushed_rows: int = 0
    flushed_requests: int = 0
    max_rows: int = 0
    inline_flushes: int = 0
    aborted: int = 0


class AdmissionController:
    """Per-model deadline-aware windows over per-model scoring services.

    ``registry`` is anything with ``get(name) -> ServingModel`` and
    ``quota(name) -> Optional[int]`` — a ``ModelRegistry`` in
    production, a stub in tests. Services are built lazily per model
    (first submit for a name pays that name's fit-on-first-use through
    the registry) and share the controller's injected ``clock``; if the
    registry exposes a ``version(name)`` lifecycle counter (the real
    one does), a version bump — evict/refresh/replace — rebuilds the
    memoized service, so post-refresh traffic scores against the fresh
    model instead of a stale scorer.

    Locking is two-level so the fleet never serializes on one model:
    a short controller-wide state lock guards the window/service maps,
    and a per-model lock serializes the expensive work — fit-on-first-
    use and the actual kernel launches of a flush. One model's cold fit
    or slow launch never blocks another model's admission.

    ``safety_factor`` scales latency estimates (>1 flushes earlier than
    the point estimate says is necessary); ``max_wait_s`` bounds how
    long a *deadline-less* window may sit open before ``poll`` flushes
    it (None = only bucket fill / explicit flushes move it; windows
    with deadlines are governed by deadline pressure alone).

    Note the quota/bucket-fill interaction: quota bounds rows that
    would *remain* queued, and an admission that reaches ``max_batch``
    flushes the window instead of growing it — a rejection therefore
    needs ``quota < queued_rows < max_batch``, so only quotas of at
    most ``max_batch - 2`` can ever bind; the controller warns once per
    model when a registered quota cannot.
    """

    def __init__(self, registry, *,
                 clock: Callable[[], float] = time.monotonic,
                 max_batch: int = BUCKETS[-1],
                 fallback_latency_s: float = 0.0,
                 safety_factor: float = 1.0,
                 max_wait_s: Optional[float] = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if safety_factor <= 0:
            raise ValueError(f"safety_factor must be > 0, "
                             f"got {safety_factor}")
        self.registry = registry
        self.clock = clock
        self.max_batch = max_batch
        self.fallback_latency_s = fallback_latency_s
        self.safety_factor = safety_factor
        self.max_wait_s = max_wait_s
        self._services: Dict[str, ScoringService] = {}
        self._service_versions: Dict[str, int] = {}
        self._windows: Dict[str, _Window] = {}
        self._model_locks: Dict[str, threading.RLock] = {}
        self._quota_warned: set = set()
        self.rejected: Dict[str, int] = {}
        self._window_stats: Dict[str, _WindowStats] = {}
        # Wakers: zero-arg callables poked after every admission that
        # leaves a window open — the async driver registers one so a new
        # (possibly earlier) deadline re-arms its sleep immediately.
        self._wakers: List[Callable[[], None]] = []
        # Short state lock (window/service/counter maps only — never
        # held across a fit or a kernel launch). RLock: policy helpers
        # re-enter it from poll()/due().
        self._lock = threading.RLock()

    # -- locking ------------------------------------------------------------
    def _model_lock(self, model: str) -> threading.RLock:
        with self._lock:
            lk = self._model_locks.get(model)
            if lk is None:
                lk = self._model_locks[model] = threading.RLock()
            return lk

    def _registry_version(self, model: str) -> int:
        version = getattr(self.registry, "version", None)
        return version(model) if version is not None else 0

    # -- services -----------------------------------------------------------
    def service(self, model: str) -> ScoringService:
        """The model's scoring service (built on first use — this is
        where an unfitted registered recipe pays its one fit, under the
        MODEL's lock only). Rebuilt when the registry's lifecycle
        version for the name moves (evict/refresh/replace) — but the
        old service's observed per-bucket latencies carry over: a
        refresh swaps the model weights, not the launch cost of a
        bucket, and resetting the estimates to ``fallback_latency_s``
        would blind the deadline policy right after every refresh."""
        # Fast path first, WITHOUT the model lock: a memoized service at
        # the current registry version is an immutable read, and taking
        # the model lock here would stall every warm submit behind an
        # in-flight flush's kernel launches — the opposite of continuous
        # admission.
        ver = self._registry_version(model)
        with self._lock:
            svc = self._services.get(model)
            if svc is not None \
                    and self._service_versions.get(model) == ver:
                return svc
        with self._model_lock(model):
            ver = self._registry_version(model)
            with self._lock:
                svc = self._services.get(model)
                if svc is not None \
                        and self._service_versions.get(model) == ver:
                    return svc
            old = svc
            sm = self.registry.get(model)    # may fit: no state lock held
            svc = ScoringService(sm.scorer(), max_batch=self.max_batch,
                                 clock=self.clock)
            if old is not None:
                with old._stats_lock:
                    svc.stats = dict(old.stats)
            self._warn_unbindable_quota(model)
            with self._lock:
                self._services[model] = svc
                self._service_versions[model] = ver
            return svc

    def _warn_unbindable_quota(self, model: str,
                               quota: Optional[int] = None) -> None:
        # A rejection needs quota < rows+n < max_batch (reaching
        # max_batch flushes instead), so a binding quota satisfies
        # quota <= max_batch - 2; anything above can never reject.
        if quota is None:
            quota = self.registry.quota(model)
        if quota is None or quota <= self.max_batch - 2:
            return
        with self._lock:
            if model in self._quota_warned:
                return
            self._quota_warned.add(model)
        warnings.warn(
            f"model {model!r}: quota {quota} rows cannot bind with "
            f"max_batch {self.max_batch} — rejection needs "
            f"quota < queued_rows < max_batch, and any admission "
            f"reaching max_batch triggers the bucket-fill flush first; "
            f"set quota <= {self.max_batch - 2} to shed load",
            RuntimeWarning, stacklevel=3)

    # -- admission ----------------------------------------------------------
    def submit(self, model: str, q, *,
               deadline: Optional[float] = None) -> AdmissionHandle:
        """Admit one request for ``model``; returns its handle.

        ``deadline`` is an absolute time on the controller's clock by
        which the caller wants the request *served* (None = indifferent:
        the request rides whatever flush its window gets). Raises
        ``QuotaExceededError`` when admitting would leave more rows
        *queued* than the model's quota — an admission that immediately
        triggers the bucket-fill flush drains the window instead of
        growing it, so it can never breach the quota. Routing errors
        (``UnknownModelError``) surface from the registry unchanged.

        Admission is continuous: once the model's service is warm, the
        append runs under the short state lock only, so submits land in
        the NEXT window while a flush's launches are still running under
        the model lock. A submit onto a window whose earliest deadline
        has already passed flushes it inline (see module docstring —
        correctness must not depend on anyone polling).
        """
        if getattr(q, "ndim", None) != 2:
            raise ValueError(f"queries must be (n, d), got "
                             f"{getattr(q, 'shape', q)}")
        n = int(q.shape[0])
        if n < 1:
            raise ValueError("need at least one query row per request")
        # Admission decisions run BEFORE the service is resolved: a
        # rejected request must not pay (or trigger) the model's
        # fit-on-first-use. registry.quota also routes, so unknown
        # names fail here, cheaply.
        quota = self.registry.quota(model)
        # re-checked per submit: set_quota() after the service was
        # memoized must still trip the one-time unbindable warning
        self._warn_unbindable_quota(model, quota)
        with self._lock:
            win = self._windows.get(model)
            rows = win.rows if win is not None else 0
        if quota is not None and rows + n < self.max_batch \
                and rows + n > quota:
            with self._lock:
                self.rejected[model] = self.rejected.get(model, 0) + 1
            raise QuotaExceededError(model, quota, rows, n)
        svc = self.service(model)       # memoized fast path: no model lock
        svc.scorer._check(q)            # feature dim needs the model
        handle = AdmissionHandle(self, model, n, deadline)
        with self._lock:
            # The append — and the quota re-check, which must be atomic
            # with it now that admission races flushes — runs under the
            # state lock only. A concurrent flush pops the window under
            # this same lock, so this submit either rides the outgoing
            # window or opens the next one; it never waits for launches.
            win = self._windows.get(model)
            rows = win.rows if win is not None else 0
            full = rows + n >= self.max_batch   # admit -> instant flush
            if quota is not None and not full and rows + n > quota:
                self.rejected[model] = self.rejected.get(model, 0) + 1
                raise QuotaExceededError(model, quota, rows, n)
            if win is None:
                # no window is created for a rejected request (above):
                # an empty one would backdate the next admitted
                # request's age under max_wait_s
                win = self._windows[model] = _Window(self.clock())
                self._wstats(model).opened += 1
            win.items.append((q, handle))
            win.rows += n
            if deadline is not None:
                win.earliest_deadline = min(win.earliest_deadline,
                                            deadline)
            # Dead deadline: already passed — possibly while THIS call
            # paid the model's fit-on-first-use above. Queueing behind
            # it would wait for a poll() that may never come.
            dead = win.earliest_deadline <= self.clock()
            if dead:
                self._wstats(model).inline_flushes += 1
        if full or dead:
            self.flush_model(model)
        else:
            self._notify_wakers()
        return handle

    def queued_rows(self, model: str) -> int:
        """Rows currently held in the model's open window."""
        with self._lock:
            win = self._windows.get(model)
            return win.rows if win is not None else 0

    def _wstats(self, model: str) -> _WindowStats:
        # caller holds self._lock
        ws = self._window_stats.get(model)
        if ws is None:
            ws = self._window_stats[model] = _WindowStats()
        return ws

    def submit_async(self, model: str, q, *,
                     deadline: Optional[float] = None):
        """Awaitable admission: like ``submit`` but returns an
        ``asyncio`` future that resolves with the scores when the batch
        lands (or raises the flush-time error).

        Must be called from a running event loop (the future is bound to
        it; completion hops threads via ``call_soon_threadsafe`` — the
        flush runs wherever the driver or a poller runs). Admission-time
        errors (quota, routing, shape) still raise synchronously, before
        any future exists: they are the caller's bug or back-pressure
        signal, not a batch outcome. Nothing here flushes: pair with a
        running ``AsyncDriver`` (or explicit polling) or the future may
        never resolve.
        """
        import asyncio

        loop = asyncio.get_running_loop()
        fut = loop.create_future()
        handle = self.submit(model, q, deadline=deadline)

        def _on_done(h: AdmissionHandle) -> None:
            err, pending = h._error, h._pending

            def _apply() -> None:
                if fut.cancelled():
                    return
                if err is not None:
                    fut.set_exception(err)
                else:
                    fut.set_result(pending.result())  # done: no flush

            loop.call_soon_threadsafe(_apply)

        handle.add_done_callback(_on_done)
        return fut

    # -- driver hooks --------------------------------------------------------
    def add_waker(self, waker: Callable[[], None]) -> None:
        """Register a zero-arg callable poked after every admission that
        leaves a window open — the driver's re-arm signal."""
        with self._lock:
            self._wakers.append(waker)

    def remove_waker(self, waker: Callable[[], None]) -> None:
        with self._lock:
            try:
                self._wakers.remove(waker)
            except ValueError:
                pass

    def _notify_wakers(self) -> None:
        with self._lock:
            wakers = list(self._wakers)
        for w in wakers:        # outside the lock: wakers take their own
            w()

    def next_due_time(self) -> Optional[float]:
        """Earliest clock time any open window becomes due on its own —
        the driver sleeps until then. None when no window can (empty
        fleet, or deadline-less windows with no ``max_wait_s`` bound:
        only bucket fill or an explicit flush moves those)."""
        with self._lock:
            t: Optional[float] = None
            now = self.clock()
            for m, win in self._windows.items():
                if not win.items:
                    continue
                if win.rows >= self.max_batch:
                    cand = now                  # already due
                elif math.isfinite(win.earliest_deadline):
                    cand = win.earliest_deadline \
                        - self.estimate_latency_s(m)
                elif self.max_wait_s is not None:
                    cand = win.opened_at + self.max_wait_s
                else:
                    continue
                t = cand if t is None else min(t, cand)
            return t

    def abort_pending(self, exc: BaseException) -> int:
        """Fail every queued (un-flushed) request with ``exc``; returns
        how many were failed. The driver calls this when it dies with
        windows still open: a crashed driver must surface to awaiting
        callers, not strand them on futures that never resolve. Handles
        raise ``exc`` from ``result()``; in-flight flushes (already
        popped) complete normally."""
        with self._lock:
            wins = dict(self._windows)
            self._windows.clear()
            for m, win in wins.items():
                self._wstats(m).aborted += len(win.items)
        failed = 0
        for win in wins.values():
            for _, h in win.items:
                h._fail(exc)
                failed += 1
        return failed

    # -- policy -------------------------------------------------------------
    def estimate_latency_s(self, model: str,
                           rows: Optional[int] = None) -> float:
        """Expected wall-clock to serve ``rows`` (default: the model's
        current window) if flushed now.

        Sums the observed mean latency of each launch the scorer's
        ``launch_plan`` predicts, read from the service's per-bucket
        ``BucketStats``, plus the service's observed per-window flush
        overhead (concat/scatter/callbacks — roughly fixed per window,
        so for a fast model it dominates the launches and no
        multiplicative margin could cover it); a bucket with no
        observations yet costs ``fallback_latency_s``. Scaled by
        ``safety_factor``.
        """
        with self._lock:
            svc = self._services.get(model)
            if rows is None:
                rows = self.queued_rows(model)
            if rows <= 0:
                return 0.0
            if svc is None:
                return self.fallback_latency_s * self.safety_factor
            total = svc.mean_flush_overhead_s
            for _, bucket in svc.scorer.launch_plan(rows):
                s = svc.stats.get(bucket)
                total += (s.mean_latency_s if s is not None and s.batches
                          else self.fallback_latency_s)
        return total * self.safety_factor

    def due(self, model: str, now: Optional[float] = None) -> bool:
        """Should ``model``'s window flush now?

        True when the window is at capacity or under deadline pressure:
        flushing takes ``estimate_latency_s``, so once
        ``now + estimate >= earliest_deadline`` any further coalescing
        would miss the deadline. ``max_wait_s`` applies only to windows
        with NO deadline — a deadline is a stronger statement of when
        the caller needs the rows, and the age bound must not override
        it by flushing early.
        """
        with self._lock:
            win = self._windows.get(model)
            if win is None or not win.items:
                return False
            if win.rows >= self.max_batch:
                return True
            if now is None:
                now = self.clock()
            if math.isfinite(win.earliest_deadline):
                return now + self.estimate_latency_s(model) \
                    >= win.earliest_deadline
            return (self.max_wait_s is not None
                    and now - win.opened_at >= self.max_wait_s)

    # -- flushing -----------------------------------------------------------
    def poll(self) -> int:
        """Flush every due window, earliest deadline first; returns the
        number of kernel launches. Call this from the serving loop
        between arrivals — it never blocks on anything but the launches
        themselves (and on no other model's lock: the due list is taken
        under the short state lock, the launches run per model)."""
        with self._lock:
            now = self.clock()
            due = [m for m in list(self._windows) if self.due(m, now)]
            due.sort(key=lambda m: (self._windows[m].earliest_deadline, m))
        return sum(self.flush_model(m) for m in due)

    def flush_model(self, model: str) -> int:
        """Flush one model's window unconditionally."""
        with self._model_lock(model):
            return self._flush_under_model_lock(model)

    def drain(self) -> int:
        """Flush everything (earliest deadline first) — end of stream."""
        with self._lock:
            order = sorted(
                self._windows,
                key=lambda m: (self._windows[m].earliest_deadline, m))
        return sum(self.flush_model(m) for m in order)

    def _flush_under_model_lock(self, model: str) -> int:
        # caller holds this model's lock, so no one else can mutate this
        # model's window or service underneath us. Resolve the service
        # BEFORE popping the window: if it raises (the name was
        # unregistered between submit and flush, or a post-evict re-fit
        # failed), the window — and every queued request in it — stays
        # intact, the error surfaces to the caller, and a later flush
        # can still serve the handles once the name is healthy again.
        with self._lock:
            win = self._windows.get(model)
            if win is None or not win.items:
                return 0
        svc = self.service(model)
        with self._lock:
            win = self._windows.pop(model, None)
            if win is not None and win.items:
                # occupancy is recorded at the pop — the instant the
                # window closes and the next one can open
                ws = self._wstats(model)
                ws.flushed += 1
                ws.flushed_rows += win.rows
                ws.flushed_requests += len(win.items)
                ws.max_rows = max(ws.max_rows, win.rows)
        if win is None or not win.items:
            return 0
        for q, handle in win.items:
            try:
                handle._bind(svc.submit(q))
            except Exception as e:
                # Exception, NOT BaseException: KeyboardInterrupt/
                # SystemExit must stop the loop, not be filed away.
                # This request is permanently unservable against the
                # CURRENT model (admission validated against the old one
                # before a replace): fail ITS handle — result() raises —
                # and keep serving the rest of the window. Raising here
                # would abort poll()'s loop over other healthy models.
                handle._fail(e)
        if all(h._pending is None for _, h in win.items):
            return 0
        return svc.flush()

    def forget(self, model: str) -> None:
        """Release every per-model structure for a retired name: the
        memoized service (and with it the packed model buffers the
        scorer pins), window, lock, and counters.

        The open window is flushed first so nothing queued is silently
        dropped — call this BEFORE ``registry.unregister`` (or after a
        ``drain``), while the name still resolves. Without it a
        long-lived controller over a churning fleet would pin each
        retired tenant's packed support set forever.
        """
        with self._model_lock(model):
            self._flush_under_model_lock(model)
            with self._lock:
                self._services.pop(model, None)
                self._service_versions.pop(model, None)
                self._windows.pop(model, None)
                self.rejected.pop(model, None)
                self._window_stats.pop(model, None)
                self._quota_warned.discard(model)
                # the lock entry itself stays: popping it while another
                # thread is blocked on it would let a later submit mint
                # a second lock and run two "model-locked" sections
                # concurrently on one service. An RLock per name ever
                # seen is noise next to the model buffers released above.

    # -- introspection ------------------------------------------------------
    def _stat_names(self) -> List[str]:
        # every name the controller has state for — a model whose only
        # traffic was rejected (service never resolved, by design: a
        # reject must not pay the fit) still shows its shed load
        with self._lock:
            return sorted(set(self._services) | set(self._windows)
                          | set(self.rejected) | set(self._window_stats))

    def stats_dict(self) -> Dict[str, dict]:
        """Per-model stats: the service's per-bucket counters plus the
        window occupancy / rejection state — the multi-model BENCH JSON
        shape."""
        with self._lock:
            return {
                m: {"buckets": (self._services[m].stats_dict()
                                if m in self._services else {}),
                    "queued_rows": self.queued_rows(m),
                    "rejected": self.rejected.get(m, 0),
                    "windows": dataclasses.asdict(
                        self._window_stats.get(m, _WindowStats()))}
                for m in self._stat_names()
            }

    def stats_lines(self) -> List[str]:
        lines = []
        with self._lock:
            for m in self._stat_names():
                rej = self.rejected.get(m, 0)
                ws = self._window_stats.get(m, _WindowStats())
                fill = (ws.flushed_rows / ws.flushed) if ws.flushed else 0.0
                lines.append(f"model={m},queued_rows={self.queued_rows(m)},"
                             f"rejected={rej},windows={ws.flushed}/"
                             f"{ws.opened},mean_fill_rows={fill:.1f}")
                svc = self._services.get(m)
                if svc is not None:
                    lines.extend("  " + ln for ln in svc.stats_lines())
        return lines
