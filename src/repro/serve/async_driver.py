"""Event-loop admission driver: deadlines honored with nobody polling.

``AdmissionController.poll()`` is pull-only — before this module, a
window's deadline was honored only if some caller happened to poll in
time. ``AsyncDriver`` closes that hole with one background daemon
thread that sleeps until the EARLIEST time any open window becomes due
(``controller.next_due_time()``), wakes, polls, and re-arms. It is
event-driven, not interval-polling: with no deadline pending the driver
parks indefinitely, and every admission pokes it through the
controller's waker hook so a new (possibly earlier) deadline re-arms
the sleep immediately.

A daemon *thread*, not an asyncio task, on purpose: a flush runs kernel
launches and blocks on device completion — parked on an event loop that
would freeze every coroutine between launches. The asyncio side only
ever parks on futures (``submit_async`` / ``serve_async``); completion
hops back to the loop via ``call_soon_threadsafe``.

Lifecycle: ``start()`` → traffic → ``stop()`` (drains open windows by
default, so nothing admitted is silently dropped). If the driver thread
dies — poll raised, service rebuild failed, anything — the crash does
not vanish into a dead thread: every queued request is failed with
``DriverCrashed`` (awaiters see it raised from their future /
``result()``), and the next ``stop()``/``check()`` re-raises it on the
caller's thread.

Fake clocks: the driver sleeps in *clock deltas* interpreted as wall
seconds. Under the test fake clock real sleeps are meaningless, so
tests drive the driver through the waker (every submit pokes it) and
``step()`` — the single poll the thread loop runs, exposed for
deterministic use.
"""
from __future__ import annotations

import threading
from typing import Optional, Tuple

from repro.serve.admission import AdmissionController


class DriverCrashed(RuntimeError):
    """The background admission driver died.

    Raised from pending handles/futures the driver aborted on its way
    down, and re-raised by ``stop()``/``check()``. ``cause`` is the
    exception that killed the driver thread.
    """

    def __init__(self, cause: BaseException):
        self.cause = cause
        super().__init__(f"admission driver crashed: {cause!r}")


class AsyncDriver:
    """Background deadline-wake poller over one ``AdmissionController``.

    Usable as a context manager (``with AsyncDriver(ctrl):`` starts it
    and stops-with-drain on exit). One driver per controller: two
    drivers would double-poll harmlessly but pointlessly.
    """

    def __init__(self, controller: AdmissionController, *,
                 name: str = "repro-admission-driver"):
        self.controller = controller
        self.name = name
        self._thread: Optional[threading.Thread] = None
        self._cond = threading.Condition()
        self._stop_flag = False
        self._poke = False
        self._crash: Optional[DriverCrashed] = None

    # -- lifecycle -----------------------------------------------------------
    @property
    def alive(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    @property
    def crashed(self) -> Optional[DriverCrashed]:
        return self._crash

    def check(self) -> None:
        """Raise the driver's crash on the calling thread, if it had one
        — the liveness probe for long-running servers."""
        if self._crash is not None:
            raise self._crash

    def start(self) -> "AsyncDriver":
        if self.alive:
            raise RuntimeError(f"driver {self.name!r} already running")
        self.check()    # a crashed driver's state explains itself; no
        #                 silent restart over an un-diagnosed corpse
        self._stop_flag = False
        self._poke = False
        self.controller.add_waker(self._wake)
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=self.name)
        self._thread.start()
        return self

    def stop(self, drain: bool = True,
             timeout: Optional[float] = 30.0) -> None:
        """Stop the driver thread; by default drain every open window
        first-class (nothing admitted is dropped). Re-raises a crash."""
        with self._cond:
            self._stop_flag = True
            self._cond.notify_all()
        t = self._thread
        if t is not None:
            t.join(timeout)
        self.controller.remove_waker(self._wake)
        self.check()
        if drain:
            self.controller.drain()

    def __enter__(self) -> "AsyncDriver":
        return self.start()

    def __exit__(self, *exc) -> None:
        # a crash raised here would mask the body's exception; prefer
        # the body's, fall back to the crash (still on .crashed)
        body_failed = exc and exc[0] is not None
        try:
            self.stop(drain=not body_failed)
        except DriverCrashed:
            if not body_failed:
                raise

    # -- the loop ------------------------------------------------------------
    def _wake(self) -> None:
        with self._cond:
            self._poke = True
            self._cond.notify_all()

    def step(self) -> int:
        """One driver iteration's worth of flushing: poll every due
        window. Exposed for fake-clock tests (advance clock, step,
        assert) — the thread loop calls exactly this."""
        return self.controller.poll()

    def _run(self) -> None:
        ctrl = self.controller
        try:
            while True:
                t = ctrl.next_due_time()
                now = ctrl.clock()
                if t is not None and t <= now:
                    self.step()
                    continue
                with self._cond:
                    if self._stop_flag:
                        return
                    if self._poke:
                        # a submit landed after next_due_time() was
                        # computed: recompute before sleeping, or we
                        # could sleep straight past its deadline
                        self._poke = False
                        continue
                    if t is None:
                        self._cond.wait()           # park: nothing can
                        #                             become due on its own
                    else:
                        self._cond.wait(timeout=max(0.0, t - now))
                    if self._stop_flag:
                        return
                    self._poke = False
        except BaseException as e:     # noqa: BLE001 — the whole point:
            #   any escape kills the thread, and that MUST surface
            crash = DriverCrashed(e)
            self._crash = crash
            ctrl.abort_pending(crash)


# -- process-default fleet ----------------------------------------------------
_default_lock = threading.Lock()
_default: Optional[Tuple[AdmissionController, AsyncDriver]] = None


def default_driver(registry=None, **controller_kwargs
                   ) -> Tuple[AdmissionController, AsyncDriver]:
    """The process-default (controller, running driver) pair, built
    lazily over ``default_registry()`` (or ``registry``) on first use.
    ``controller_kwargs`` only apply to that first build."""
    global _default
    with _default_lock:
        if _default is None:
            if registry is None:
                from repro.serve.registry import default_registry
                registry = default_registry()
            ctrl = AdmissionController(registry, **controller_kwargs)
            _default = (ctrl, AsyncDriver(ctrl).start())
        return _default


def reset_default_driver() -> None:
    """Stop and discard the process-default pair (tests; fork hygiene
    before spawning shm workers — the driver thread does not survive a
    fork)."""
    global _default
    with _default_lock:
        pair, _default = _default, None
    if pair is not None:
        pair[1].stop(drain=True)


async def serve_async(model: str, q, *,
                      deadline: Optional[float] = None,
                      controller: Optional[AdmissionController] = None):
    """Score ``q`` against registered ``model``, asynchronously.

    The coroutine front door: admission happens synchronously on the
    calling loop thread (quota/routing errors raise here), then the
    caller awaits the batch instead of busy-waiting on ``Pending`` —
    the background driver (the process-default one unless a
    ``controller`` with its own driver is passed) flushes when the
    window fills or the deadline demands it. ``deadline`` is absolute on
    the controller's clock, like ``submit``.
    """
    if controller is None:
        controller, _ = default_driver()
    return await controller.submit_async(model, q, deadline=deadline)
