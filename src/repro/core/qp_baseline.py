"""Generic QP baselines the paper compares SMO against.

Two solvers for  min 1/2 gamma^T K gamma  s.t.  lo <= gamma <= hi,
sum(gamma) = 1 - eps:

* ``fista`` — accelerated projected gradient with the exact Euclidean
  projection onto {box  ∩  hyperplane} (bisection on the shift multiplier);
  Lipschitz constant from power iteration on K. This stands in for the
  "traditional QP solver" timing baseline (weakly-polynomial interior /
  active-set methods do not fit a jit; FISTA is the strongest JAX-native
  generic baseline and converges to the same optimum of the convex QP).
* ``pgd`` — plain projected gradient (no momentum), for ablation.

Both are O(m^2) per iteration (full Kgamma matvec) vs SMO's O(m) — the
scaling gap the paper's Table 1 demonstrates.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.ocssvm import SlabSpec, feasible_init

Array = jax.Array


def project_box_hyperplane(v: Array, lo: float, hi: float, total: float,
                           iters: int = 64) -> Array:
    """Euclidean projection of v onto {lo<=x<=hi, sum(x)=total}.

    Solves sum(clip(v - lam, lo, hi)) = total by bisection (monotone in lam).
    """
    lam_lo = jnp.min(v) - hi
    lam_hi = jnp.max(v) - lo

    def body(_, carry):
        a, b = carry
        mid = 0.5 * (a + b)
        s = jnp.sum(jnp.clip(v - mid, lo, hi))
        too_big = s > total  # need larger lam
        return (jnp.where(too_big, mid, a), jnp.where(too_big, b, mid))

    a, b = jax.lax.fori_loop(0, iters, body, (lam_lo, lam_hi))
    lam = 0.5 * (a + b)
    return jnp.clip(v - lam, lo, hi)


def _power_iteration(K: Array, iters: int = 30) -> Array:
    m = K.shape[0]
    u = jnp.ones((m,), K.dtype) / jnp.sqrt(m)

    def body(_, u):
        w = K @ u
        return w / jnp.maximum(jnp.linalg.norm(w), 1e-30)

    u = jax.lax.fori_loop(0, iters, body, u)
    return jnp.maximum(u @ (K @ u), 1e-12)


class QPResult(NamedTuple):
    gamma: Array
    objective: Array
    iters: Array


@partial(jax.jit, static_argnames=("max_iters", "tol", "accelerate"))
def solve_qp(X: Array, spec: SlabSpec, *, max_iters: int = 5000,
             tol: float = 1e-8, accelerate: bool = True) -> QPResult:
    """FISTA / PGD on the reduced dual with a precomputed Gram matrix."""
    m = X.shape[0]
    Xf = X.astype(jnp.float32)
    K = spec.kernel.gram(Xf)
    lo, hi, total = spec.lower(m), spec.upper(m), spec.total()
    L = _power_iteration(K)
    step = 1.0 / L

    g0 = feasible_init(m, spec)

    def obj(g):
        return 0.5 * g @ (K @ g)

    def body(carry):
        g, y, t, it, _ = carry
        g_new = project_box_hyperplane(y - step * (K @ y), lo, hi, total)
        if accelerate:
            t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
            y_new = g_new + ((t - 1.0) / t_new) * (g_new - g)
        else:
            t_new, y_new = t, g_new
        delta = jnp.max(jnp.abs(g_new - g))
        return (g_new, y_new, t_new, it + 1, delta)

    def cond(carry):
        _, _, _, it, delta = carry
        return (it < max_iters) & (delta > tol)

    init = (g0, g0, jnp.ones(()), jnp.zeros((), jnp.int32),
            jnp.asarray(jnp.inf))
    g, _, _, it, _ = jax.lax.while_loop(cond, body, init)
    return QPResult(gamma=g, objective=obj(g), iters=it)
