"""Paper-faithful SMO for the One-Class Slab SVM (Algorithm 1).

Thin facade over ``repro.core.engine``: one violating pair per iteration,
updated analytically (eq. 35-39), with the f-cache maintained by a rank-2
update and rho1/rho2 re-estimated from on-margin SVs every step
(eq. 20-21).

Two working-set selections:

* ``selection="paper"`` — the paper's heuristic (eq. 56):
  b = argmax |f_bar(x_b)| among KKT violators, a = argmax
  |f_bar(x_b) - f_bar(x_a)|, with partners whose clipped step would be
  zero masked out (see ``engine.select.PaperSelector``).
* ``selection="mvp"`` — Keerthi-style maximal-violating-pair on the
  reduced dual; converged when the duality gap <= tol. Needs no rho
  estimate for selection, so it is immune to early rho oscillation.

Both reach the same optimum (tests assert objective parity with the QP
baseline). The whole solve is a single ``jax.lax.while_loop``.

Gram strategies: ``precomputed`` (materialize K once; small m),
``on_the_fly`` (recompute the needed kernel rows per iteration; O(m d)
per step, no m^2 memory), or ``pallas`` (the fused fupdate kernel).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.engine.gram import raw_scores_blocked  # re-export (compat)
from repro.core.engine.types import SMOResult
from repro.core.ocssvm import (OCSSVMModel, SlabSpec, concrete_spec,
                               feasible_init)

Array = jax.Array

__all__ = ["solve", "SMOResult", "raw_scores_blocked"]


def solve(
    X: Array,
    spec: SlabSpec,
    *,
    gram_mode: str = "precomputed",
    selection: str = "paper",
    interpret: Optional[bool] = None,
    precision: str = "f32",
    tol: float = 1e-4,
    max_iters: int = 200_000,
    patience: int = 20,
    gamma0: Optional[Array] = None,
) -> SMOResult:
    """Run Algorithm 1 until <=1 KKT violator (paper) / gap<=tol (mvp).

    The spec normally stays a traced pytree (one compile covers a whole
    hyper-parameter sweep); only the Pallas provider must specialize on
    concrete kernel parameters, so gram_mode="pallas" hashes a
    concretized spec as a static argument instead. ``interpret``
    force-overrides the Pallas provider's interpret-mode autodetection
    (None -> interpret off-TPU). ``precision`` ("f32"/"bf16"/"f16") is
    the Gram tile-input dtype (``repro.kernels.precision``).
    """
    kw = dict(gram_mode=gram_mode, selection=selection, interpret=interpret,
              precision=precision, tol=tol, max_iters=max_iters,
              patience=patience, gamma0=gamma0)
    if gram_mode == "pallas":
        return _solve_static(X, concrete_spec(spec), **kw)
    return _solve_traced(X, spec, **kw)


def _solve_impl(
    X: Array,
    spec: SlabSpec,
    *,
    gram_mode: str,
    selection: str,
    interpret: Optional[bool],
    precision: str,
    tol: float,
    max_iters: int,
    patience: int,
    gamma0: Optional[Array],
) -> SMOResult:
    m, _ = X.shape
    Xf = X.astype(jnp.float32)
    hi, lo = spec.upper(m), spec.lower(m)

    gamma = (feasible_init(m, spec, jnp.float32) if gamma0 is None
             else gamma0.astype(jnp.float32))

    provider = engine.make_provider(gram_mode, Xf, spec.kernel,
                                    interpret=interpret, precision=precision)
    selector = engine.make_selector(selection, provider, P=1, hi=hi, lo=lo,
                                    m=m, tol=tol)
    stats_fn = partial(engine.solver_stats_fresh, hi=hi, lo=lo, m=m, tol=tol)

    state0 = engine.init_state(provider, stats_fn, gamma)
    s = engine.run(provider, selector, stats_fn, state0, hi=hi, lo=lo,
                   tol=tol, max_iters=max_iters, patience=patience)

    model = OCSSVMModel(gamma=s.gamma, rho1=s.rho1, rho2=s.rho2, X=Xf,
                        spec=spec)
    return SMOResult(model=model, iters=s.it, n_viol=s.n_viol,
                     max_viol=s.max_viol, gap=s.gap,
                     converged=engine.has_converged(s, selector.criterion,
                                                    tol),
                     f=s.f)


_SOLVE_STATIC = ("gram_mode", "selection", "interpret", "precision", "tol",
                 "max_iters", "patience")
_solve_traced = partial(jax.jit, static_argnames=_SOLVE_STATIC)(_solve_impl)
_solve_static = partial(jax.jit,
                        static_argnames=_SOLVE_STATIC + ("spec",))(_solve_impl)
