"""Paper-faithful SMO for the One-Class Slab SVM (Algorithm 1).

One violating pair per iteration, updated analytically (eq. 35-39), with the
f-cache maintained by a rank-2 update and rho1/rho2 re-estimated from
on-margin SVs every step (eq. 20-21).

Two working-set selections:

* ``selection="paper"`` — the paper's heuristic (eq. 56):
  b = argmax |f_bar(x_b)| among KKT violators, a = argmax
  |f_bar(x_b) - f_bar(x_a)|.  We additionally mask partners ``a`` whose
  clipped step would be zero (the paper's rule implicitly assumes the pair
  can move; without the mask the iteration deadlocks on bound-blocked
  pairs — Platt's original resolves this with fallback example sweeps).
* ``selection="mvp"`` — Keerthi-style maximal-violating-pair on the reduced
  dual: b = argmin{f_i : gamma_i < hi}, a = argmax{f_j : gamma_j > lo};
  converged when f_a - f_b <= tol.  Needs no rho estimate, so it is immune
  to early rho oscillation; used as the fast default at scale.

Both reach the same optimum (tests assert objective parity with the QP
baseline). The whole solve is a single ``jax.lax.while_loop`` — the carried
state is a pytree, so a solve can be checkpointed/restarted mid-optimization.

Gram strategies: ``precomputed`` (materialize K once; small m) or
``on_the_fly`` (recompute the <=3 needed kernel rows per iteration from X;
O(m d) per step, no m^2 memory — the Pallas ``fupdate`` path on TPU).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.kernel_fn import KernelFn
from repro.core.kkt import slab_margin, violation
from repro.core.ocssvm import (OCSSVMModel, SlabSpec, feasible_init,
                               recover_rhos)

Array = jax.Array


class SMOState(NamedTuple):
    gamma: Array      # (m,)
    f: Array          # (m,) raw scores K @ gamma
    rho1: Array
    rho2: Array
    it: Array         # int32 iteration counter
    n_viol: Array     # int32 current KKT violator count
    max_viol: Array   # float max violation
    gap: Array        # float MVP duality gap  max f|down - min f|up
    stall: Array      # int32 consecutive no-progress steps


class SMOResult(NamedTuple):
    model: OCSSVMModel
    iters: Array
    n_viol: Array
    max_viol: Array
    gap: Array
    converged: Array


def raw_scores_blocked(X: Array, gamma: Array, kernel: KernelFn,
                       block: int = 2048) -> Array:
    """K @ gamma without materializing K (row-blocked)."""
    m = X.shape[0]
    if m <= block:
        return kernel.cross(X, X) @ gamma
    nblk = (m + block - 1) // block
    pad = nblk * block - m
    Xp = jnp.pad(X, ((0, pad), (0, 0)))

    def body(i, acc):
        xb = jax.lax.dynamic_slice_in_dim(Xp, i * block, block)
        return jax.lax.dynamic_update_slice_in_dim(
            acc, kernel.cross(xb, X) @ gamma, i * block, 0)

    out = jax.lax.fori_loop(0, nblk, body, jnp.zeros((nblk * block,), gamma.dtype))
    return out[:m]


@partial(jax.jit, static_argnames=("gram_mode", "selection", "tol",
                                   "max_iters", "patience"))
def solve(
    X: Array,
    spec: SlabSpec,
    *,
    gram_mode: str = "precomputed",
    selection: str = "paper",
    tol: float = 1e-4,
    max_iters: int = 200_000,
    patience: int = 20,
    gamma0: Optional[Array] = None,
) -> SMOResult:
    """Run Algorithm 1 until <=1 KKT violator (paper) / gap<=tol (mvp)."""
    m, _ = X.shape
    kernel = spec.kernel
    dtype = jnp.float32
    Xf = X.astype(dtype)

    gamma = feasible_init(m, spec, dtype) if gamma0 is None else gamma0.astype(dtype)

    K = kernel.gram(Xf) if gram_mode == "precomputed" else None
    diagK = kernel.diag(Xf)
    f = (K @ gamma) if K is not None else raw_scores_blocked(Xf, gamma, kernel)
    rho1, rho2 = recover_rhos(gamma, f, spec)

    hi = spec.upper(m)
    lo = spec.lower(m)
    bnd = 1e-8 * (hi - lo)          # bound-identification slack
    tiny = jnp.asarray(1e-12, dtype)
    neg = jnp.asarray(-jnp.inf, dtype)
    pos = jnp.asarray(jnp.inf, dtype)

    def krow(idx):
        if K is not None:
            return K[:, idx]
        return kernel.rows(Xf, Xf[idx][None, :])[:, 0]

    def diagnostics(gamma, f, rho1, rho2):
        v = violation(gamma, f, rho1, rho2, spec)
        up = gamma < hi - bnd       # can increase
        dn = gamma > lo + bnd       # can decrease
        gap = jnp.max(jnp.where(dn, f, neg)) - jnp.min(jnp.where(up, f, pos))
        return v, gap

    v0, gap0 = diagnostics(gamma, f, rho1, rho2)
    state = SMOState(gamma, f, rho1, rho2,
                     jnp.zeros((), jnp.int32),
                     jnp.sum(v0 > tol).astype(jnp.int32),
                     jnp.max(v0), gap0, jnp.zeros((), jnp.int32))

    def not_done(s: SMOState):
        if selection == "mvp":
            unconverged = s.gap > tol
        else:
            # Paper: "until at most one variable doesn't satisfy KKT";
            # also accept a uniformly-small violation (same optimum).
            unconverged = (s.n_viol > 1) & (s.max_viol > tol)
        return (s.it < max_iters) & unconverged & (s.stall < patience)

    def select_paper(s: SMOState):
        v, _ = diagnostics(s.gamma, s.f, s.rho1, s.rho2)
        fbar = slab_margin(s.f, s.rho1, s.rho2)
        b = jnp.argmax(jnp.where(v > tol, jnp.abs(fbar), neg))
        # Candidate step size against every partner a (needs row b).
        kb = krow(b)
        eta_den = jnp.maximum(diagK + diagK[b] - 2.0 * kb, tiny)
        t = s.gamma + s.gamma[b]
        L = jnp.maximum(t - hi, lo)
        H = jnp.minimum(hi, t - lo)
        gb_t = s.gamma[b] + (s.f - s.f[b]) / eta_den
        movable = jnp.abs(jnp.clip(gb_t, L, H) - s.gamma[b]) > tiny * 10
        gap_score = jnp.where(movable, jnp.abs(fbar[b] - fbar), neg)
        gap_score = gap_score.at[b].set(neg)
        a = jnp.argmax(gap_score)
        return a, b, kb

    def select_mvp(s: SMOState):
        up = s.gamma < hi - bnd
        dn = s.gamma > lo + bnd
        b = jnp.argmin(jnp.where(up, s.f, pos))   # grows: smallest score
        a = jnp.argmax(jnp.where(dn, s.f, neg))   # shrinks: largest score
        return a, b, krow(b)

    def body(s: SMOState):
        a, b, kb = select_paper(s) if selection == "paper" else select_mvp(s)
        ka = krow(a)

        eta = 1.0 / jnp.maximum(diagK[a] + diagK[b] - 2.0 * kb[a], tiny)
        ga, gb = s.gamma[a], s.gamma[b]
        t = ga + gb
        L = jnp.maximum(t - hi, lo)
        H = jnp.minimum(hi, t - lo)
        gb_new = jnp.clip(gb + eta * (s.f[a] - s.f[b]), L, H)   # eq. 35/38/39
        ga_new = t - gb_new                                      # eq. 37
        dgb = gb_new - gb

        gamma_new = s.gamma.at[a].set(ga_new).at[b].set(gb_new)
        f_new = s.f + dgb * (kb - ka)
        r1, r2 = recover_rhos(gamma_new, f_new, spec)

        v_new, gap_new = diagnostics(gamma_new, f_new, r1, r2)
        progressed = jnp.abs(dgb) > tiny * 10
        stall = jnp.where(progressed, 0, s.stall + 1).astype(jnp.int32)
        return SMOState(gamma_new, f_new, r1, r2, s.it + 1,
                        jnp.sum(v_new > tol).astype(jnp.int32),
                        jnp.max(v_new), gap_new, stall)

    s = jax.lax.while_loop(not_done, body, state)
    model = OCSSVMModel(gamma=s.gamma, rho1=s.rho1, rho2=s.rho2, X=Xf, spec=spec)
    if selection == "mvp":
        conv = s.gap <= tol
    else:
        conv = (s.n_viol <= 1) | (s.max_viol <= tol)
    return SMOResult(model=model, iters=s.it, n_viol=s.n_viol,
                     max_viol=s.max_viol, gap=s.gap, converged=conv)
