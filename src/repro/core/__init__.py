"""repro.core — the paper's contribution: OCSSVM + fast SMO training.

All solvers are facades over the pluggable engine in
``repro.core.engine`` (GramProvider x Selector x one while-loop driver);
``repro.fit`` picks the composition automatically.
"""
from repro.core import engine
from repro.core.kernel_fn import KernelFn, linear, poly, rbf
from repro.core.ocssvm import (OCSSVMModel, SlabSpec, compact_support,
                               dual_objective, feasible_init, recover_rhos,
                               with_quantile_offsets)
from repro.core.kkt import slab_margin, violation, n_violators, converged
from repro.core.smo import SMOResult, solve as solve_smo
from repro.core.batched_smo import solve_blocked
from repro.core.shrinking import (solve_blocked_shrinking,
                                  solve_sharded_shrinking)
from repro.core.qp_baseline import QPResult, project_box_hyperplane, solve_qp
from repro.core.mcc import mcc
from repro.core.head import FittedHead, fit_head, pool_features
from repro.core.distributed_smo import (sharded_raw_scores,
                                        solve_blocked_distributed)

__all__ = [
    "engine",
    "KernelFn", "linear", "rbf", "poly",
    "OCSSVMModel", "SlabSpec", "compact_support", "dual_objective",
    "feasible_init",
    "recover_rhos", "slab_margin", "violation", "n_violators", "converged",
    "SMOResult", "solve_smo", "solve_blocked", "solve_blocked_shrinking",
    "solve_sharded_shrinking", "solve_blocked_distributed",
    "sharded_raw_scores", "with_quantile_offsets",
    "QPResult", "project_box_hyperplane", "solve_qp", "mcc",
    "FittedHead", "fit_head", "pool_features",
]
