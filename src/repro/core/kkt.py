"""KKT optimality conditions for the reduced OCSSVM dual (paper eq. 49-53).

The five gamma-space cases, written as per-plane distance violations so all
magnitudes share the raw-score scale (equivalent to the paper's product-form
conditions, but numerically uniform):

    gamma_i = 0          -> rho1 <= s_i <= rho2      (strict interior)
    0 < gamma_i < hi     -> s_i = rho1               (on lower plane)
    gamma_i = hi         -> s_i <= rho1              (below lower plane)
    lo < gamma_i < 0     -> s_i = rho2               (on upper plane)
    gamma_i = lo         -> s_i >= rho2              (above upper plane)

``violation(...)`` returns a non-negative per-sample violation magnitude;
the solver stops when at most one sample violates beyond ``tol`` (the
paper's Algorithm 1 termination), or when the max violation is below tol.

The implementation lives in ``repro.core.engine.stats`` (shared with the
sharded solver, which needs explicit global bounds + validity masks); this
module keeps the spec-based convenience view.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.engine.stats import slab_margin, violation as _violation
from repro.core.ocssvm import SlabSpec

Array = jax.Array

__all__ = ["slab_margin", "violation", "n_violators", "converged"]


def violation(
    gamma: Array,
    scores: Array,
    rho1: Array,
    rho2: Array,
    spec: SlabSpec,
    bound_tol: float = 1e-8,
) -> Array:
    """Per-sample KKT violation magnitude (>= 0)."""
    m = gamma.shape[0]
    return _violation(gamma, scores, rho1, rho2, hi=spec.upper(m),
                      lo=spec.lower(m), m=m, bound_tol=bound_tol)


def n_violators(v: Array, tol: float) -> Array:
    return jnp.sum(v > tol)


def converged(v: Array, tol: float) -> Array:
    """Paper termination: at most one variable violates KKT."""
    return n_violators(v, tol) <= 1
