"""KKT optimality conditions for the reduced OCSSVM dual (paper eq. 49-53).

The five gamma-space cases, written as per-plane distance violations so all
magnitudes share the raw-score scale (equivalent to the paper's product-form
conditions, but numerically uniform):

    gamma_i = 0          -> rho1 <= s_i <= rho2      (strict interior)
    0 < gamma_i < hi     -> s_i = rho1               (on lower plane)
    gamma_i = hi         -> s_i <= rho1              (below lower plane)
    lo < gamma_i < 0     -> s_i = rho2               (on upper plane)
    gamma_i = lo         -> s_i >= rho2              (above upper plane)

``violation(...)`` returns a non-negative per-sample violation magnitude;
the solver stops when at most one sample violates beyond ``tol`` (the
paper's Algorithm 1 termination), or when the max violation is below tol.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.ocssvm import SlabSpec

Array = jax.Array


def slab_margin(scores: Array, rho1: Array, rho2: Array) -> Array:
    """f_bar(x) = min(s - rho1, rho2 - s) (paper eq. 56)."""
    return jnp.minimum(scores - rho1, rho2 - scores)


def violation(
    gamma: Array,
    scores: Array,
    rho1: Array,
    rho2: Array,
    spec: SlabSpec,
    bound_tol: float = 1e-8,
) -> Array:
    """Per-sample KKT violation magnitude (>= 0)."""
    m = gamma.shape[0]
    hi = spec.upper(m)
    lo = spec.lower(m)
    bt_hi = hi * bound_tol * m
    bt_lo = -lo * bound_tol * m

    at_zero = jnp.abs(gamma) <= jnp.minimum(bt_hi, bt_lo)
    at_hi = gamma >= hi - bt_hi
    at_lo = gamma <= lo + bt_lo
    free_pos = (~at_zero) & (~at_hi) & (gamma > 0)
    free_neg = (~at_zero) & (~at_lo) & (gamma < 0)

    v_zero = jnp.maximum(jnp.maximum(rho1 - scores, scores - rho2), 0.0)
    v_free_pos = jnp.abs(scores - rho1)
    v_at_hi = jnp.maximum(scores - rho1, 0.0)
    v_free_neg = jnp.abs(scores - rho2)
    v_at_lo = jnp.maximum(rho2 - scores, 0.0)

    v = jnp.where(at_zero, v_zero, 0.0)
    v = jnp.where(free_pos, v_free_pos, v)
    v = jnp.where(at_hi, v_at_hi, v)
    v = jnp.where(free_neg, v_free_neg, v)
    v = jnp.where(at_lo, v_at_lo, v)
    return v


def n_violators(v: Array, tol: float) -> Array:
    return jnp.sum(v > tol)


def converged(v: Array, tol: float) -> Array:
    """Paper termination: at most one variable violates KKT."""
    return n_violators(v, tol) <= 1
