"""OneClassSlabHead — the paper's classifier as a first-class head on
backbone features (the open-set-recognition integration point).

Any repro.models backbone yields (batch, d_model) pooled features; this head
fits the OCSSVM slab on them with the SMO family and scores new features.
Feature normalization matters for kernel geometry, so the head owns a
whitening transform (mean/scale fit on the training features).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.batched_smo import solve_blocked
from repro.core.ocssvm import OCSSVMModel, SlabSpec
from repro.core.smo import SMOResult, solve as solve_smo

Array = jax.Array


class FittedHead(NamedTuple):
    model: OCSSVMModel
    mean: Array
    scale: Array
    result: SMOResult

    def _norm(self, F: Array) -> Array:
        return (F - self.mean) / self.scale

    def score(self, F: Array) -> Array:
        """Slab decision value; >= 0 means in-distribution."""
        return self.model.decision_function(self._norm(F))

    def predict(self, F: Array) -> Array:
        return self.model.predict(self._norm(F))


def pool_features(hidden: Array, mode: str = "mean") -> Array:
    """(batch, seq, d) -> (batch, d)."""
    if mode == "mean":
        return hidden.mean(axis=1)
    if mode == "last":
        return hidden[:, -1, :]
    raise ValueError(f"unknown pooling {mode!r}")


def fit_head(
    features: Array,
    spec: SlabSpec,
    *,
    solver: str = "blocked",
    P: int = 8,
    tol: float = 1e-4,
    normalize: bool = True,
) -> FittedHead:
    """Fit the OCSSVM slab on (n, d) in-distribution features."""
    F = features.astype(jnp.float32)
    if normalize:
        mean = F.mean(axis=0)
        scale = F.std(axis=0) + 1e-6
    else:
        mean = jnp.zeros((F.shape[1],), jnp.float32)
        scale = jnp.ones((F.shape[1],), jnp.float32)
    Fn = (F - mean) / scale
    if solver == "blocked":
        res = solve_blocked(Fn, spec, P=P, tol=tol)
    elif solver == "paper":
        res = solve_smo(Fn, spec, selection="paper", tol=tol)
    elif solver == "mvp":
        res = solve_smo(Fn, spec, selection="mvp", tol=tol)
    else:
        raise ValueError(f"unknown solver {solver!r}")
    return FittedHead(model=res.model, mean=mean, scale=scale, result=res)
