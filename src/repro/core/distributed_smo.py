"""Data-parallel blocked SMO over the production mesh (engine facade).

The training set X, the dual vector gamma, and the f-cache are sharded by
rows across the mesh's data axes (("data",) single-pod, ("pod","data")
multi-pod — ``repro.launch.mesh.make_solver_mesh`` builds both from the
launch layer). The whole solve is the SAME engine driver as the
single-device solvers, run inside ``shard_map`` with the sharded
provider/selector:

1. ``ShardedBlockSelector``: every shard proposes its local top-P grow /
   top-P shrink candidates; one ``all_gather`` of the tiny packed
   candidate set (O(P) scalars + P*d floats per shard — independent of m)
   makes selection *globally identical* on every device,
2. the Gauss-Seidel pair solve runs replicated (2P x 2P block),
3. ``ShardedGram`` applies the rank-2P f update to the local rows only —
   no communication — through the SAME fused Pallas ``fupdate`` kernel as
   the single-device pallas provider (interpret mode on CPU), and
   scatters delta-gamma into the local slice,
4. rho recovery / convergence tests are the fused-stats reductions
   (``engine.stats.solver_stats_prev``): ONE psum of a stacked vector plus
   ONE pmax per iteration instead of 12 small collectives. At production
   scale each small all-reduce is latency-bound (~10 us on multi-hop ICI),
   so this drops the per-iteration critical path ~6x (hillclimb 3,
   EXPERIMENTS.md).

Per-iteration communication is O(P d) — independent of m — which is what
makes the paper's "scales to large training sets" claim hold at pod scale:
compute per shard is O(m_local d), halving with every doubling of shards.
Pass a ``CollectiveLedger`` to get that bill itemized at trace time
(``ledger.iteration_bytes`` — see docs/distributed.md).

The un-sharded reference (`solve_blocked`) produces identical selections
on one device; tests assert distributed == single-device optima.
"""
from __future__ import annotations

from collections import OrderedDict
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import engine
from repro.core.engine.types import SMOResult
from repro.core.ocssvm import (OCSSVMModel, SlabSpec, concrete_spec,
                               feasible_init)
from repro.kernels.precision import round_to_tile
from repro.utils.compat import shard_map

Array = jax.Array

__all__ = ["solve_blocked_distributed", "sharded_raw_scores"]


def _axis_rank(data_axes: Sequence[str], sizes: Sequence[int]) -> Array:
    rank = jnp.zeros((), jnp.int32)
    for ax, size in zip(data_axes, sizes):
        rank = rank * size + jax.lax.axis_index(ax)
    return rank


def _shard_geometry(m: int, mesh: Mesh, data_axes: Tuple[str, ...]):
    """(sizes, n_shards, m_pad, m_local) for row-sharding m over the
    mesh's data axes."""
    sizes = tuple(int(mesh.shape[ax]) for ax in data_axes)
    n_shards = 1
    for s_ in sizes:
        n_shards *= s_
    m_pad = ((m + n_shards - 1) // n_shards) * n_shards
    return sizes, n_shards, m_pad, m_pad // n_shards


# Compiled sharded entry points, keyed on everything that shapes the
# trace: mesh, axes, problem shape, spec, solver knobs, precision,
# interpret, and the ledger identity (a cache hit re-runs the compiled
# collectives WITHOUT re-recording — the ledger is a trace-time hook).
# Without this cache every shrinking round would re-trace and recompile
# the whole distributed while-loop solver (the local driver pays one
# compile per bucket shape via the module-level jit in batched_smo; this
# is the sharded counterpart). Bounded LRU: each entry pins a compiled
# executable (and, through the MeshComm closure, its ledger), so a
# workload handing a fresh ledger per fit call must not grow this
# forever — old entries are evicted, and with them the pinned ledgers.
_SHARD_FN_CACHE = OrderedDict()
_SHARD_FN_CACHE_MAX = 32


def _cached_shard_fn(key, build):
    try:
        hash(key)
    except TypeError:       # e.g. a kernel carrying traced/array params
        return build()
    fn = _SHARD_FN_CACHE.get(key)
    if fn is None:
        fn = _SHARD_FN_CACHE[key] = build()
    else:
        _SHARD_FN_CACHE.move_to_end(key)
    while len(_SHARD_FN_CACHE) > _SHARD_FN_CACHE_MAX:
        _SHARD_FN_CACHE.popitem(last=False)
    return fn


def _place(mesh: Mesh, spec: P, *arrays):
    """Explicit input shardings: lay each operand out row-sharded BEFORE
    the shard_map call, so entering the solve never implies a resharding
    transfer (the launch layer hands fit already-placed global arrays).
    Under an outer jit (the pod-scale benchmark lowers the whole facade)
    the placement becomes a sharding constraint on the traced value."""
    sharding = NamedSharding(mesh, spec)
    return tuple(
        jax.lax.with_sharding_constraint(a, sharding)
        if isinstance(a, jax.core.Tracer) else jax.device_put(a, sharding)
        for a in arrays)


def solve_blocked_distributed(
    X: Array,
    spec: SlabSpec,
    mesh: Mesh,
    *,
    data_axes: Tuple[str, ...] = ("data",),
    P_pairs: int = 8,
    tol: float = 1e-4,
    max_outer: int = 50_000,
    patience: int = 20,
    fused_stats: bool = True,
    rho_every: int = 1,
    precision: str = "f32",
    interpret: Optional[bool] = None,
    gamma0: Optional[Array] = None,
    warm=None,
    ledger: Optional[engine.CollectiveLedger] = None,
) -> SMOResult:
    """Solve the OCSSVM dual with X row-sharded over ``data_axes``.

    fused_stats: retained for signature compatibility. The engine's
    sharded statistics path (``solver_stats_prev``) IS the fused
    implementation — 2 collectives per iteration — and is always used;
    there is no slower unfused path to fall back to anymore.
    rho_every=k recomputes rho1/rho2 every k iterations (the margin-SV
    averages drift slowly near convergence; the paper recomputes each
    step). precision: Gram tile-input dtype — the sharded provider
    applies the same tile rounding as the local providers, so a
    distributed solve matches its single-device counterpart at any
    precision. interpret: force the per-shard Pallas fupdate kernel into
    interpret mode (None auto-detects: interpret on CPU, compiled on
    TPU). gamma0 warm-starts the solve (the sharded shrinking driver
    re-enters here between repack rounds). warm: an
    ``engine.WarmStart`` — gamma0/f_seed enter row-sharded like every
    data vector, the (small) correction set rides REPLICATED, and each
    shard reconciles its own f slice with one local fused fupdate
    sweep: the warm init costs ZERO collectives (the cold init
    all-gathers X and gamma). Mutually exclusive with gamma0. ledger: a
    ``CollectiveLedger`` populated at trace time with every collective's
    per-device payload, split into "init" (once) and "iter"
    (per-iteration) phases.
    """
    del fused_stats
    if warm is not None and gamma0 is not None:
        raise ValueError("pass warm= or gamma0=, not both")
    # The per-shard Pallas fupdate kernel specializes on concrete kernel
    # parameters (same rule as the local pallas provider).
    spec = concrete_spec(spec)
    m, d = X.shape
    kernel = spec.kernel
    sizes, n_shards, m_pad, m_local = _shard_geometry(m, mesh, data_axes)

    Xf = jnp.pad(X.astype(jnp.float32), ((0, m_pad - m), (0, 0)))
    valid = jnp.arange(m_pad) < m
    if warm is not None:
        g0 = jnp.pad(warm.gamma0.astype(jnp.float32), (0, m_pad - m))
        # f_seed shards exactly like gamma; the pad rows' seed value is
        # irrelevant (valid masks them everywhere, same as cold init).
        f_seed = jnp.pad(warm.f_seed.astype(jnp.float32), (0, m_pad - m))
        x_corr, d_corr = warm.x_corr, warm.delta
    else:
        g0 = (feasible_init(m, spec, jnp.float32) if gamma0 is None
              else gamma0.astype(jnp.float32))
        g0 = jnp.pad(g0, (0, m_pad - m))

    hi, lo = spec.upper(m), spec.lower(m)
    data_spec = P(data_axes)
    row_spec = P(data_axes, None)

    def build():
        comm = engine.MeshComm(data_axes, sizes=sizes, ledger=ledger)

        def local_solve(X_l, gamma_l, valid_l, *warm_ops):
            # Tile-round once, before provider AND selector: both then
            # see identical rows (ShardedGram's precision invariant) and
            # no per-iteration re-round is needed anywhere.
            X_l = round_to_tile(X_l, precision)
            rank = _axis_rank(data_axes, sizes)
            gids = rank * m_local + jnp.arange(m_local, dtype=jnp.int32)

            provider = engine.ShardedGram(X_l, kernel, gids=gids,
                                          rank=rank, m_local=m_local,
                                          m_pad=m_pad, comm=comm,
                                          interpret=interpret,
                                          precision=precision)
            selector = engine.ShardedBlockSelector(X_l, P=P_pairs, hi=hi,
                                                   lo=lo, gids=gids,
                                                   valid=valid_l,
                                                   comm=comm)
            stats_fn = partial(engine.solver_stats_prev, hi=hi, lo=lo,
                               m=m, tol=tol, comm=comm, valid=valid_l)

            w_l = None
            if warm_ops:
                # Local f_seed slice + replicated correction set: the
                # reconcile sweep is purely shard-local.
                f_l, x_c, d_c = warm_ops
                w_l = engine.WarmStart(gamma0=gamma_l, f_seed=f_l,
                                       x_corr=x_c, delta=d_c)
            state0 = engine.init_state(provider, stats_fn, gamma_l,
                                       ledger=ledger, warm=w_l)
            s = engine.run(provider, selector, stats_fn, state0, hi=hi,
                           lo=lo, tol=tol, max_iters=max_outer,
                           patience=patience, rho_every=rho_every,
                           ledger=ledger)
            return (s.gamma, s.f, s.rho1, s.rho2, s.it, s.n_viol,
                    s.max_viol, s.gap)

        in_specs = (row_spec, data_spec, data_spec)
        if warm is not None:
            in_specs = in_specs + (data_spec, P(None, None), P(None))
        return jax.jit(shard_map(
            local_solve, mesh=mesh,
            in_specs=in_specs,
            out_specs=(data_spec, data_spec, P(), P(), P(), P(), P(), P()),
            check_vma=False,
        ))

    warm_key = None if warm is None else tuple(warm.x_corr.shape)
    shard_fn = _cached_shard_fn(
        ("solve", mesh, data_axes, m, d, spec, P_pairs, tol, max_outer,
         patience, rho_every, precision, interpret, warm_key,
         None if ledger is None else id(ledger)), build)
    Xf, = _place(mesh, row_spec, Xf)
    g0, valid = _place(mesh, data_spec, g0, valid)
    if warm is not None:
        f_seed, = _place(mesh, data_spec, f_seed)
        x_corr, = _place(mesh, P(None, None), x_corr)
        d_corr, = _place(mesh, P(None), d_corr)
        gamma, f, rho1, rho2, it, n_viol, max_viol, gap = shard_fn(
            Xf, g0, valid, f_seed, x_corr, d_corr)
    else:
        gamma, f, rho1, rho2, it, n_viol, max_viol, gap = shard_fn(
            Xf, g0, valid)
    model = OCSSVMModel(gamma=gamma[:m], rho1=rho1, rho2=rho2, X=Xf[:m],
                        spec=spec)
    return SMOResult(model=model, iters=it, n_viol=n_viol,
                     max_viol=max_viol, gap=gap, converged=gap <= tol,
                     f=f[:m])


def sharded_raw_scores(
    X: Array,
    gamma: Array,
    kernel,
    mesh: Mesh,
    *,
    data_axes: Tuple[str, ...] = ("data",),
    precision: str = "f32",
    ledger: Optional[engine.CollectiveLedger] = None,
) -> Array:
    """f = K @ gamma with X row-sharded over the mesh's data axes.

    Each shard gathers X and gamma once and accumulates its local rows'
    scores over column blocks (``ShardedGram.init_scores``) — the sharded
    counterpart of ``raw_scores_blocked``, used by the sharded shrinking
    driver's full-set KKT sweeps. O(m d / n_shards) compute per device,
    one gather of X + gamma total. The ledger bills this O(m d) gather
    under its own "sweep" phase — it is once-per-repack-round work, not
    part of the per-iteration O(P d) bill.
    """
    m, d = X.shape
    sizes, n_shards, m_pad, m_local = _shard_geometry(m, mesh, data_axes)
    Xf = jnp.pad(X.astype(jnp.float32), ((0, m_pad - m), (0, 0)))
    gp = jnp.pad(gamma.astype(jnp.float32), (0, m_pad - m))
    data_spec = P(data_axes)
    row_spec = P(data_axes, None)
    if ledger is not None:
        ledger.set_phase("sweep")

    def build():
        comm = engine.MeshComm(data_axes, sizes=sizes, ledger=ledger)

        def local_scores(X_l, g_l):
            X_l = round_to_tile(X_l, precision)
            rank = _axis_rank(data_axes, sizes)
            gids = rank * m_local + jnp.arange(m_local, dtype=jnp.int32)
            provider = engine.ShardedGram(X_l, kernel, gids=gids,
                                          rank=rank, m_local=m_local,
                                          m_pad=m_pad, comm=comm,
                                          precision=precision)
            return provider.init_scores(g_l)

        return jax.jit(shard_map(
            local_scores, mesh=mesh, in_specs=(row_spec, data_spec),
            out_specs=data_spec, check_vma=False))

    shard_fn = _cached_shard_fn(
        ("scores", mesh, data_axes, m, d, kernel, precision,
         None if ledger is None else id(ledger)), build)
    Xf, = _place(mesh, row_spec, Xf)
    gp, = _place(mesh, data_spec, gp)
    return shard_fn(Xf, gp)[:m]
