"""Data-parallel blocked SMO over the production mesh (shard_map).

The training set X, the dual vector gamma, and the f-cache are sharded by
rows across the mesh's data axes (("data",) single-pod, ("pod","data")
multi-pod). Each outer iteration:

1. every shard proposes its local top-P grow / top-P shrink candidates
   (values + global row ids + the candidate rows of X),
2. one ``all_gather`` of the tiny candidate set (O(P) scalars + P*d floats
   per shard — independent of m) makes selection *globally identical* on
   every device,
3. the Gauss-Seidel pair solve runs replicated (2P x 2P block),
4. each shard applies the rank-2P f update to its local rows only —
   no communication — and scatters delta-gamma into its local slice,
5. rho recovery / convergence tests are psum/pmax tree reductions.

Per-iteration communication is O(P d) — independent of m — which is what
makes the paper's "scales to large training sets" claim hold at pod scale:
compute per shard is O(m_local d), halving with every doubling of shards.

The un-sharded reference (`solve_blocked`) produces identical selections on
one device; tests assert distributed == single-device trajectories.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.kernel_fn import KernelFn
from repro.core.ocssvm import OCSSVMModel, SlabSpec, feasible_init
from repro.core.smo import SMOResult

Array = jax.Array


class _DistState(NamedTuple):
    gamma: Array   # (m_local,)
    f: Array       # (m_local,)
    rho1: Array
    rho2: Array
    it: Array
    n_viol: Array
    max_viol: Array
    gap: Array
    stall: Array


def _axis_rank(data_axes: Sequence[str], sizes: Sequence[int]) -> Array:
    rank = jnp.zeros((), jnp.int32)
    for ax, size in zip(data_axes, sizes):
        rank = rank * size + jax.lax.axis_index(ax)
    return rank


def solve_blocked_distributed(
    X: Array,
    spec: SlabSpec,
    mesh: Mesh,
    *,
    data_axes: Tuple[str, ...] = ("data",),
    P_pairs: int = 8,
    tol: float = 1e-4,
    max_outer: int = 50_000,
    patience: int = 20,
    fused_stats: bool = True,
    rho_every: int = 1,
) -> SMOResult:
    """Solve the OCSSVM dual with X row-sharded over ``data_axes``.

    fused_stats: pack the per-iteration scalar reductions (rho-recovery
    sums/counts, interval endpoints, violation stats, MVP gap) into ONE
    psum of a stacked vector plus ONE pmax (mins negated) — 2 collectives
    per iteration instead of 12. At production scale each small
    all-reduce is latency-bound (~10 us on multi-hop ICI), so the solver's
    per-iteration critical path drops ~6x (hillclimb 3, EXPERIMENTS.md).
    rho_every=k recomputes rho1/rho2 every k iterations (the margin-SV
    averages drift slowly near convergence; the paper recomputes each
    step).
    """
    m, d = X.shape
    kernel = spec.kernel
    sizes = tuple(int(mesh.shape[ax]) for ax in data_axes)
    n_shards = 1
    for s_ in sizes:
        n_shards *= s_
    m_pad = ((m + n_shards - 1) // n_shards) * n_shards
    m_local = m_pad // n_shards

    dtype = jnp.float32
    Xf = jnp.pad(X.astype(dtype), ((0, m_pad - m), (0, 0)))
    valid = (jnp.arange(m_pad) < m)
    gamma0 = jnp.pad(feasible_init(m, spec, dtype), (0, m_pad - m))

    hi, lo = spec.upper(m), spec.lower(m)
    bnd = 1e-8 * (hi - lo)
    tiny = jnp.asarray(1e-12, dtype)
    neg = jnp.asarray(-jnp.inf, dtype)
    pos = jnp.asarray(jnp.inf, dtype)
    PP = P_pairs

    def _psum(x):
        return jax.lax.psum(x, data_axes)

    def _pmax(x):
        return jax.lax.pmax(x, data_axes)

    def _pmin(x):
        return jax.lax.pmin(x, data_axes)

    def _recover_rhos(gamma_l, f_l, valid_l):
        ghi = hi * 1e-6 * m
        glo = -lo * 1e-6 * m
        free_lower = valid_l & (gamma_l > ghi) & (gamma_l < hi - ghi)
        free_upper = valid_l & (gamma_l < -glo) & (gamma_l > lo + glo)
        sum1 = _psum(jnp.sum(jnp.where(free_lower, f_l, 0.0)))
        n1 = _psum(jnp.sum(free_lower))
        sum2 = _psum(jnp.sum(jnp.where(free_upper, f_l, 0.0)))
        n2 = _psum(jnp.sum(free_upper))
        mean1 = sum1 / jnp.maximum(n1, 1)
        mean2 = sum2 / jnp.maximum(n2, 1)

        big = jnp.asarray(jnp.finfo(dtype).max / 4, dtype)
        at_hi = valid_l & (gamma_l >= hi - ghi)
        at_lo = valid_l & (gamma_l <= lo + glo)
        nonneg = valid_l & (gamma_l >= -glo)
        nonpos = valid_l & (gamma_l <= ghi)
        r1_lo = _pmax(jnp.max(jnp.where(at_hi, f_l, -big)))
        r1_hi = _pmin(jnp.min(jnp.where(nonpos, f_l, big)))
        r1_mid = jnp.where((r1_lo > -big / 2) & (r1_hi < big / 2),
                           0.5 * (r1_lo + r1_hi),
                           jnp.where(r1_hi < big / 2, r1_hi, r1_lo))
        r2_lo = _pmax(jnp.max(jnp.where(nonneg, f_l, -big)))
        r2_hi = _pmin(jnp.min(jnp.where(at_lo, f_l, big)))
        r2_mid = jnp.where((r2_lo > -big / 2) & (r2_hi < big / 2),
                           0.5 * (r2_lo + r2_hi),
                           jnp.where(r2_lo > -big / 2, r2_lo, r2_hi))
        rho1 = jnp.where(n1 > 0, mean1, r1_mid)
        rho2 = jnp.where(n2 > 0, mean2, r2_mid)
        return rho1, rho2

    def _violation(gamma_l, f_l, rho1, rho2, valid_l):
        bt_hi = hi * 1e-8 * m
        bt_lo = -lo * 1e-8 * m
        at_zero = jnp.abs(gamma_l) <= jnp.minimum(bt_hi, bt_lo)
        at_hi = gamma_l >= hi - bt_hi
        at_lo = gamma_l <= lo + bt_lo
        free_pos = (~at_zero) & (~at_hi) & (gamma_l > 0)
        free_neg = (~at_zero) & (~at_lo) & (gamma_l < 0)
        v = jnp.where(at_zero,
                      jnp.maximum(jnp.maximum(rho1 - f_l, f_l - rho2), 0.0), 0.0)
        v = jnp.where(free_pos, jnp.abs(f_l - rho1), v)
        v = jnp.where(at_hi, jnp.maximum(f_l - rho1, 0.0), v)
        v = jnp.where(free_neg, jnp.abs(f_l - rho2), v)
        v = jnp.where(at_lo, jnp.maximum(rho2 - f_l, 0.0), v)
        return jnp.where(valid_l, v, 0.0)

    def _fused_stats(gamma_l, f_l, valid_l, rho1_prev, rho2_prev,
                     recompute_rho):
        """All per-iteration scalar statistics in 2 collectives.

        psum vector: [sum_free_lower_f, n_free_lower, sum_free_upper_f,
                      n_free_upper, n_violators]
        pmax vector: [r1_lo, r2_lo, -r1_hi, -r2_hi, max_viol,
                      max_f_down, -min_f_up]   (mins as negated maxes)
        """
        ghi = hi * 1e-6 * m
        glo = -lo * 1e-6 * m
        big = jnp.asarray(jnp.finfo(dtype).max / 4, dtype)

        free_lower = valid_l & (gamma_l > ghi) & (gamma_l < hi - ghi)
        free_upper = valid_l & (gamma_l < -glo) & (gamma_l > lo + glo)
        at_hi = valid_l & (gamma_l >= hi - ghi)
        at_lo = valid_l & (gamma_l <= lo + glo)
        nonneg = valid_l & (gamma_l >= -glo)
        nonpos = valid_l & (gamma_l <= ghi)
        up = valid_l & (gamma_l < hi - bnd)
        dn = valid_l & (gamma_l > lo + bnd)

        # provisional violation against the PREVIOUS rho (one round trip):
        v = _violation(gamma_l, f_l, rho1_prev, rho2_prev, valid_l)

        psum_vec = jnp.stack([
            jnp.sum(jnp.where(free_lower, f_l, 0.0)),
            jnp.sum(free_lower).astype(dtype),
            jnp.sum(jnp.where(free_upper, f_l, 0.0)),
            jnp.sum(free_upper).astype(dtype),
            jnp.sum(v > tol).astype(dtype),
        ])
        pmax_vec = jnp.stack([
            jnp.max(jnp.where(at_hi, f_l, -big)),
            jnp.max(jnp.where(nonneg, f_l, -big)),
            -jnp.min(jnp.where(nonpos, f_l, big)),
            -jnp.min(jnp.where(at_lo, f_l, big)),
            jnp.max(v),
            jnp.max(jnp.where(dn, f_l, neg)),
            -jnp.min(jnp.where(up, f_l, pos)),
        ])
        ps = jax.lax.psum(psum_vec, data_axes)
        pm = jax.lax.pmax(pmax_vec, data_axes)

        mean1 = ps[0] / jnp.maximum(ps[1], 1.0)
        mean2 = ps[2] / jnp.maximum(ps[3], 1.0)
        r1_lo, r2_lo, r1_hi, r2_hi = pm[0], pm[1], -pm[2], -pm[3]
        r1_mid = jnp.where((r1_lo > -big / 2) & (r1_hi < big / 2),
                           0.5 * (r1_lo + r1_hi),
                           jnp.where(r1_hi < big / 2, r1_hi, r1_lo))
        r2_mid = jnp.where((r2_lo > -big / 2) & (r2_hi < big / 2),
                           0.5 * (r2_lo + r2_hi),
                           jnp.where(r2_lo > -big / 2, r2_lo, r2_hi))
        rho1 = jnp.where(ps[1] > 0, mean1, r1_mid)
        rho2 = jnp.where(ps[3] > 0, mean2, r2_mid)
        rho1 = jnp.where(recompute_rho, rho1, rho1_prev)
        rho2 = jnp.where(recompute_rho, rho2, rho2_prev)
        n_viol = ps[4].astype(jnp.int32)
        max_viol = pm[4]
        gap = pm[5] - (-pm[6])
        return rho1, rho2, n_viol, max_viol, gap

    def local_solve(X_l, gamma_l, valid_l):
        rank = _axis_rank(data_axes, sizes)
        gids = rank * m_local + jnp.arange(m_local, dtype=jnp.int32)

        # Initial local f needs the *global* Kgamma: gather X once, then
        # accumulate over column blocks — the full (m_local x m) cross-
        # Gram block would be hundreds of GB at m = 1M.
        X_all = jax.lax.all_gather(X_l, data_axes, tiled=True)      # (m_pad, d)
        g_all = jax.lax.all_gather(gamma_l, data_axes, tiled=True)  # (m_pad,)
        blk = 2048
        nblk = (m_pad + blk - 1) // blk
        Xp = jnp.pad(X_all, ((0, nblk * blk - m_pad), (0, 0)))
        gp = jnp.pad(g_all, (0, nblk * blk - m_pad))   # pad gamma=0: no-op

        def fblock(i, acc):
            xb = jax.lax.dynamic_slice_in_dim(Xp, i * blk, blk)
            gb = jax.lax.dynamic_slice_in_dim(gp, i * blk, blk)
            return acc + kernel.cross(X_l, xb) @ gb

        f_l = jax.lax.fori_loop(0, nblk, fblock,
                                jnp.zeros((m_local,), dtype))
        del X_all, g_all, Xp, gp

        if fused_stats:
            rho1, rho2 = _recover_rhos(gamma_l, f_l, valid_l)
            _, _, n_v0, mx_v0, gap0 = _fused_stats(
                gamma_l, f_l, valid_l, rho1, rho2, jnp.asarray(False))
        else:
            rho1, rho2 = _recover_rhos(gamma_l, f_l, valid_l)
            v0 = _violation(gamma_l, f_l, rho1, rho2, valid_l)
            up0 = valid_l & (gamma_l < hi - bnd)
            dn0 = valid_l & (gamma_l > lo + bnd)
            gap0 = (_pmax(jnp.max(jnp.where(dn0, f_l, neg)))
                    - _pmin(jnp.min(jnp.where(up0, f_l, pos))))
            n_v0 = _psum(jnp.sum(v0 > tol)).astype(jnp.int32)
            mx_v0 = _pmax(jnp.max(v0))
        state = _DistState(gamma_l, f_l, rho1, rho2,
                           jnp.zeros((), jnp.int32),
                           n_v0, mx_v0, gap0,
                           jnp.zeros((), jnp.int32))

        def cond(s: _DistState):
            return (s.it < max_outer) & (s.gap > tol) & (s.stall < patience)

        def body(s: _DistState):
            up = valid_l & (s.gamma < hi - bnd)
            dn = valid_l & (s.gamma > lo + bnd)

            # Local candidates.
            up_val, up_i = jax.lax.top_k(jnp.where(up, -s.f, neg), PP)
            dn_val, dn_i = jax.lax.top_k(jnp.where(dn, s.f, neg), PP)

            # Pack both candidate sides into ONE matrix so selection costs
            # a single all-gather instead of ten (ids ride as f32 —
            # exact below 2^24 rows; the solver is latency-bound, 432 B
            # but 16 collectives/iter before packing).
            def pack(idx, val):
                return jnp.concatenate(
                    [val[:, None], gids[idx].astype(dtype)[:, None],
                     s.gamma[idx][:, None], s.f[idx][:, None], X_l[idx]],
                    axis=1)                          # (P, 4 + d)

            cand = jnp.stack([pack(up_i, up_val), pack(dn_i, dn_val)])
            cand_g = jax.lax.all_gather(cand, data_axes, tiled=False)
            # (n_shards, 2, P, 4+d) -> per side (n_shards*P, 4+d)
            cg = cand_g.transpose(1, 0, 2, 3).reshape(2, -1, cand.shape[-1])
            uv, uid = cg[0, :, 0], cg[0, :, 1].astype(jnp.int32)
            ug, uf, uX = cg[0, :, 2], cg[0, :, 3], cg[0, :, 4:]
            dv, did = cg[1, :, 0], cg[1, :, 1].astype(jnp.int32)
            dg, df_, dX = cg[1, :, 2], cg[1, :, 3], cg[1, :, 4:]

            _, usel = jax.lax.top_k(uv, PP)     # global top-P grows
            up_ids = uid[usel]
            # Exclude grow picks from shrink candidates (disjoint pairs).
            clash = (did[:, None] == up_ids[None, :]).any(axis=1)
            _, dsel = jax.lax.top_k(jnp.where(clash, neg, dv), PP)

            sel_ids = jnp.concatenate([uid[usel], did[dsel]])
            g_sel0 = jnp.concatenate([ug[usel], dg[dsel]])
            f_sel0 = jnp.concatenate([uf[usel], df_[dsel]])
            X_sel = jnp.concatenate([uX[usel], dX[dsel]], axis=0)   # (2P, d)

            Kblk = kernel.cross(X_sel, X_sel)
            dsl = jnp.diagonal(Kblk)

            def inner(k, carry):
                g_sel, f_sel = carry
                ib, ia = k, PP + k
                eta = 1.0 / jnp.maximum(dsl[ia] + dsl[ib] - 2.0 * Kblk[ia, ib],
                                        tiny)
                t = g_sel[ia] + g_sel[ib]
                L = jnp.maximum(t - hi, lo)
                H = jnp.minimum(hi, t - lo)
                gb_new = jnp.clip(g_sel[ib] + eta * (f_sel[ia] - f_sel[ib]),
                                  L, H)
                dgb = gb_new - g_sel[ib]
                dgb = jnp.where(sel_ids[ia] == sel_ids[ib], 0.0, dgb)
                g_sel = g_sel.at[ib].add(dgb).at[ia].add(-dgb)
                f_sel = f_sel + dgb * (Kblk[:, ib] - Kblk[:, ia])
                return g_sel, f_sel

            g_sel, _ = jax.lax.fori_loop(0, PP, inner, (g_sel0, f_sel0))
            delta = g_sel - g_sel0

            # Local rank-2P f update (no communication).
            f_new = s.f + kernel.rows(X_l, X_sel) @ delta
            # Scatter delta into the local gamma slice.
            loc = sel_ids - rank * m_local
            in_range = (loc >= 0) & (loc < m_local)
            loc_c = jnp.clip(loc, 0, m_local - 1)
            gamma_new = s.gamma.at[loc_c].add(jnp.where(in_range, delta, 0.0))

            if fused_stats:
                recompute = (rho_every == 1) | ((s.it + 1) % rho_every == 0)
                r1, r2, n_v, mx_v, gap_n = _fused_stats(
                    gamma_new, f_new, valid_l, s.rho1, s.rho2, recompute)
            else:
                r1, r2 = _recover_rhos(gamma_new, f_new, valid_l)
                v_new = _violation(gamma_new, f_new, r1, r2, valid_l)
                up_n = valid_l & (gamma_new < hi - bnd)
                dn_n = valid_l & (gamma_new > lo + bnd)
                gap_n = (_pmax(jnp.max(jnp.where(dn_n, f_new, neg)))
                         - _pmin(jnp.min(jnp.where(up_n, f_new, pos))))
                n_v = _psum(jnp.sum(v_new > tol)).astype(jnp.int32)
                mx_v = _pmax(jnp.max(v_new))
            progressed = jnp.max(jnp.abs(delta)) > tiny * 10
            stall = jnp.where(progressed, 0, s.stall + 1).astype(jnp.int32)
            return _DistState(gamma_new, f_new, r1, r2, s.it + 1,
                              n_v, mx_v, gap_n, stall)

        s = jax.lax.while_loop(cond, body, state)
        return (s.gamma, s.f, s.rho1, s.rho2, s.it, s.n_viol, s.max_viol,
                s.gap)

    data_spec = P(data_axes)
    shard_fn = jax.shard_map(
        local_solve, mesh=mesh,
        in_specs=(P(data_axes, None), data_spec, data_spec),
        out_specs=(data_spec, data_spec, P(), P(), P(), P(), P(), P()),
        check_vma=False,
    )
    gamma, f, rho1, rho2, it, n_viol, max_viol, gap = shard_fn(
        Xf, gamma0, valid)
    model = OCSSVMModel(gamma=gamma[:m], rho1=rho1, rho2=rho2, X=Xf[:m],
                        spec=spec)
    return SMOResult(model=model, iters=it, n_viol=n_viol,
                     max_viol=max_viol, gap=gap, converged=gap <= tol)
