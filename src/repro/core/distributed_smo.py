"""Data-parallel blocked SMO over the production mesh (engine facade).

The training set X, the dual vector gamma, and the f-cache are sharded by
rows across the mesh's data axes (("data",) single-pod, ("pod","data")
multi-pod). The whole solve is the SAME engine driver as the single-device
solvers, run inside ``shard_map`` with the sharded provider/selector:

1. ``ShardedBlockSelector``: every shard proposes its local top-P grow /
   top-P shrink candidates; one ``all_gather`` of the tiny packed
   candidate set (O(P) scalars + P*d floats per shard — independent of m)
   makes selection *globally identical* on every device,
2. the Gauss-Seidel pair solve runs replicated (2P x 2P block),
3. ``ShardedGram`` applies the rank-2P f update to the local rows only —
   no communication — and scatters delta-gamma into the local slice,
4. rho recovery / convergence tests are the fused-stats reductions
   (``engine.stats.solver_stats_prev``): ONE psum of a stacked vector plus
   ONE pmax per iteration instead of 12 small collectives. At production
   scale each small all-reduce is latency-bound (~10 us on multi-hop ICI),
   so this drops the per-iteration critical path ~6x (hillclimb 3,
   EXPERIMENTS.md).

Per-iteration communication is O(P d) — independent of m — which is what
makes the paper's "scales to large training sets" claim hold at pod scale:
compute per shard is O(m_local d), halving with every doubling of shards.

The un-sharded reference (`solve_blocked`) produces identical selections
on one device; tests assert distributed == single-device optima.
"""
from __future__ import annotations

from functools import partial
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import engine
from repro.core.engine.types import SMOResult
from repro.core.ocssvm import OCSSVMModel, SlabSpec, feasible_init
from repro.kernels.precision import round_to_tile
from repro.utils.compat import shard_map

Array = jax.Array

__all__ = ["solve_blocked_distributed"]


def _axis_rank(data_axes: Sequence[str], sizes: Sequence[int]) -> Array:
    rank = jnp.zeros((), jnp.int32)
    for ax, size in zip(data_axes, sizes):
        rank = rank * size + jax.lax.axis_index(ax)
    return rank


def solve_blocked_distributed(
    X: Array,
    spec: SlabSpec,
    mesh: Mesh,
    *,
    data_axes: Tuple[str, ...] = ("data",),
    P_pairs: int = 8,
    tol: float = 1e-4,
    max_outer: int = 50_000,
    patience: int = 20,
    fused_stats: bool = True,
    rho_every: int = 1,
    precision: str = "f32",
) -> SMOResult:
    """Solve the OCSSVM dual with X row-sharded over ``data_axes``.

    fused_stats: retained for signature compatibility. The engine's
    sharded statistics path (``solver_stats_prev``) IS the fused
    implementation — 2 collectives per iteration — and is always used;
    there is no slower unfused path to fall back to anymore.
    rho_every=k recomputes rho1/rho2 every k iterations (the margin-SV
    averages drift slowly near convergence; the paper recomputes each
    step). precision: Gram tile-input dtype — the sharded provider
    applies the same tile rounding as the local providers, so a
    distributed solve matches its single-device counterpart at any
    precision.
    """
    del fused_stats
    m, d = X.shape
    kernel = spec.kernel
    sizes = tuple(int(mesh.shape[ax]) for ax in data_axes)
    n_shards = 1
    for s_ in sizes:
        n_shards *= s_
    m_pad = ((m + n_shards - 1) // n_shards) * n_shards
    m_local = m_pad // n_shards

    Xf = jnp.pad(X.astype(jnp.float32), ((0, m_pad - m), (0, 0)))
    valid = jnp.arange(m_pad) < m
    gamma0 = jnp.pad(feasible_init(m, spec, jnp.float32), (0, m_pad - m))

    hi, lo = spec.upper(m), spec.lower(m)

    def local_solve(X_l, gamma_l, valid_l):
        # Tile-round once, before provider AND selector: both then see
        # identical rows (ShardedGram's precision invariant) and no
        # per-iteration re-round is needed anywhere.
        X_l = round_to_tile(X_l, precision)
        rank = _axis_rank(data_axes, sizes)
        gids = rank * m_local + jnp.arange(m_local, dtype=jnp.int32)
        comm = engine.MeshComm(data_axes)

        provider = engine.ShardedGram(X_l, kernel, gids=gids, rank=rank,
                                      m_local=m_local, m_pad=m_pad,
                                      axes=data_axes, precision=precision)
        selector = engine.ShardedBlockSelector(X_l, P=P_pairs, hi=hi, lo=lo,
                                               gids=gids, valid=valid_l,
                                               axes=data_axes)
        stats_fn = partial(engine.solver_stats_prev, hi=hi, lo=lo, m=m,
                           tol=tol, comm=comm, valid=valid_l)

        state0 = engine.init_state(provider, stats_fn, gamma_l)
        s = engine.run(provider, selector, stats_fn, state0, hi=hi, lo=lo,
                       tol=tol, max_iters=max_outer, patience=patience,
                       rho_every=rho_every)
        return (s.gamma, s.f, s.rho1, s.rho2, s.it, s.n_viol, s.max_viol,
                s.gap)

    data_spec = P(data_axes)
    shard_fn = shard_map(
        local_solve, mesh=mesh,
        in_specs=(P(data_axes, None), data_spec, data_spec),
        out_specs=(data_spec, data_spec, P(), P(), P(), P(), P(), P()),
        check_vma=False,
    )
    gamma, f, rho1, rho2, it, n_viol, max_viol, gap = shard_fn(
        Xf, gamma0, valid)
    model = OCSSVMModel(gamma=gamma[:m], rho1=rho1, rho2=rho2, X=Xf[:m],
                        spec=spec)
    return SMOResult(model=model, iters=it, n_viol=n_viol,
                     max_viol=max_viol, gap=gap, converged=gap <= tol)
