"""LIBSVM-style shrinking, adapted to fixed-shape JAX: a repack driver.

Classic shrinking skips bound-pinned coordinates inside the solver loop.
Under jit every vector op is full-m regardless of masks, so masking saves
nothing — instead this driver PHYSICALLY repacks the active set:

1. run the engine-backed blocked solver a bounded number of iterations on
   the full set,
2. freeze coordinates at a bound whose score keeps them there with margin
   (they cannot be part of any violating pair),
3. gather the active coordinates (size rounded up to a bucket to bound
   recompilation), fold the frozen coordinates' kernel contribution into a
   per-row ``f_offset``, and solve the small problem exactly
   (box bounds rescaled: nu' = nu * m_total / m_active keeps
   1/(nu1' m_active) == 1/(nu1 m_total)),
4. scatter back, verify KKT on the FULL set, repeat if anything at a
   bound woke up (the classic unshrink pass).

Per-iteration work in step 3 is O(m_active * d) instead of O(m * d) —
near convergence m_active is the support-vector count, typically a small
fraction of m. The reached optimum is the full-problem optimum (the final
full-set KKT check gates termination); tests assert objective parity.

Every inner solve routes through the shared engine (``solve_blocked`` is
an engine facade), so ``gram_mode="pallas"`` drives the fused Pallas
f-update inside the shrinking rounds too.

``solve_sharded_shrinking`` is the row-sharded composition of the same
idea: bounded *distributed* warm rounds (``solve_blocked_distributed``,
per-shard Pallas fupdate on the hot loop), per-shard freeze masks (one
fused pmax gives every shard the global movable-score extrema), and —
once the global active set fits under ``SINGLE_PASS_MAX`` — a gather of
the active rows to one shard followed by the LOCAL blocked solver on the
repacked problem, with the frozen shards' kernel contribution riding
along as ``f_offset``. Full-set KKT verification between rounds runs
sharded (``sharded_raw_scores``), so no step ever needs the O(m^2) Gram
or an unsharded O(m d) pass on one device.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.batched_smo import solve_blocked
from repro.core.engine import CollectiveLedger, MeshComm
from repro.core.engine.gram import SINGLE_PASS_MAX, raw_scores_blocked
from repro.core.engine.stats import violation as _violation
from repro.core.engine.types import SMOResult
from repro.core.ocssvm import (OCSSVMModel, SlabSpec, concrete_spec,
                               recover_rhos)
from repro.kernels.precision import round_to_tile
from repro.utils.compat import shard_map

Array = jax.Array

__all__ = ["solve_blocked_shrinking", "solve_sharded_shrinking"]


def _bucket(n: int, m: int) -> int:
    """Round n up to a power-of-two-ish bucket (bounds recompiles)."""
    if n >= m:
        return m
    b = 1 << max(6, math.ceil(math.log2(max(n, 1))))
    return min(b, m)


def solve_blocked_shrinking(
    X: Array,
    spec: SlabSpec,
    *,
    P: int = 8,
    gram_mode: str = "on_the_fly",
    interpret: Optional[bool] = None,
    precision: str = "f32",
    tol: float = 1e-4,
    warm_iters: int = 200,
    max_rounds: int = 8,
    round_iters: int = 50_000,
    margin: float = 2.0,
    max_outer: Optional[int] = None,
    patience: int = 20,
    gamma0: Optional[Array] = None,
    warm=None,
) -> SMOResult:
    """max_outer caps the per-round iteration budget (alias of
    round_iters, so the blocked solvers' signature works here too);
    gamma0 warm-starts the phase-1 full-set solve. ``warm`` (an
    ``engine.WarmStart``) goes one further: the phase-1 solve seeds
    gamma AND reconciles its f-cache from the prior fit's scores with
    one fused rank-s sweep (``solve_blocked(warm=)``); later rounds
    proceed from wherever phase 1 lands, exactly as with gamma0."""
    if max_outer is not None:
        round_iters = min(round_iters, max_outer)
    m, d = X.shape
    X32 = jnp.asarray(X, jnp.float32)
    # Tile-round once up front: the repack driver's own KKT sweeps and
    # f_offset folds then see exactly the rows the inner low-precision
    # solves see (for "f32" this is the plain f32 cast). The RETURNED
    # model still carries the unrounded X32 — precision is an execution
    # detail of the solve, and every facade returns the same model data.
    Xf = round_to_tile(X32, precision)
    kernel = spec.kernel
    hi, lo = spec.upper(m), spec.lower(m)
    bnd = 1e-8 * (hi - lo)

    def _solve(Xs, sp, **kw):
        return solve_blocked(Xs, sp, P=P, gram_mode=gram_mode,
                             interpret=interpret, precision=precision,
                             tol=tol, patience=patience, **kw)

    # Phase 1: bounded full-set warm solve.
    res = _solve(Xf, spec, max_outer=warm_iters, gamma0=gamma0, warm=warm)
    gamma = res.model.gamma
    if bool(res.converged):
        return res

    total_iters = int(res.iters)
    for _ in range(max_rounds):
        f = raw_scores_blocked(Xf, gamma, kernel)
        rho1, rho2 = recover_rhos(gamma, f, spec)
        v = _violation(gamma, f, rho1, rho2, hi=hi, lo=lo, m=m)
        if int(jnp.sum(v > tol)) <= 1:
            break

        # Freeze coordinates pinned at a bound with margin: at hi the KKT
        # wants f <= lambda; it can never pair as the "down" end of a
        # violating pair if f is below every movable-up score by margin.
        up_ok = gamma < hi - bnd
        dn_ok = gamma > lo + bnd
        m_up = jnp.min(jnp.where(up_ok, f, jnp.inf))
        m_dn = jnp.max(jnp.where(dn_ok, f, -jnp.inf))
        frozen_hi = (~up_ok) & (f < m_up - margin * tol)
        frozen_lo = (~dn_ok) & (f > m_dn + margin * tol)
        frozen_zero = (jnp.abs(gamma) < bnd) & (v <= tol * 0.5)
        frozen = (frozen_hi | frozen_lo | frozen_zero) & (v <= tol)

        active = np.asarray(~frozen)
        n_active = int(active.sum())
        if n_active >= int(0.9 * m) or n_active < 4 * P:
            # shrinking not profitable: finish on the full set
            res = _solve(Xf, spec, max_outer=round_iters, gamma0=gamma)
            gamma = res.model.gamma
            total_iters += int(res.iters)
            break

        # Bucket the active size by waking the least-frozen coordinates.
        n_b = _bucket(n_active, m)
        order = np.argsort(~active, kind="stable")     # active first
        idx = np.sort(order[:n_b])
        idx_j = jnp.asarray(idx)

        X_act = Xf[idx_j]
        g_act = gamma[idx_j]
        # Frozen contribution to the active rows' scores:
        f_act_full = f[idx_j]
        k_act = (kernel.cross(X_act, X_act) @ g_act
                 if n_b <= SINGLE_PASS_MAX
                 else raw_scores_blocked(X_act, g_act, kernel))
        f_offset = f_act_full - k_act

        sub_spec = dataclasses.replace(
            spec, nu1=spec.nu1 * m / n_b, nu2=spec.nu2 * m / n_b)
        sub = _solve(X_act, sub_spec, max_outer=round_iters, gamma0=g_act,
                     f_offset=f_offset)
        gamma = gamma.at[idx_j].set(sub.model.gamma)
        total_iters += int(sub.iters)

    f = raw_scores_blocked(Xf, gamma, kernel)
    rho1, rho2 = recover_rhos(gamma, f, spec)
    v = _violation(gamma, f, rho1, rho2, hi=hi, lo=lo, m=m)
    up_ok = gamma < hi - bnd
    dn_ok = gamma > lo + bnd
    gap = (jnp.max(jnp.where(dn_ok, f, -jnp.inf))
           - jnp.min(jnp.where(up_ok, f, jnp.inf)))
    model = OCSSVMModel(gamma=gamma, rho1=rho1, rho2=rho2, X=X32, spec=spec)
    return SMOResult(model=model, iters=jnp.asarray(total_iters),
                     n_viol=jnp.sum(v > tol).astype(jnp.int32),
                     max_viol=jnp.max(v), gap=gap,
                     converged=jnp.sum(v > tol) <= 1, f=f)


def _sharded_freeze_mask(gamma: Array, f: Array, v: Array, mesh: Mesh,
                         data_axes: Tuple[str, ...], *, hi: float,
                         lo: float, tol: float, margin: float, m: int,
                         ledger: Optional[CollectiveLedger] = None
                         ) -> Array:
    """The freeze decision of ``solve_blocked_shrinking``, tracked per
    shard: each shard classifies ITS rows from its local gamma/f/v slices;
    the only cross-shard facts needed are the two global movable-score
    extrema, which cost one fused pmax (billed to the ledger's "sweep"
    phase). Returns the global frozen mask (padded tail rows report
    frozen — they are never part of the active set). The compiled
    shard function is cached like the solve/sweep entry points, so
    repeated repack rounds of the same geometry trace once."""
    from repro.core.distributed_smo import _cached_shard_fn

    bnd = 1e-8 * (hi - lo)
    sizes = tuple(int(mesh.shape[ax]) for ax in data_axes)
    n_shards = 1
    for s_ in sizes:
        n_shards *= s_
    m_pad = ((m + n_shards - 1) // n_shards) * n_shards
    gp = jnp.pad(gamma.astype(jnp.float32), (0, m_pad - m))
    fp = jnp.pad(f.astype(jnp.float32), (0, m_pad - m))
    vp = jnp.pad(v.astype(jnp.float32), (0, m_pad - m))
    validp = jnp.arange(m_pad) < m
    if ledger is not None:
        ledger.set_phase("sweep")

    def build():
        comm = MeshComm(data_axes, sizes=sizes, ledger=ledger)

        def local_freeze(g_l, f_l, v_l, valid_l):
            up_ok = valid_l & (g_l < hi - bnd)
            dn_ok = valid_l & (g_l > lo + bnd)
            # One pmax of [-(min movable-up f), max movable-down f]: the
            # mins ride negated, exactly like the fused solver stats.
            pm = comm.pmax(jnp.stack([
                -jnp.min(jnp.where(up_ok, f_l, jnp.inf)),
                jnp.max(jnp.where(dn_ok, f_l, -jnp.inf)),
            ]))
            m_up, m_dn = -pm[0], pm[1]
            frozen_hi = (~up_ok) & (f_l < m_up - margin * tol)
            frozen_lo = (~dn_ok) & (f_l > m_dn + margin * tol)
            frozen_zero = (jnp.abs(g_l) < bnd) & (v_l <= tol * 0.5)
            frozen = (frozen_hi | frozen_lo | frozen_zero) & (v_l <= tol)
            return frozen | ~valid_l

        dspec = P(data_axes)
        return jax.jit(shard_map(local_freeze, mesh=mesh,
                                 in_specs=(dspec, dspec, dspec, dspec),
                                 out_specs=dspec, check_vma=False))

    shard_fn = _cached_shard_fn(
        ("freeze", mesh, tuple(data_axes), m, hi, lo, tol, margin,
         None if ledger is None else id(ledger)), build)
    return shard_fn(gp, fp, vp, validp)[:m]


def solve_sharded_shrinking(
    X: Array,
    spec: SlabSpec,
    mesh: Mesh,
    *,
    data_axes: Tuple[str, ...] = ("data",),
    P_pairs: int = 8,
    gram_mode: str = "on_the_fly",
    interpret: Optional[bool] = None,
    precision: str = "f32",
    tol: float = 1e-4,
    warm_iters: int = 200,
    max_rounds: int = 8,
    round_iters: int = 50_000,
    margin: float = 2.0,
    max_outer: Optional[int] = None,
    patience: int = 20,
    gamma0: Optional[Array] = None,
    warm=None,
    gather_max: Optional[int] = None,
    rho_every: int = 1,
    ledger: Optional[CollectiveLedger] = None,
) -> SMOResult:
    """Shrinking repack driver for a ROW-SHARDED problem.

    Rounds alternate between bounded distributed solves on the mesh and —
    as soon as the global active set fits under ``gather_max`` (default
    ``SINGLE_PASS_MAX``) — a gather of the active rows to one shard and a
    LOCAL blocked repack solve (``gram_mode`` picks its provider; the
    distributed rounds always run the per-shard Pallas fupdate). The
    full-set KKT sweep between rounds is sharded, so per-device memory
    stays O(m d / n_shards) throughout.

    ``ledger`` threads through to every distributed solve and sharded
    score sweep for collective-bytes accounting.
    """
    # Imported here, not at module top: distributed_smo imports this
    # module's sibling facades' dependency chain (engine -> gram) and the
    # shrinking driver is the only piece that needs the reverse edge.
    from repro.core.distributed_smo import (sharded_raw_scores,
                                            solve_blocked_distributed)

    if max_outer is not None:
        round_iters = min(round_iters, max_outer)
    if gather_max is None:
        gather_max = SINGLE_PASS_MAX
    # Concrete (hashable) spec up front: the distributed rounds and the
    # sweeps key their compiled shard functions on it, and the per-shard
    # Pallas fupdate specializes on the kernel parameters anyway.
    spec = concrete_spec(spec)
    m, d = X.shape
    X32 = jnp.asarray(X, jnp.float32)
    # Same invariant as the local driver: the repack sweeps and f_offset
    # folds see exactly the tile-rounded rows the solves see.
    Xf = round_to_tile(X32, precision)
    kernel = spec.kernel
    hi, lo = spec.upper(m), spec.lower(m)
    bnd = 1e-8 * (hi - lo)

    def _dist(g0, iters, w=None):
        return solve_blocked_distributed(
            X32, spec, mesh, data_axes=data_axes, P_pairs=P_pairs, tol=tol,
            max_outer=iters, patience=patience, precision=precision,
            interpret=interpret, gamma0=g0, rho_every=rho_every,
            ledger=ledger, warm=w)

    def _scores(g):
        return sharded_raw_scores(Xf, g, kernel, mesh, data_axes=data_axes,
                                  precision=precision, ledger=ledger)

    # Phase 1: bounded full-set distributed warm solve.
    res = _dist(gamma0, warm_iters, warm)
    gamma = res.model.gamma
    if bool(res.converged):
        return res

    total_iters = int(res.iters)
    for _ in range(max_rounds):
        f = _scores(gamma)
        rho1, rho2 = recover_rhos(gamma, f, spec)
        v = _violation(gamma, f, rho1, rho2, hi=hi, lo=lo, m=m)
        if int(jnp.sum(v > tol)) <= 1:
            break

        frozen = _sharded_freeze_mask(gamma, f, v, mesh, data_axes, hi=hi,
                                      lo=lo, tol=tol, margin=margin, m=m,
                                      ledger=ledger)
        active = np.asarray(~frozen)
        n_active = int(active.sum())
        if n_active >= int(0.9 * m) or n_active < 4 * P_pairs:
            # Shrinking not profitable: finish distributed on the full set.
            res = _dist(gamma, round_iters)
            gamma = res.model.gamma
            total_iters += int(res.iters)
            break

        if n_active > gather_max:
            # Active set still at sharded scale: another bounded
            # distributed round, warm-started, then re-sweep.
            res = _dist(gamma, round_iters)
            gamma = res.model.gamma
            total_iters += int(res.iters)
            continue

        # The global active set fits on one shard: gather it, repack, and
        # continue with the LOCAL blocked solver (bucketed to bound
        # recompiles, waking the least-frozen rows to fill the bucket).
        n_b = _bucket(n_active, m)
        order = np.argsort(~active, kind="stable")     # active first
        idx = np.sort(order[:n_b])
        idx_j = jnp.asarray(idx)

        X_act = Xf[idx_j]
        g_act = gamma[idx_j]
        k_act = (kernel.cross(X_act, X_act) @ g_act
                 if n_b <= SINGLE_PASS_MAX
                 else raw_scores_blocked(X_act, g_act, kernel))
        f_offset = f[idx_j] - k_act

        sub_spec = dataclasses.replace(
            spec, nu1=spec.nu1 * m / n_b, nu2=spec.nu2 * m / n_b)
        sub = solve_blocked(X_act, sub_spec, P=P_pairs, gram_mode=gram_mode,
                            interpret=interpret, precision=precision,
                            tol=tol, max_outer=round_iters, gamma0=g_act,
                            f_offset=f_offset, patience=patience)
        gamma = gamma.at[idx_j].set(sub.model.gamma)
        total_iters += int(sub.iters)

    # Final full-set verification, sharded.
    f = _scores(gamma)
    rho1, rho2 = recover_rhos(gamma, f, spec)
    v = _violation(gamma, f, rho1, rho2, hi=hi, lo=lo, m=m)
    up_ok = gamma < hi - bnd
    dn_ok = gamma > lo + bnd
    gap = (jnp.max(jnp.where(dn_ok, f, -jnp.inf))
           - jnp.min(jnp.where(up_ok, f, jnp.inf)))
    model = OCSSVMModel(gamma=gamma, rho1=rho1, rho2=rho2, X=X32, spec=spec)
    return SMOResult(model=model, iters=jnp.asarray(total_iters),
                     n_viol=jnp.sum(v > tol).astype(jnp.int32),
                     max_viol=jnp.max(v), gap=gap,
                     converged=jnp.sum(v > tol) <= 1, f=f)
