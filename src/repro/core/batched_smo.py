"""Blocked SMO — the beyond-paper TPU-native solver.

Instead of one violating pair per iteration (paper Algorithm 1), each outer
step:

1. selects ``P`` disjoint maximal-violating pairs in one vectorized sweep
   (P smallest-score coordinates that can grow x P largest-score
   coordinates that can shrink — the Keerthi working set generalized to a
   block),
2. runs **Gauss-Seidel** over the P analytic 2-variable subproblems using
   only the small (2P x 2P) Gram block to keep the selected scores exact
   (each inner step is then a true block-coordinate-descent step =>
   monotone descent, same fixed points as the paper's update),
3. applies ONE rank-2P f-cache update  f += K(X, X_sel) @ delta_gamma —
   an (m x d)(d x 2P)(2P) matmul chain on the MXU instead of 2P separate
   vector AXPYs.

Feasibility is exact: every pair moves on the equality hyperplane and is
clipped to the box. P=1 reduces to the paper's update rule (tests assert
objective parity with the sequential solver and the QP baseline).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.kernel_fn import KernelFn
from repro.core.kkt import violation
from repro.core.ocssvm import OCSSVMModel, SlabSpec, feasible_init, recover_rhos
from repro.core.smo import SMOResult, raw_scores_blocked

Array = jax.Array


class BlockedState(NamedTuple):
    gamma: Array
    f: Array
    rho1: Array
    rho2: Array
    it: Array
    n_viol: Array
    max_viol: Array
    gap: Array
    stall: Array


@partial(jax.jit, static_argnames=("P", "gram_mode", "tol", "max_outer",
                                   "patience"))
def solve_blocked(
    X: Array,
    spec: SlabSpec,
    *,
    P: int = 8,
    gram_mode: str = "on_the_fly",
    tol: float = 1e-4,
    max_outer: int = 50_000,
    patience: int = 20,
    gamma0: Optional[Array] = None,
    f_offset: Optional[Array] = None,
) -> SMOResult:
    """f_offset: constant per-row score contribution from coordinates
    OUTSIDE this problem (the shrinking driver freezes bound coordinates
    and solves the active subset; their kernel contribution to each active
    row's score rides along as this offset)."""
    m, _ = X.shape
    kernel = spec.kernel
    dtype = jnp.float32
    Xf = X.astype(dtype)

    gamma = feasible_init(m, spec, dtype) if gamma0 is None else gamma0.astype(dtype)
    K = kernel.gram(Xf) if gram_mode == "precomputed" else None
    diagK = kernel.diag(Xf)
    f = (K @ gamma) if K is not None else raw_scores_blocked(Xf, gamma, kernel)
    if f_offset is not None:
        f = f + f_offset.astype(dtype)
    rho1, rho2 = recover_rhos(gamma, f, spec)

    hi, lo = spec.upper(m), spec.lower(m)
    bnd = 1e-8 * (hi - lo)
    tiny = jnp.asarray(1e-12, dtype)
    neg = jnp.asarray(-jnp.inf, dtype)
    pos = jnp.asarray(jnp.inf, dtype)

    def diagnostics(gamma, f, rho1, rho2):
        v = violation(gamma, f, rho1, rho2, spec)
        up = gamma < hi - bnd
        dn = gamma > lo + bnd
        gap = jnp.max(jnp.where(dn, f, neg)) - jnp.min(jnp.where(up, f, pos))
        return v, gap

    v0, gap0 = diagnostics(gamma, f, rho1, rho2)
    state = BlockedState(gamma, f, rho1, rho2,
                         jnp.zeros((), jnp.int32),
                         jnp.sum(v0 > tol).astype(jnp.int32),
                         jnp.max(v0), gap0, jnp.zeros((), jnp.int32))

    def cond(s: BlockedState):
        return (s.it < max_outer) & (s.gap > tol) & (s.stall < patience)

    def body(s: BlockedState):
        up = s.gamma < hi - bnd
        dn = s.gamma > lo + bnd
        # P "grow" coordinates: smallest scores among movable-up.
        _, up_idx = jax.lax.top_k(jnp.where(up, -s.f, neg), P)
        # P "shrink" coordinates: largest scores among movable-down,
        # excluding the grow set (disjointness).
        dn_score = jnp.where(dn, s.f, neg).at[up_idx].set(neg)
        _, dn_idx = jax.lax.top_k(dn_score, P)
        sel = jnp.concatenate([up_idx, dn_idx])          # (2P,)

        if K is not None:
            Krows = K[:, sel]                            # (m, 2P)
        else:
            Krows = kernel.rows(Xf, Xf[sel])             # (m, 2P)
        Kblk = Krows[sel]                                # (2P, 2P)

        g_sel0 = s.gamma[sel]
        f_sel0 = s.f[sel]
        dsel = diagK[sel]

        # Gauss-Seidel over pairs (k, P+k): exact analytic step per pair
        # against the *current* selected scores (paper eq. 35-39).
        def inner(k, carry):
            g_sel, f_sel = carry
            ib, ia = k, P + k                    # b grows, a shrinks
            eta = 1.0 / jnp.maximum(dsel[ia] + dsel[ib] - 2.0 * Kblk[ia, ib],
                                    tiny)
            t = g_sel[ia] + g_sel[ib]
            L = jnp.maximum(t - hi, lo)
            H = jnp.minimum(hi, t - lo)
            gb_new = jnp.clip(g_sel[ib] + eta * (f_sel[ia] - f_sel[ib]), L, H)
            dgb = gb_new - g_sel[ib]
            # Degenerate pair (duplicate index from top_k ties): freeze.
            dgb = jnp.where(sel[ia] == sel[ib], 0.0, dgb)
            g_sel = g_sel.at[ib].add(dgb).at[ia].add(-dgb)
            f_sel = f_sel + dgb * (Kblk[:, ib] - Kblk[:, ia])
            return g_sel, f_sel

        g_sel, _ = jax.lax.fori_loop(0, P, inner, (g_sel0, f_sel0))
        delta = g_sel - g_sel0                            # (2P,)

        gamma_new = s.gamma.at[sel].add(delta)
        f_new = s.f + Krows @ delta                       # rank-2P update
        r1, r2 = recover_rhos(gamma_new, f_new, spec)

        v_new, gap_new = diagnostics(gamma_new, f_new, r1, r2)
        progressed = jnp.max(jnp.abs(delta)) > tiny * 10
        stall = jnp.where(progressed, 0, s.stall + 1).astype(jnp.int32)
        return BlockedState(gamma_new, f_new, r1, r2, s.it + 1,
                            jnp.sum(v_new > tol).astype(jnp.int32),
                            jnp.max(v_new), gap_new, stall)

    s = jax.lax.while_loop(cond, body, state)
    model = OCSSVMModel(gamma=s.gamma, rho1=s.rho1, rho2=s.rho2, X=Xf, spec=spec)
    return SMOResult(model=model, iters=s.it, n_viol=s.n_viol,
                     max_viol=s.max_viol, gap=s.gap, converged=s.gap <= tol)
