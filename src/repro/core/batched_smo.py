"""Blocked SMO — the beyond-paper TPU-native solver (engine facade).

Instead of one violating pair per iteration (paper Algorithm 1), each
outer step selects ``P`` disjoint maximal-violating pairs in one
vectorized sweep, runs Gauss-Seidel over the P analytic 2-variable
subproblems against the small (2P x 2P) Gram block, and applies ONE
rank-2P f-cache update f += K(X, X_sel) @ delta — an MXU matmul chain
instead of 2P separate vector AXPYs. With ``gram_mode="pallas"`` that
update is the fused Pallas ``fupdate`` kernel: one HBM pass over X per
iteration (interpret mode on CPU).

Feasibility is exact: every pair moves on the equality hyperplane and is
clipped to the box. P=1 reduces to the paper's update rule (tests assert
objective parity with the sequential solver and the QP baseline).

All of the loop logic lives in ``repro.core.engine``; this module only
composes (BlockSelector x chosen GramProvider) and keeps the historical
signature.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.engine.types import SMOResult
from repro.core.ocssvm import (OCSSVMModel, SlabSpec, concrete_spec,
                               feasible_init)

Array = jax.Array

__all__ = ["solve_blocked"]


def solve_blocked(
    X: Array,
    spec: SlabSpec,
    *,
    P: int = 8,
    gram_mode: str = "on_the_fly",
    interpret: Optional[bool] = None,
    precision: str = "f32",
    tol: float = 1e-4,
    max_outer: int = 50_000,
    patience: int = 20,
    gamma0: Optional[Array] = None,
    f_offset: Optional[Array] = None,
    warm=None,
) -> SMOResult:
    """f_offset: constant per-row score contribution from coordinates
    OUTSIDE this problem (the shrinking driver freezes bound coordinates
    and solves the active subset; their kernel contribution to each active
    row's score rides along as this offset).

    warm: optional ``engine.WarmStart`` (from
    ``engine.prepare_warm_start``) — seeds gamma from the prior fit and
    reconciles the f-cache with one fused rank-s sweep instead of the
    O(m^2) init pass. Mutually exclusive with ``gamma0`` (the warm seed
    IS the initial gamma). A plain jit-traced pytree: re-fitting with a
    different correction-set size retraces, same size re-runs.

    The spec stays a traced pytree except under gram_mode="pallas", where
    the Pallas kernel must specialize on concrete kernel parameters (the
    concretized spec becomes a static jit argument). ``interpret``
    force-overrides the Pallas provider's interpret-mode autodetection;
    ``precision`` is the Gram tile-input dtype
    (``repro.kernels.precision``)."""
    if warm is not None and gamma0 is not None:
        raise ValueError("pass warm= or gamma0=, not both")
    kw = dict(P=P, gram_mode=gram_mode, interpret=interpret,
              precision=precision, tol=tol, max_outer=max_outer,
              patience=patience, gamma0=gamma0, f_offset=f_offset,
              warm=warm)
    if gram_mode == "pallas":
        return _solve_static(X, concrete_spec(spec), **kw)
    return _solve_traced(X, spec, **kw)


def _solve_impl(
    X: Array,
    spec: SlabSpec,
    *,
    P: int,
    gram_mode: str,
    interpret: Optional[bool],
    precision: str,
    tol: float,
    max_outer: int,
    patience: int,
    gamma0: Optional[Array],
    f_offset: Optional[Array],
    warm,
) -> SMOResult:
    m, _ = X.shape
    Xf = X.astype(jnp.float32)
    hi, lo = spec.upper(m), spec.lower(m)

    if warm is not None:
        gamma = warm.gamma0.astype(jnp.float32)
    else:
        gamma = (feasible_init(m, spec, jnp.float32) if gamma0 is None
                 else gamma0.astype(jnp.float32))

    provider = engine.make_provider(gram_mode, Xf, spec.kernel,
                                    interpret=interpret, precision=precision)
    selector = engine.BlockSelector(provider, P=P, hi=hi, lo=lo)
    stats_fn = partial(engine.solver_stats_fresh, hi=hi, lo=lo, m=m, tol=tol)

    state0 = engine.init_state(provider, stats_fn, gamma, f_offset=f_offset,
                               warm=warm)
    s = engine.run(provider, selector, stats_fn, state0, hi=hi, lo=lo,
                   tol=tol, max_iters=max_outer, patience=patience)

    model = OCSSVMModel(gamma=s.gamma, rho1=s.rho1, rho2=s.rho2, X=Xf,
                        spec=spec)
    # Report f WITHOUT the external offset: K @ gamma over these rows is
    # what a warm-start artifact wants to checkpoint.
    f_out = s.f if f_offset is None else s.f - f_offset.astype(s.f.dtype)
    return SMOResult(model=model, iters=s.it, n_viol=s.n_viol,
                     max_viol=s.max_viol, gap=s.gap,
                     converged=s.gap <= tol, f=f_out)


_SOLVE_STATIC = ("P", "gram_mode", "interpret", "precision", "tol",
                 "max_outer", "patience")
_solve_traced = partial(jax.jit, static_argnames=_SOLVE_STATIC)(_solve_impl)
_solve_static = partial(jax.jit,
                        static_argnames=_SOLVE_STATIC + ("spec",))(_solve_impl)
