"""Matthews Correlation Coefficient — the paper's evaluation metric."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def mcc(y_true: Array, y_pred: Array) -> Array:
    """MCC for labels in {-1, +1}. Returns 0 when any marginal is empty."""
    yt = y_true > 0
    yp = y_pred > 0
    tp = jnp.sum(yt & yp).astype(jnp.float32)
    tn = jnp.sum(~yt & ~yp).astype(jnp.float32)
    fp = jnp.sum(~yt & yp).astype(jnp.float32)
    fn = jnp.sum(yt & ~yp).astype(jnp.float32)
    num = tp * tn - fp * fn
    den = jnp.sqrt((tp + fp) * (tp + fn) * (tn + fp) * (tn + fn))
    return jnp.where(den > 0, num / den, 0.0)
