"""One-Class Slab SVM model state, in the paper's reduced gamma-space.

The paper's key reduction (eq. 29-32): the dual depends only on
``gamma = alpha - alpha_bar``, giving

    min_gamma  1/2 gamma^T K gamma
    s.t.       -eps/(nu2*m) <= gamma_i <= 1/(nu1*m),   sum(gamma) = 1 - eps

``raw score`` s_i = sum_j gamma_j k(x_i, x_j); the slab decision is
``sgn((s - rho1) * (rho2 - s))`` (eq. 19): +1 inside the slab, -1 outside.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import stats as _stats
from repro.core.engine.gram import SINGLE_PASS_MAX
from repro.core.kernel_fn import KernelFn

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SlabSpec:
    """Static problem specification (nu1, nu2, eps and the kernel)."""

    nu1: float = 0.5
    nu2: float = 0.01
    eps: float = 2.0 / 3.0
    kernel: KernelFn = dataclasses.field(default_factory=KernelFn)

    def tree_flatten(self):
        return (self.kernel,), (self.nu1, self.nu2, self.eps)

    @classmethod
    def tree_unflatten(cls, aux, children):
        (kernel,) = children
        nu1, nu2, eps = aux
        return cls(nu1=nu1, nu2=nu2, eps=eps, kernel=kernel)

    # Box bounds in gamma space (eq. 31) and the equality target (eq. 32).
    def upper(self, m: int) -> float:
        return 1.0 / (self.nu1 * m)

    def lower(self, m: int) -> float:
        return -self.eps / (self.nu2 * m)

    def total(self) -> float:
        return 1.0 - self.eps


class OCSSVMModel(NamedTuple):
    """Fitted model: dual coefficients + slab offsets + the training data."""

    gamma: Array  # (m,) dual coefficients alpha - alpha_bar
    rho1: Array   # lower-plane offset
    rho2: Array   # upper-plane offset
    X: Array      # (m, d) training points (support data)
    spec: SlabSpec

    def raw_scores(self, Xq: Array) -> Array:
        """s(x) = sum_j gamma_j k(x, x_j) for query points (n, d) -> (n,)."""
        return self.spec.kernel.cross(Xq, self.X) @ self.gamma

    def decision_function(self, Xq: Array) -> Array:
        """Signed slab margin value (eq. 19 before the sgn)."""
        s = self.raw_scores(Xq)
        return (s - self.rho1) * (self.rho2 - s)

    def predict(self, Xq: Array) -> Array:
        """+1 inside the slab (target class), -1 outside."""
        return jnp.where(self.decision_function(Xq) >= 0, 1, -1)


def concrete_spec(spec: SlabSpec) -> SlabSpec:
    """Pull the spec's (hyper-)parameters to host python floats.

    The jitted solver facades take the spec as a *static* argument (the
    Pallas provider must specialize on concrete kernel parameters), so it
    has to be hashable: 0-d jax arrays — e.g. a spec recovered from a
    fitted model's ``res.model.spec`` — are converted; tracers cannot be
    (call the solver outside jit, or with a spec built from floats).
    """

    def _f(v, name):
        if isinstance(v, jax.core.Tracer):
            raise TypeError(
                f"SlabSpec.{name} is a traced value; the solver facades "
                "take the spec as a static (hashable) argument — build it "
                "from concrete floats or call outside jit.")
        return float(v)

    kernel = dataclasses.replace(
        spec.kernel, gamma=_f(spec.kernel.gamma, "kernel.gamma"),
        coef0=_f(spec.kernel.coef0, "kernel.coef0"))
    return dataclasses.replace(
        spec, nu1=_f(spec.nu1, "nu1"), nu2=_f(spec.nu2, "nu2"),
        eps=_f(spec.eps, "eps"), kernel=kernel)


def feasible_init(m: int, spec: SlabSpec, dtype=jnp.float32) -> Array:
    """A strictly feasible gamma: water-fill ``1 - eps`` into the box.

    Uniform (1-eps)/m works whenever it is inside the box; otherwise fill
    the first ceil((1-eps)/hi) entries to the cap and put the remainder in
    the next slot (general water-filling, jit-safe).
    """
    hi = spec.upper(m)
    lo = spec.lower(m)
    total = spec.total()
    uniform = total / m
    inside = (uniform <= hi) & (uniform >= lo)

    def _uniform():
        return jnp.full((m,), uniform, dtype)

    def _waterfill():
        # total > 0 always (eps < 1): fill caps left to right.
        full = jnp.floor(total / hi).astype(jnp.int32)
        idx = jnp.arange(m)
        g = jnp.where(idx < full, hi, 0.0).astype(dtype)
        rem = total - full.astype(dtype) * hi
        return g.at[full].add(rem.astype(dtype))

    return jax.lax.cond(inside, _uniform, _waterfill)


def recover_rhos(
    gamma: Array,
    scores: Array,
    spec: SlabSpec,
    tol: float = 1e-6,
) -> Tuple[Array, Array]:
    """rho1 / rho2 from on-margin support vectors (eq. 20-21).

    Lower-plane SVs: 0 < gamma < 1/(nu1 m)  -> s = rho1.
    Upper-plane SVs: -eps/(nu2 m) < gamma < 0 -> s = rho2.

    When a plane has no free SV (all at bound), fall back to the KKT
    interval midpoint: rho1 in [max_{gamma=hi} s, min_{gamma<=0} s],
    rho2 in [max_{gamma>=0} s, min_{gamma=lo} s].

    This is the spec-based view of the one implementation in
    ``repro.core.engine.stats`` (which also serves the sharded solver).
    """
    m = gamma.shape[0]
    return _stats.recover_rhos(gamma, scores, hi=spec.upper(m),
                               lo=spec.lower(m), m=m, tol=tol)


def with_quantile_offsets(model: "OCSSVMModel") -> "OCSSVMModel":
    """Beyond-paper robustness: primal-consistent slab offsets.

    KKT analysis of the reduced dual (DESIGN.md §7) shows rho1 = rho2 at
    any optimum with free SVs on both planes — the slab collapses and the
    sign classifier degenerates (scores still RANK correctly, since the
    decision value is -(s - rho)^2). The primal, for the fitted w, is
    minimized by quantile offsets instead:

        d/drho1 [-rho1 + 1/(nu1 m) sum max(0, rho1 - s_i)] = 0
            -> rho1 = nu1-quantile of scores
        d/drho2 [ eps rho2 + eps/(nu2 m) sum max(0, s_i - rho2)] = 0
            -> rho2 = (1 - nu2)-quantile of scores

    which restores a usable slab whenever w != 0. Paper-faithful margin-SV
    recovery (eq. 20-21) stays the default everywhere else.
    """
    s = model.raw_scores(model.X)
    rho1 = jnp.quantile(s, model.spec.nu1)
    rho2 = jnp.quantile(s, 1.0 - model.spec.nu2)
    return model._replace(rho1=rho1, rho2=rho2)


def compact_support(model: "OCSSVMModel",
                    threshold: float = 1e-7) -> "OCSSVMModel":
    """Drop non-support rows: keep only |gamma_i| > threshold.

    Serving never needs the full training set — scoring cost is
    O(n_sv * d) per query, and after convergence most coordinates sit at
    exactly 0 or below ``threshold``. The returned model's
    ``decision_function`` differs from the full model's by at most
    ``sum(|dropped gamma|) * max_k |k|`` (each dropped coefficient is
    <= threshold), which is the bound ``docs/serving.md`` documents.

    Host-side (concrete arrays): compaction changes shapes, so it cannot
    live under jit; it runs once per fitted model in the serving cache.
    """
    g = np.asarray(model.gamma)
    idx = np.nonzero(np.abs(g) > threshold)[0]
    idx_j = jnp.asarray(idx, jnp.int32)
    return model._replace(gamma=jnp.asarray(model.gamma)[idx_j],
                          X=jnp.asarray(model.X)[idx_j])


def dual_objective(gamma: Array, K: Array) -> Array:
    """1/2 gamma^T K gamma (eq. 30)."""
    return 0.5 * gamma @ (K @ gamma)


def dual_objective_matfree(gamma: Array, X: Array, kernel: KernelFn) -> Array:
    """Objective without materializing K.

    Below the engine's single-pass threshold (the same one
    ``raw_scores_blocked`` uses) one cross-kernel pass suffices; above it
    the quadratic form is accumulated over row blocks.
    """
    if X.shape[0] <= SINGLE_PASS_MAX:
        return 0.5 * gamma @ (kernel.cross(X, X) @ gamma)
    return _blocked_obj(gamma, X, kernel)


def _blocked_obj(gamma: Array, X: Array, kernel: KernelFn, block: int = 2048) -> Array:
    m = X.shape[0]
    nblk = (m + block - 1) // block
    pad = nblk * block - m
    Xp = jnp.pad(X, ((0, pad), (0, 0)))
    gp = jnp.pad(gamma, (0, pad))

    def body(i, acc):
        xb = jax.lax.dynamic_slice_in_dim(Xp, i * block, block)
        gb = jax.lax.dynamic_slice_in_dim(gp, i * block, block)
        return acc + gb @ (kernel.cross(xb, Xp) @ gp)

    return 0.5 * jax.lax.fori_loop(0, nblk, body, jnp.zeros((), gamma.dtype))
