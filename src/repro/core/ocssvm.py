"""One-Class Slab SVM model state, in the paper's reduced gamma-space.

The paper's key reduction (eq. 29-32): the dual depends only on
``gamma = alpha - alpha_bar``, giving

    min_gamma  1/2 gamma^T K gamma
    s.t.       -eps/(nu2*m) <= gamma_i <= 1/(nu1*m),   sum(gamma) = 1 - eps

``raw score`` s_i = sum_j gamma_j k(x_i, x_j); the slab decision is
``sgn((s - rho1) * (rho2 - s))`` (eq. 19): +1 inside the slab, -1 outside.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.kernel_fn import KernelFn

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SlabSpec:
    """Static problem specification (nu1, nu2, eps and the kernel)."""

    nu1: float = 0.5
    nu2: float = 0.01
    eps: float = 2.0 / 3.0
    kernel: KernelFn = dataclasses.field(default_factory=KernelFn)

    def tree_flatten(self):
        return (self.kernel,), (self.nu1, self.nu2, self.eps)

    @classmethod
    def tree_unflatten(cls, aux, children):
        (kernel,) = children
        nu1, nu2, eps = aux
        return cls(nu1=nu1, nu2=nu2, eps=eps, kernel=kernel)

    # Box bounds in gamma space (eq. 31) and the equality target (eq. 32).
    def upper(self, m: int) -> float:
        return 1.0 / (self.nu1 * m)

    def lower(self, m: int) -> float:
        return -self.eps / (self.nu2 * m)

    def total(self) -> float:
        return 1.0 - self.eps


class OCSSVMModel(NamedTuple):
    """Fitted model: dual coefficients + slab offsets + the training data."""

    gamma: Array  # (m,) dual coefficients alpha - alpha_bar
    rho1: Array   # lower-plane offset
    rho2: Array   # upper-plane offset
    X: Array      # (m, d) training points (support data)
    spec: SlabSpec

    def raw_scores(self, Xq: Array) -> Array:
        """s(x) = sum_j gamma_j k(x, x_j) for query points (n, d) -> (n,)."""
        return self.spec.kernel.cross(Xq, self.X) @ self.gamma

    def decision_function(self, Xq: Array) -> Array:
        """Signed slab margin value (eq. 19 before the sgn)."""
        s = self.raw_scores(Xq)
        return (s - self.rho1) * (self.rho2 - s)

    def predict(self, Xq: Array) -> Array:
        """+1 inside the slab (target class), -1 outside."""
        return jnp.where(self.decision_function(Xq) >= 0, 1, -1)


def feasible_init(m: int, spec: SlabSpec, dtype=jnp.float32) -> Array:
    """A strictly feasible gamma: water-fill ``1 - eps`` into the box.

    Uniform (1-eps)/m works whenever it is inside the box; otherwise fill
    the first ceil((1-eps)/hi) entries to the cap and put the remainder in
    the next slot (general water-filling, jit-safe).
    """
    hi = spec.upper(m)
    lo = spec.lower(m)
    total = spec.total()
    uniform = total / m
    inside = (uniform <= hi) & (uniform >= lo)

    def _uniform():
        return jnp.full((m,), uniform, dtype)

    def _waterfill():
        # total > 0 always (eps < 1): fill caps left to right.
        full = jnp.floor(total / hi).astype(jnp.int32)
        idx = jnp.arange(m)
        g = jnp.where(idx < full, hi, 0.0).astype(dtype)
        rem = total - full.astype(dtype) * hi
        return g.at[full].add(rem.astype(dtype))

    return jax.lax.cond(inside, _uniform, _waterfill)


def recover_rhos(
    gamma: Array,
    scores: Array,
    spec: SlabSpec,
    tol: float = 1e-6,
) -> Tuple[Array, Array]:
    """rho1 / rho2 from on-margin support vectors (eq. 20-21).

    Lower-plane SVs: 0 < gamma < 1/(nu1 m)  -> s = rho1.
    Upper-plane SVs: -eps/(nu2 m) < gamma < 0 -> s = rho2.

    When a plane has no free SV (all at bound), fall back to the KKT
    interval midpoint: rho1 in [max_{gamma=hi} s, min_{gamma<=0} s],
    rho2 in [max_{gamma>=0} s, min_{gamma=lo} s].
    """
    m = gamma.shape[0]
    hi = spec.upper(m)
    lo = spec.lower(m)
    ghi = hi * tol * m  # absolute slack scaled to the box size
    glo = -lo * tol * m

    free_lower = (gamma > ghi) & (gamma < hi - ghi)
    free_upper = (gamma < -glo) & (gamma > lo + glo)

    def _masked_mean(mask, values):
        n = jnp.sum(mask)
        return jnp.sum(jnp.where(mask, values, 0.0)) / jnp.maximum(n, 1), n

    mean1, n1 = _masked_mean(free_lower, scores)
    mean2, n2 = _masked_mean(free_upper, scores)

    big = jnp.asarray(jnp.finfo(scores.dtype).max / 4, scores.dtype)
    at_hi = gamma >= hi - ghi
    at_lo = gamma <= lo + glo
    nonneg = gamma >= -glo   # gamma >= 0 (within tol): s <= rho2 region
    nonpos = gamma <= ghi    # gamma <= 0 (within tol): s >= rho1 region

    # rho1 interval: scores of capped-at-hi points sit above rho1;
    # scores of gamma<=0 points sit below... (s >= rho1 for gamma<=0).
    r1_lo = jnp.max(jnp.where(at_hi, scores, -big))
    r1_hi = jnp.min(jnp.where(nonpos, scores, big))
    r1_mid = jnp.where(
        (r1_lo > -big / 2) & (r1_hi < big / 2), 0.5 * (r1_lo + r1_hi),
        jnp.where(r1_hi < big / 2, r1_hi, r1_lo))

    # rho2 interval: gamma>=0 points have s <= rho2; capped-at-lo have s >= rho2.
    r2_lo = jnp.max(jnp.where(nonneg, scores, -big))
    r2_hi = jnp.min(jnp.where(at_lo, scores, big))
    r2_mid = jnp.where(
        (r2_lo > -big / 2) & (r2_hi < big / 2), 0.5 * (r2_lo + r2_hi),
        jnp.where(r2_lo > -big / 2, r2_lo, r2_hi))

    rho1 = jnp.where(n1 > 0, mean1, r1_mid)
    rho2 = jnp.where(n2 > 0, mean2, r2_mid)
    return rho1, rho2


def with_quantile_offsets(model: "OCSSVMModel") -> "OCSSVMModel":
    """Beyond-paper robustness: primal-consistent slab offsets.

    KKT analysis of the reduced dual (DESIGN.md §7) shows rho1 = rho2 at
    any optimum with free SVs on both planes — the slab collapses and the
    sign classifier degenerates (scores still RANK correctly, since the
    decision value is -(s - rho)^2). The primal, for the fitted w, is
    minimized by quantile offsets instead:

        d/drho1 [-rho1 + 1/(nu1 m) sum max(0, rho1 - s_i)] = 0
            -> rho1 = nu1-quantile of scores
        d/drho2 [ eps rho2 + eps/(nu2 m) sum max(0, s_i - rho2)] = 0
            -> rho2 = (1 - nu2)-quantile of scores

    which restores a usable slab whenever w != 0. Paper-faithful margin-SV
    recovery (eq. 20-21) stays the default everywhere else.
    """
    s = model.raw_scores(model.X)
    rho1 = jnp.quantile(s, model.spec.nu1)
    rho2 = jnp.quantile(s, 1.0 - model.spec.nu2)
    return model._replace(rho1=rho1, rho2=rho2)


def dual_objective(gamma: Array, K: Array) -> Array:
    """1/2 gamma^T K gamma (eq. 30)."""
    return 0.5 * gamma @ (K @ gamma)


def dual_objective_matfree(gamma: Array, X: Array, kernel: KernelFn) -> Array:
    """Objective without materializing K — one cross-kernel pass."""
    return 0.5 * gamma @ (kernel.cross(X, X) @ gamma) if X.shape[0] <= 4096 else _blocked_obj(gamma, X, kernel)


def _blocked_obj(gamma: Array, X: Array, kernel: KernelFn, block: int = 2048) -> Array:
    m = X.shape[0]
    nblk = (m + block - 1) // block
    pad = nblk * block - m
    Xp = jnp.pad(X, ((0, pad), (0, 0)))
    gp = jnp.pad(gamma, (0, pad))

    def body(i, acc):
        xb = jax.lax.dynamic_slice_in_dim(Xp, i * block, block)
        gb = jax.lax.dynamic_slice_in_dim(gp, i * block, block)
        return acc + gb @ (kernel.cross(xb, Xp) @ gp)

    return 0.5 * jax.lax.fori_loop(0, nblk, body, jnp.zeros((), gamma.dtype))
