"""Rho recovery + KKT diagnostics + MVP gap, written once for every solver.

All statistics are phrased as *local masked reductions* followed by a
cross-device combine through a ``Comm`` object:

* ``LocalComm``  — single-device: the combine is the identity (free).
* ``MeshComm``   — inside ``shard_map``: ``psum``/``pmax`` over the data
  axes. Min-reductions ride as negated maxes so one ``pmax`` of a stacked
  vector covers all extrema; one ``psum`` covers all sums/counts — at most
  two collectives per call regardless of how many statistics are needed
  (the "fused stats" optimization from hillclimb 3, EXPERIMENTS.md).

Two variants of the per-iteration statistics bundle:

* ``solver_stats_fresh`` — recover rho first, then measure violations
  against the *fresh* rho (the paper recomputes each step). On a single
  device the extra reduction pass is free, so this is the local default.
* ``solver_stats_prev``  — measure violations against the *previous*
  iteration's rho so rho recovery and diagnostics share one round trip
  (2 collectives total). This is the sharded default: at pod scale each
  small all-reduce is latency-bound, and a one-step-stale violation count
  only delays termination by at most one iteration (convergence is gated
  on the gap, which is always fresh).

``hi``/``lo``/``m`` are the *global* box bounds and problem size — they
must not be derived from local array shapes, which differ under sharding.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CollectiveRecord:
    """One collective as seen at trace time: op kind, solve phase, the
    per-device payload bytes (static — shapes are known when tracing),
    and the iter epoch (which traced solve's loop body it belongs to)."""

    op: str       # "psum" | "pmax" | "all_gather"
    phase: str    # "init" (once per solve) | "iter" (once per iteration)
    nbytes: int   # per-device payload estimate
    epoch: int = 0   # distinguishes iter phases of successive solves


class CollectiveLedger:
    """Trace-time collective-bytes accounting for the sharded solver.

    Every ``MeshComm`` reduction/gather records (op, phase, bytes) here as
    it is TRACED. The engine driver traces its ``while_loop`` body exactly
    once, so the records tagged phase="iter" are the per-iteration
    collective bill — the O(P d) budget ROADMAP promises — and the
    "init" records are the one-time start-up cost (the column-blocked
    all-gather of X and gamma in ``ShardedGram.init_scores`` plus the two
    initial stats passes).

    Bytes are per-device payload estimates from static shapes: operand
    bytes for psum/pmax (each device contributes and receives one copy),
    gathered-output bytes for all_gather. They deliberately ignore the
    reduction algorithm's constant factor (ring vs tree) — the budget
    assertions care about the O(P d) vs O(m) distinction, not link-level
    truth, which only real ICI profiling can provide.

    The ledger fills when the solve is traced; a jit cache hit re-runs
    the compiled collectives without re-recording (trace-time hook, not a
    runtime profiler).

    Phases: "init" (once per solve), "iter" (once per iteration), and
    "sweep" (once per shrinking repack round — the sharded KKT sweep's
    O(m d) gather, kept out of the per-iteration bill).
    """

    def __init__(self):
        self.records: List[CollectiveRecord] = []
        self._phase = "init"
        self._iter_epoch = 0

    def set_phase(self, phase: str) -> None:
        # Entering "iter" starts a new epoch: one ledger threaded through
        # several solves (the sharded shrinking driver's warm + repack
        # rounds) then reports the per-iteration bill of ONE solve, not
        # the sum of every traced loop body.
        if phase == "iter" and self._phase != "iter":
            self._iter_epoch += 1
        self._phase = phase

    def record(self, op: str, nbytes: int) -> None:
        self.records.append(CollectiveRecord(
            op, self._phase, int(nbytes),
            self._iter_epoch if self._phase == "iter" else 0))

    def phase_bytes(self, phase: str) -> int:
        if phase == "iter":
            return self.iteration_bytes
        return sum(r.nbytes for r in self.records if r.phase == phase)

    def phase_ops(self, phase: str) -> int:
        if phase == "iter":
            return self.iteration_ops
        return sum(1 for r in self.records if r.phase == phase)

    def _iter_epochs(self) -> dict:
        out: dict = {}
        for r in self.records:
            if r.phase == "iter":
                b, n = out.get(r.epoch, (0, 0))
                out[r.epoch] = (b + r.nbytes, n + 1)
        return out

    @property
    def iteration_bytes(self) -> int:
        """Per-device collective bytes paid by ONE iteration of the most
        expensive traced solve sharing this ledger (epochs should agree
        for identical geometry; max is the honest bound)."""
        ep = self._iter_epochs()
        return max((b for b, _ in ep.values()), default=0)

    @property
    def iteration_ops(self) -> int:
        ep = self._iter_epochs()
        return max((n for _, n in ep.values()), default=0)

    def summary(self) -> dict:
        out = {
            "init_bytes": self.phase_bytes("init"),
            "init_ops": self.phase_ops("init"),
            "iteration_bytes": self.iteration_bytes,
            "iteration_ops": self.iteration_ops,
        }
        for phase in sorted({r.phase for r in self.records}
                            - {"init", "iter"}):
            out[f"{phase}_bytes"] = self.phase_bytes(phase)
            out[f"{phase}_ops"] = self.phase_ops(phase)
        return out


def _payload_bytes(x: Array) -> int:
    return int(x.size) * x.dtype.itemsize


class LocalComm:
    """Single-device combine: reductions are already global."""

    axes: Tuple[str, ...] = ()

    def psum(self, x: Array) -> Array:
        return x

    def pmax(self, x: Array) -> Array:
        return x


class MeshComm:
    """Cross-shard combine over mesh data axes (use inside shard_map).

    ``sizes`` (the mesh extent of each axis, in ``axes`` order) and
    ``ledger`` are optional: with both set, every reduction/gather records
    its per-device payload into the ``CollectiveLedger`` at trace time.
    """

    def __init__(self, axes: Sequence[str], *,
                 sizes: Optional[Sequence[int]] = None,
                 ledger: Optional[CollectiveLedger] = None):
        self.axes = tuple(axes)
        self.sizes = None if sizes is None else tuple(int(s) for s in sizes)
        self.ledger = ledger

    @property
    def n_shards(self) -> Optional[int]:
        if self.sizes is None:
            return None
        n = 1
        for s in self.sizes:
            n *= s
        return n

    def _record(self, op: str, nbytes: int) -> None:
        if self.ledger is not None:
            self.ledger.record(op, nbytes)

    def psum(self, x: Array) -> Array:
        self._record("psum", _payload_bytes(x))
        return jax.lax.psum(x, self.axes)

    def pmax(self, x: Array) -> Array:
        self._record("pmax", _payload_bytes(x))
        return jax.lax.pmax(x, self.axes)

    def all_gather(self, x: Array, *, tiled: bool = True) -> Array:
        """all_gather over the data axes, with the gathered-output bytes
        (local bytes x n_shards) recorded as this device's payload."""
        n = self.n_shards
        self._record("all_gather",
                     _payload_bytes(x) * (n if n is not None else 1))
        return jax.lax.all_gather(x, self.axes, tiled=tiled)


LOCAL_COMM = LocalComm()


def slab_margin(scores: Array, rho1: Array, rho2: Array) -> Array:
    """f_bar(x) = min(s - rho1, rho2 - s) (paper eq. 56)."""
    return jnp.minimum(scores - rho1, rho2 - scores)


def violation(gamma: Array, scores: Array, rho1: Array, rho2: Array, *,
              hi: float, lo: float, m: int,
              valid: Optional[Array] = None,
              bound_tol: float = 1e-8) -> Array:
    """Per-sample KKT violation magnitude (>= 0), the paper's 5 cases
    (eq. 49-53) phrased as per-plane score distances:

        gamma_i = 0          -> rho1 <= s_i <= rho2
        0 < gamma_i < hi     -> s_i = rho1
        gamma_i = hi         -> s_i <= rho1
        lo < gamma_i < 0     -> s_i = rho2
        gamma_i = lo         -> s_i >= rho2
    """
    bt_hi = hi * bound_tol * m
    bt_lo = -lo * bound_tol * m

    at_zero = jnp.abs(gamma) <= jnp.minimum(bt_hi, bt_lo)
    at_hi = gamma >= hi - bt_hi
    at_lo = gamma <= lo + bt_lo
    free_pos = (~at_zero) & (~at_hi) & (gamma > 0)
    free_neg = (~at_zero) & (~at_lo) & (gamma < 0)

    v = jnp.where(at_zero,
                  jnp.maximum(jnp.maximum(rho1 - scores, scores - rho2), 0.0),
                  0.0)
    v = jnp.where(free_pos, jnp.abs(scores - rho1), v)
    v = jnp.where(at_hi, jnp.maximum(scores - rho1, 0.0), v)
    v = jnp.where(free_neg, jnp.abs(scores - rho2), v)
    v = jnp.where(at_lo, jnp.maximum(rho2 - scores, 0.0), v)
    if valid is not None:
        v = jnp.where(valid, v, 0.0)
    return v


def _masked(valid: Optional[Array], mask: Array) -> Array:
    return mask if valid is None else (valid & mask)


def _rho_from_parts(sum1, n1, sum2, n2, r1_lo, r1_hi, r2_lo, r2_hi, big):
    """Free-SV means with KKT-interval-midpoint fallback (eq. 20-21)."""
    mean1 = sum1 / jnp.maximum(n1, 1.0)
    mean2 = sum2 / jnp.maximum(n2, 1.0)
    r1_mid = jnp.where((r1_lo > -big / 2) & (r1_hi < big / 2),
                       0.5 * (r1_lo + r1_hi),
                       jnp.where(r1_hi < big / 2, r1_hi, r1_lo))
    r2_mid = jnp.where((r2_lo > -big / 2) & (r2_hi < big / 2),
                       0.5 * (r2_lo + r2_hi),
                       jnp.where(r2_lo > -big / 2, r2_lo, r2_hi))
    rho1 = jnp.where(n1 > 0, mean1, r1_mid)
    rho2 = jnp.where(n2 > 0, mean2, r2_mid)
    return rho1, rho2


def _rho_masks(gamma: Array, valid: Optional[Array], *, hi: float, lo: float,
               m: int, tol: float):
    ghi = hi * tol * m      # absolute slack scaled to the box size
    glo = -lo * tol * m
    return dict(
        free_lower=_masked(valid, (gamma > ghi) & (gamma < hi - ghi)),
        free_upper=_masked(valid, (gamma < -glo) & (gamma > lo + glo)),
        at_hi=_masked(valid, gamma >= hi - ghi),
        at_lo=_masked(valid, gamma <= lo + glo),
        nonneg=_masked(valid, gamma >= -glo),   # gamma >= 0: s <= rho2 side
        nonpos=_masked(valid, gamma <= ghi),    # gamma <= 0: s >= rho1 side
    )


def recover_rhos(gamma: Array, scores: Array, *, hi: float, lo: float,
                 m: int, comm: LocalComm = LOCAL_COMM,
                 valid: Optional[Array] = None,
                 tol: float = 1e-6) -> Tuple[Array, Array]:
    """rho1 / rho2 from on-margin SVs, midpoint fallback when a plane has
    no free SV. One psum + one pmax when ``comm`` is a mesh."""
    dtype = scores.dtype
    big = jnp.asarray(jnp.finfo(dtype).max / 4, dtype)
    mk = _rho_masks(gamma, valid, hi=hi, lo=lo, m=m, tol=tol)

    ps = comm.psum(jnp.stack([
        jnp.sum(jnp.where(mk["free_lower"], scores, 0.0)),
        jnp.sum(mk["free_lower"]).astype(dtype),
        jnp.sum(jnp.where(mk["free_upper"], scores, 0.0)),
        jnp.sum(mk["free_upper"]).astype(dtype),
    ]))
    pm = comm.pmax(jnp.stack([
        jnp.max(jnp.where(mk["at_hi"], scores, -big)),
        jnp.max(jnp.where(mk["nonneg"], scores, -big)),
        -jnp.min(jnp.where(mk["nonpos"], scores, big)),
        -jnp.min(jnp.where(mk["at_lo"], scores, big)),
    ]))
    return _rho_from_parts(ps[0], ps[1], ps[2], ps[3],
                           pm[0], -pm[2], pm[1], -pm[3], big)


def _gap_masks(gamma: Array, valid: Optional[Array], *, hi: float,
               lo: float):
    bnd = 1e-8 * (hi - lo)            # bound-identification slack
    up = _masked(valid, gamma < hi - bnd)    # can increase
    dn = _masked(valid, gamma > lo + bnd)    # can decrease
    return up, dn


def solver_stats_fresh(gamma: Array, f: Array, rho1_prev: Array,
                       rho2_prev: Array, recompute_rho, *, hi: float,
                       lo: float, m: int, tol: float,
                       comm: LocalComm = LOCAL_COMM,
                       valid: Optional[Array] = None):
    """(rho1, rho2, n_viol, max_viol, gap) with violations vs FRESH rho."""
    dtype = f.dtype
    neg = jnp.asarray(-jnp.inf, dtype)
    pos = jnp.asarray(jnp.inf, dtype)

    rho1, rho2 = recover_rhos(gamma, f, hi=hi, lo=lo, m=m, comm=comm,
                              valid=valid)
    rho1 = jnp.where(recompute_rho, rho1, rho1_prev)
    rho2 = jnp.where(recompute_rho, rho2, rho2_prev)

    v = violation(gamma, f, rho1, rho2, hi=hi, lo=lo, m=m, valid=valid)
    up, dn = _gap_masks(gamma, valid, hi=hi, lo=lo)
    n_viol = comm.psum(jnp.sum(v > tol).astype(dtype)).astype(jnp.int32)
    pm = comm.pmax(jnp.stack([
        jnp.max(v),
        jnp.max(jnp.where(dn, f, neg)),
        -jnp.min(jnp.where(up, f, pos)),
    ]))
    gap = pm[1] + pm[2]
    return rho1, rho2, n_viol, pm[0], gap


def solver_stats_prev(gamma: Array, f: Array, rho1_prev: Array,
                      rho2_prev: Array, recompute_rho, *, hi: float,
                      lo: float, m: int, tol: float,
                      comm: LocalComm = LOCAL_COMM,
                      valid: Optional[Array] = None):
    """(rho1, rho2, n_viol, max_viol, gap) in exactly 2 collectives.

    psum vector: [sum_free_lower_f, n_free_lower, sum_free_upper_f,
                  n_free_upper, n_violators]
    pmax vector: [r1_lo, r2_lo, -r1_hi, -r2_hi, max_viol,
                  max_f_down, -min_f_up]       (mins as negated maxes)

    Violations are measured against ``rho*_prev`` so the rho sums and the
    violation stats share one round trip.
    """
    dtype = f.dtype
    big = jnp.asarray(jnp.finfo(dtype).max / 4, dtype)
    neg = jnp.asarray(-jnp.inf, dtype)
    pos = jnp.asarray(jnp.inf, dtype)

    mk = _rho_masks(gamma, valid, hi=hi, lo=lo, m=m, tol=1e-6)
    up, dn = _gap_masks(gamma, valid, hi=hi, lo=lo)
    v = violation(gamma, f, rho1_prev, rho2_prev, hi=hi, lo=lo, m=m,
                  valid=valid)

    ps = comm.psum(jnp.stack([
        jnp.sum(jnp.where(mk["free_lower"], f, 0.0)),
        jnp.sum(mk["free_lower"]).astype(dtype),
        jnp.sum(jnp.where(mk["free_upper"], f, 0.0)),
        jnp.sum(mk["free_upper"]).astype(dtype),
        jnp.sum(v > tol).astype(dtype),
    ]))
    pm = comm.pmax(jnp.stack([
        jnp.max(jnp.where(mk["at_hi"], f, -big)),
        jnp.max(jnp.where(mk["nonneg"], f, -big)),
        -jnp.min(jnp.where(mk["nonpos"], f, big)),
        -jnp.min(jnp.where(mk["at_lo"], f, big)),
        jnp.max(v),
        jnp.max(jnp.where(dn, f, neg)),
        -jnp.min(jnp.where(up, f, pos)),
    ]))

    rho1, rho2 = _rho_from_parts(ps[0], ps[1], ps[2], ps[3],
                                 pm[0], -pm[2], pm[1], -pm[3], big)
    rho1 = jnp.where(recompute_rho, rho1, rho1_prev)
    rho2 = jnp.where(recompute_rho, rho2, rho2_prev)
    return rho1, rho2, ps[4].astype(jnp.int32), pm[4], pm[5] + pm[6]
