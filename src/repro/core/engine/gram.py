"""GramProvider — the pluggable Gram-access axis of the solver engine.

A provider owns the training rows and answers the four kernel-matrix
queries the SMO hot loop needs, each against a ``Selection`` of 2P rows:

* ``init_scores(gamma)``          — f = K @ gamma (once, at solve start)
* ``block(sel)``                  — the (2P, 2P) Gram block of the pairs
* ``apply_update(f, sel, delta)`` — f + K[:, sel] @ delta (rank-2P update,
                                    the per-iteration hot path)
* ``scatter(gamma, sel, delta)``  — fold the pair steps back into gamma

Implementations:

* ``precomputed`` — materialize K once (O(m^2) memory; small m / tests).
* ``on_the_fly``  — recompute the needed kernel rows from X per iteration
                    (O(m d) per step, no m^2 memory).
* ``pallas``      — ``on_the_fly`` with the f-cache update fused into the
                    Pallas ``kernels/fupdate`` kernel (one HBM pass over X
                    per iteration; interpret mode on non-TPU backends), and
                    the init pass fused the same way when m is small enough
                    for the selected block to sit in VMEM.
* ``sharded``     — device-local rows under ``shard_map``: updates touch
                    only the local f/gamma slices, selections arrive as
                    gathered (2P, d) row blocks so no global indexing is
                    ever needed.

Every provider takes a ``precision`` ("f32" default, "bf16", "f16"): the
training rows are round-tripped through the tile dtype ONCE at
construction, so the pure-jnp providers see exactly the rounded values
the Pallas provider streams in 16-bit tiles — a given (selector,
precision) pair converges to the same gamma whichever provider runs it.
Norms, the f-cache, gamma and all epilogues stay f32
(``repro.kernels.precision``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernel_fn import KernelFn
from repro.core.engine.types import Selection
from repro.kernels.fupdate.ops import fupdate
from repro.kernels.precision import check_precision, round_to_tile

Array = jax.Array

# Largest m for a single unblocked cross-kernel pass; above this,
# row-blocked accumulation (raw_scores_blocked / _blocked pieces) keeps the
# working set at O(BLOCK * m) instead of O(m^2). Shared by every caller
# that decides "one pass vs blocked" (scores, objectives, shrinking).
SINGLE_PASS_MAX = 4096
BLOCK = 2048


def raw_scores_blocked(X: Array, gamma: Array, kernel: KernelFn,
                       block: int = BLOCK) -> Array:
    """K @ gamma without materializing K (row-blocked above the threshold)."""
    m = X.shape[0]
    if m <= SINGLE_PASS_MAX:
        return kernel.cross(X, X) @ gamma
    nblk = (m + block - 1) // block
    pad = nblk * block - m
    Xp = jnp.pad(X, ((0, pad), (0, 0)))

    def body(i, acc):
        xb = jax.lax.dynamic_slice_in_dim(Xp, i * block, block)
        return jax.lax.dynamic_update_slice_in_dim(
            acc, kernel.cross(xb, X) @ gamma, i * block, 0)

    out = jax.lax.fori_loop(0, nblk, body,
                            jnp.zeros((nblk * block,), gamma.dtype))
    return out[:m]


class _ScoreDeltas:
    """Shared O(s * m) score-delta algebra — the warm-start substrate.

    Every provider mixes this in: ``delta_scores`` folds a rank-s kernel
    contribution into an f-cache with ONE pass over the owned rows (the
    fused Pallas sweep under the pallas/sharded providers), and
    ``append_rows``/``expire_rows`` compose it with a cache rebuild so a
    data delta costs O(dm * m) instead of the O(m^2) cold init.
    ``reconcile_scores`` is the driver-facing entry: it turns a
    ``engine.state.WarmStart``'s assumed-configuration f_seed into the
    new problem's exact K @ gamma0.
    """

    def delta_scores(self, f: Array, X_delta: Array,
                     g_delta: Array) -> Array:
        """f + k(X_own, X_delta) @ g_delta — one pass, no m^2 anything."""
        if X_delta.shape[0] == 0:
            return f
        return f + self.kernel.rows(self.X, X_delta) @ g_delta

    def reconcile_scores(self, warm) -> Array:
        """Fold a WarmStart's correction set into its seeded f-cache.

        ``prepare_warm_start`` guarantees the result equals K @ gamma0
        over the owned rows (the local slice when sharded — zero
        collectives: corrections ride replicated, f_seed rides sharded).
        """
        return self.delta_scores(warm.f_seed, warm.x_corr, warm.delta)

    def append_rows(self, X_app, gamma: Array, f: Array, g_app=None):
        """(provider', gamma', f') for the extended problem [X; X_app].

        Appended rows default to gamma = 0 (fresh data), so surviving
        scores are untouched; their own scores cost one O(dm * m) pass.
        A nonzero ``g_app`` first folds the same-rank delta into the
        surviving f. Host-side API (between solves, concrete shapes).
        """
        Xa = round_to_tile(
            jnp.asarray(X_app, jnp.float32).reshape(-1, self.X.shape[1]),
            self.precision)
        if g_app is None:
            g_app = jnp.zeros((Xa.shape[0],), jnp.float32)
            f_old = f
        else:
            g_app = jnp.asarray(g_app, jnp.float32)
            f_old = self.delta_scores(f, Xa, g_app)
        p2 = self._rebuilt_extended(Xa)
        gamma2 = jnp.concatenate([jnp.asarray(gamma, jnp.float32), g_app])
        # The appended rows' own scores against the full extended set.
        f_app = self.kernel.rows(p2.X, Xa).T @ gamma2
        return p2, gamma2, jnp.concatenate([f_old, f_app])

    def expire_rows(self, idx, gamma: Array, f: Array):
        """(provider', gamma', f') with rows ``idx`` removed — O(e * m).

        Surviving scores lose the expired rows' kernel columns times
        their gamma (one rank-e sweep); no O(m^2) recompute. Host-side
        API (between solves, concrete indices).
        """
        idx = np.asarray(idx, np.int64).reshape(-1)
        keep = np.setdiff1d(np.arange(self.X.shape[0]), idx)
        Xe = self.X[jnp.asarray(idx)].reshape(-1, self.X.shape[1])
        ge = jnp.asarray(gamma)[jnp.asarray(idx)].reshape(-1)
        f2 = self.delta_scores(f, Xe, -ge)[jnp.asarray(keep)]
        return (self._rebuilt_shrunk(keep), jnp.asarray(gamma)[keep], f2)

    def _rebuilt_extended(self, Xa: Array):
        raise NotImplementedError

    def _rebuilt_shrunk(self, keep: np.ndarray):
        raise NotImplementedError


class PrecomputedGram(_ScoreDeltas):
    """Materialized m x m Gram matrix: every query is a gather/matmul."""

    name = "precomputed"

    def __init__(self, X: Array, kernel: KernelFn, precision: str = "f32",
                 *, _K: Array | None = None):
        self.precision = check_precision(precision)
        self.X = round_to_tile(X, precision)
        self.kernel = kernel
        self.K = kernel.gram(self.X) if _K is None else _K
        self._diag = kernel.diag(self.X)

    def _rebuilt_extended(self, Xa: Array) -> "PrecomputedGram":
        # Extend K with the new cross block — O(dm * m) kernel evals,
        # not a fresh O(m^2) gram.
        C = self.kernel.rows(self.X, Xa)              # (m, dm)
        Kaa = self.kernel.cross(Xa, Xa)
        K2 = jnp.block([[self.K, C], [C.T, Kaa]])
        return PrecomputedGram(jnp.concatenate([self.X, Xa], axis=0),
                               self.kernel, self.precision, _K=K2)

    def _rebuilt_shrunk(self, keep: np.ndarray) -> "PrecomputedGram":
        kj = jnp.asarray(keep)
        return PrecomputedGram(self.X[kj], self.kernel, self.precision,
                               _K=self.K[kj][:, kj])

    def diag(self) -> Array:
        return self._diag

    def column(self, i) -> Array:
        return self.K[:, i]

    def init_scores(self, gamma: Array) -> Array:
        return self.K @ gamma

    def prepare(self, sel: Selection) -> Selection:
        # Gather the 2P columns once; block() and apply_update() both
        # read them, halving the per-iteration gather traffic.
        if sel.rows is None:
            sel = sel._replace(rows=self.K[:, sel.ids])
        return sel

    def block(self, sel: Selection) -> Array:
        if sel.rows is not None:
            return sel.rows[sel.ids]
        return self.K[sel.ids][:, sel.ids]

    def diag_sel(self, sel: Selection) -> Array:
        return self._diag[sel.ids]

    def apply_update(self, f: Array, sel: Selection, delta: Array) -> Array:
        rows = self.K[:, sel.ids] if sel.rows is None else sel.rows
        return f + rows @ delta

    def scatter(self, gamma: Array, sel: Selection, delta: Array) -> Array:
        return gamma.at[sel.ids].add(delta)


class OnTheFlyGram(_ScoreDeltas):
    """Recompute the <= 2P needed kernel rows from X each iteration."""

    name = "on_the_fly"

    def __init__(self, X: Array, kernel: KernelFn, precision: str = "f32"):
        self.precision = check_precision(precision)
        self.X = round_to_tile(X, precision)
        self.kernel = kernel
        self._diag = kernel.diag(self.X)

    def _rebuilt_extended(self, Xa: Array) -> "OnTheFlyGram":
        return type(self)._clone(self, jnp.concatenate([self.X, Xa],
                                                       axis=0))

    def _rebuilt_shrunk(self, keep: np.ndarray) -> "OnTheFlyGram":
        return type(self)._clone(self, self.X[jnp.asarray(keep)])

    @classmethod
    def _clone(cls, proto: "OnTheFlyGram", X2: Array) -> "OnTheFlyGram":
        return cls(X2, proto.kernel, precision=proto.precision)

    def diag(self) -> Array:
        return self._diag

    def column(self, i) -> Array:
        return self.kernel.rows(self.X, self.X[i][None, :])[:, 0]

    def init_scores(self, gamma: Array) -> Array:
        return raw_scores_blocked(self.X, gamma, self.kernel)

    def prepare(self, sel: Selection) -> Selection:
        return sel   # rows are recomputed exactly where needed

    def block(self, sel: Selection) -> Array:
        if sel.rows is not None:
            return sel.rows[sel.ids]
        return self.kernel.cross(sel.X, sel.X)

    def diag_sel(self, sel: Selection) -> Array:
        return self._diag[sel.ids]

    def apply_update(self, f: Array, sel: Selection, delta: Array) -> Array:
        rows = (self.kernel.rows(self.X, sel.X) if sel.rows is None
                else sel.rows)
        return f + rows @ delta

    def scatter(self, gamma: Array, sel: Selection, delta: Array) -> Array:
        return gamma.at[sel.ids].add(delta)


class PallasGram(OnTheFlyGram):
    """on_the_fly with the rank-2P f update fused into the Pallas kernel."""

    name = "pallas"

    def __init__(self, X: Array, kernel: KernelFn,
                 interpret: bool | None = None, precision: str = "f32"):
        super().__init__(X, kernel, precision=precision)
        self.interpret = interpret   # None -> auto (True off-TPU)

    def init_scores(self, gamma: Array) -> Array:
        if self.X.shape[0] <= BLOCK:
            # f = 0 + k(X, X) @ gamma in one fused pass; the whole selected
            # block must fit VMEM, so only below the blocking threshold.
            zero = jnp.zeros((self.X.shape[0],), jnp.float32)
            return fupdate(self.X, self.X, gamma, zero, self.kernel,
                           interpret=self.interpret,
                           precision=self.precision)
        return raw_scores_blocked(self.X, gamma, self.kernel)

    def apply_update(self, f: Array, sel: Selection, delta: Array) -> Array:
        if sel.rows is not None:
            # A selector already produced the full columns (paper rule's
            # movability mask) — reusing them beats a second HBM pass.
            return f + sel.rows @ delta
        # self.X is already tile-rounded, so the in-kernel cast to the
        # 16-bit stream dtype is exact — kernel and jnp paths agree.
        return fupdate(self.X, sel.X, delta, f, self.kernel,
                       interpret=self.interpret, precision=self.precision)

    def delta_scores(self, f: Array, X_delta: Array,
                     g_delta: Array) -> Array:
        # The warm-start reconcile sweep IS the hot-loop rank-2P update
        # with the correction set as the selected block — same fused
        # kernel, one HBM pass over X. Above BLOCK the selected block
        # would not sit in VMEM; fall back to the jnp pass.
        if X_delta.shape[0] == 0:
            return f
        if X_delta.shape[0] > BLOCK:
            return super().delta_scores(f, X_delta, g_delta)
        return fupdate(self.X, X_delta, g_delta, f, self.kernel,
                       interpret=self.interpret, precision=self.precision)

    @classmethod
    def _clone(cls, proto: "PallasGram", X2: Array) -> "PallasGram":
        return cls(X2, proto.kernel, interpret=proto.interpret,
                   precision=proto.precision)


class ShardedGram(_ScoreDeltas):
    """Device-local rows under shard_map; f/gamma are local slices.

    ``gids`` are this shard's global row ids; selections carry gathered
    (2P, d) row blocks, so the per-iteration update needs no communication
    at all — only ``init_scores`` all-gathers (once, column-blocked).
    The rank-2P f update runs the SAME fused Pallas ``fupdate`` kernel as
    the single-device ``PallasGram``, applied to the local rows (interpret
    mode on CPU; ``interpret=None`` auto-detects like the local provider).

    ``comm`` is the facade's ``MeshComm`` over the data axes: the
    init-time gathers route through it so the ``CollectiveLedger`` (when
    attached) sees every collective this provider issues.

    Precision invariant: ``X_local`` is tile-rounded at construction
    (idempotent), and the selector feeding this provider must gather its
    candidate rows from the same rounded shard data — the distributed
    facade rounds once, before building both. ``fupdate`` then re-casts
    the already-rounded rows to the 16-bit stream dtype exactly, so the
    kernel and jnp paths agree bit-for-bit on the Gram entries.
    """

    name = "sharded"

    def __init__(self, X_local: Array, kernel: KernelFn, *, gids: Array,
                 rank: Array, m_local: int, m_pad: int, comm,
                 interpret: bool | None = None, precision: str = "f32"):
        self.precision = check_precision(precision)
        self.X = round_to_tile(X_local, precision)
        self.kernel = kernel
        self.gids = gids
        self.rank = rank
        self.m_local = m_local
        self.m_pad = m_pad
        self.comm = comm
        self.axes = comm.axes
        self.interpret = interpret   # None -> auto (True off-TPU)

    def init_scores(self, gamma_local: Array) -> Array:
        # Local f needs the *global* K gamma: gather X and gamma once, then
        # accumulate over column blocks — the full (m_local x m) cross-Gram
        # block would be hundreds of GB at m = 1M.
        X_all = self.comm.all_gather(self.X, tiled=True)
        g_all = self.comm.all_gather(gamma_local, tiled=True)
        blk = BLOCK
        nblk = (self.m_pad + blk - 1) // blk
        Xp = jnp.pad(X_all, ((0, nblk * blk - self.m_pad), (0, 0)))
        gp = jnp.pad(g_all, (0, nblk * blk - self.m_pad))  # pad 0: no-op

        def fblock(i, acc):
            xb = jax.lax.dynamic_slice_in_dim(Xp, i * blk, blk)
            gb = jax.lax.dynamic_slice_in_dim(gp, i * blk, blk)
            return acc + self.kernel.cross(self.X, xb) @ gb

        return jax.lax.fori_loop(
            0, nblk, fblock, jnp.zeros((self.m_local,), jnp.float32))

    def prepare(self, sel: Selection) -> Selection:
        return sel

    def block(self, sel: Selection) -> Array:
        return self.kernel.cross(sel.X, sel.X)

    def diag_sel(self, sel: Selection) -> Array:
        return self.kernel.diag(sel.X)

    def apply_update(self, f: Array, sel: Selection, delta: Array) -> Array:
        # Rank-2P update of the local rows only — no communication: the
        # same fused Pallas pass as PallasGram, per shard. self.X is
        # tile-rounded here and sel.X carries rows the selector gathered
        # from the SAME rounded shard data (the distributed facade rounds
        # X_local once, before building provider and selector), so the
        # in-kernel cast to the 16-bit stream dtype is exact. fupdate's
        # internal pads (selected block to a lane multiple, rows/features
        # to tile multiples) carry zero deltas / zero rows and contribute
        # exactly 0 to f (tests assert this bitwise, bf16/f16 included).
        return fupdate(self.X, sel.X, delta, f, self.kernel,
                       interpret=self.interpret, precision=self.precision)

    def scatter(self, gamma: Array, sel: Selection, delta: Array) -> Array:
        loc = sel.ids - self.rank * self.m_local
        in_range = (loc >= 0) & (loc < self.m_local)
        loc_c = jnp.clip(loc, 0, self.m_local - 1)
        return gamma.at[loc_c].add(jnp.where(in_range, delta, 0.0))

    def delta_scores(self, f: Array, X_delta: Array,
                     g_delta: Array) -> Array:
        # Rank-s delta of the LOCAL f slice against REPLICATED delta rows
        # — zero collectives, same fused Pallas pass as apply_update.
        # This is how the sharded warm start reconciles: f_seed rides
        # sharded like gamma, the correction set rides replicated, and
        # every shard folds its own slice independently.
        if X_delta.shape[0] == 0:
            return f
        if X_delta.shape[0] > BLOCK:
            return f + self.kernel.rows(self.X, X_delta) @ g_delta
        return fupdate(self.X, X_delta, g_delta, f, self.kernel,
                       interpret=self.interpret, precision=self.precision)

    def append_rows(self, X_app, gamma: Array, f: Array, g_app=None):
        """Sharded append is a facade-level operation (row placement,
        gids and m_pad all change shape across every shard), so the
        provider's share is the score algebra only: ``delta_scores`` /
        ``reconcile_scores`` on the local slice. The distributed facade
        re-shards rows and rebuilds providers — see
        ``solve_blocked_distributed(..., warm=)``."""
        raise NotImplementedError(
            "sharded append is handled by the distributed facade "
            "(re-shard + warm=); use delta_scores for the local f algebra")

    def expire_rows(self, idx, gamma: Array, f: Array):
        raise NotImplementedError(
            "sharded expiry is handled by the distributed facade "
            "(re-shard + warm=); use delta_scores for the local f algebra")


def make_provider(gram_mode: str, X: Array, kernel: KernelFn,
                  interpret: bool | None = None, precision: str = "f32"):
    """Build a local provider by name ("sharded" is constructed explicitly
    by the distributed facade — it needs the shard topology)."""
    if gram_mode == "precomputed":
        return PrecomputedGram(X, kernel, precision=precision)
    if gram_mode == "on_the_fly":
        return OnTheFlyGram(X, kernel, precision=precision)
    if gram_mode == "pallas":
        return PallasGram(X, kernel, interpret=interpret,
                          precision=precision)
    raise ValueError(f"unknown gram_mode {gram_mode!r}")
