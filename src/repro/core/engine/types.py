"""Shared state/selection pytrees for the pluggable solver engine."""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax

Array = jax.Array


class SolverState(NamedTuple):
    """The one carried state for every SMO variant (a while_loop pytree).

    For the sharded provider ``gamma``/``f`` are the device-local slices;
    everything else is replicated scalars.
    """

    gamma: Array      # (m,) dual coefficients (local slice when sharded)
    f: Array          # (m,) raw-score cache K @ gamma
    rho1: Array       # lower-plane offset (eq. 20)
    rho2: Array       # upper-plane offset (eq. 21)
    it: Array         # int32 iteration counter
    n_viol: Array     # int32 current KKT violator count
    max_viol: Array   # float max KKT violation
    gap: Array        # float MVP duality gap: max f|down - min f|up
    stall: Array      # int32 consecutive no-progress steps


class Selection(NamedTuple):
    """A working set of 2P rows: the grow half [0:P], the shrink half [P:2P].

    ``ids`` are *global* row indices (== local indices on one device).
    ``gamma``/``f``/``X`` are the gathered per-row values, so providers can
    evaluate kernel rows without re-indexing sharded arrays.
    """

    ids: Array        # (2P,) int32 row ids
    gamma: Array      # (2P,) current dual values
    f: Array          # (2P,) current scores
    X: Array          # (2P, d) selected data rows
    # Optional (m, 2P) kernel columns a selector already computed while
    # choosing the working set (the paper selector needs full rows for its
    # movability mask); providers reuse them instead of recomputing.
    rows: Optional[Array] = None

    @property
    def n_pairs(self) -> int:
        return self.ids.shape[0] // 2


class SMOResult(NamedTuple):
    """Public result type shared by every solver facade."""

    model: "object"   # OCSSVMModel (kept loose to avoid an import cycle)
    iters: Array
    n_viol: Array
    max_viol: Array
    gap: Array
    converged: Array
    # Final f-cache K @ gamma over the full training set. Facades populate
    # it so ``engine.state.artifact_from_result`` can package a warm-start
    # artifact without an O(m^2) score recompute; None from legacy paths.
    f: Optional[Array] = None
