"""repro.core.engine — the pluggable SMO solver engine.

The paper's contribution is a single analytic 2-variable update (eq.
35-39); everything else about training — how Gram rows are produced, which
rows move, how convergence is measured — is policy. This package factors
the solver into three orthogonal axes so every training scenario composes
the same hot loop instead of re-implementing it:

    SolverState ──▶ Selector.select ──▶ Selection (2P rows)
                          │                   │
                          │            provider.block (2P x 2P)
                          │                   ▼
                          │         gauss_seidel_pairs (eq. 35-39)
                          │                   │ delta (2P,)
                          ▼                   ▼
                provider.scatter     provider.apply_update
                  (gamma += )         (f += K[:, sel] @ delta —
                          │            the Pallas fupdate kernel)
                          └───────┬───────────┘
                                  ▼
                       stats_fn (rho recovery + KKT + gap,
                        <= 2 collectives when sharded)

Axes
----
* **GramProvider** (``gram.py``) — ``precomputed`` (materialized K),
  ``on_the_fly`` (recompute <= 2P rows per step), ``pallas`` (the fused
  ``kernels/fupdate`` HBM-single-pass update; interpret mode on CPU),
  ``sharded`` (device-local slices under shard_map; selection arrives as
  gathered row blocks so updates need zero communication).
* **Selector** (``select.py``) — ``paper`` (eq. 56 heuristic, KKT
  termination), ``mvp`` (Keerthi maximal-violating pair), ``block``
  (top-P pairs per sweep; P=1 reduces to the paper's single-pair rule),
  ``ShardedBlockSelector`` (globally-consistent top-P from per-shard
  candidates, one all_gather of O(P d) bytes).
* **Driver** (``driver.py``) — ONE ``jax.lax.while_loop`` with the
  stall/patience/gap logic; ``stats.py`` holds rho recovery and the KKT /
  duality-gap diagnostics written once, comm-parameterized (identity
  reductions locally, two fused collectives per iteration on a mesh).

Facades
-------
``repro.core.smo.solve``, ``repro.core.batched_smo.solve_blocked``,
``repro.core.distributed_smo.solve_blocked_distributed`` and
``repro.core.shrinking.solve_blocked_shrinking`` keep their public
signatures and assemble (provider, selector, stats) for this driver;
``repro.fit`` picks the composition from the problem size.
"""
from repro.core.engine.driver import (gauss_seidel_pairs, has_converged,
                                      init_state, run)
from repro.core.engine.gram import (BLOCK, SINGLE_PASS_MAX, OnTheFlyGram,
                                    PallasGram, PrecomputedGram, ShardedGram,
                                    make_provider, raw_scores_blocked)
from repro.core.engine.select import (BlockSelector, PaperSelector,
                                      ShardedBlockSelector, make_selector)
from repro.core.engine.stats import (LOCAL_COMM, CollectiveLedger,
                                     CollectiveRecord, LocalComm, MeshComm,
                                     recover_rhos, slab_margin,
                                     solver_stats_fresh, solver_stats_prev,
                                     violation)
from repro.core.engine.state import (SolverArtifact, WarmStart,
                                     WarmStartInfo, artifact_from_result,
                                     match_rows, prepare_warm_start,
                                     row_hashes)
from repro.core.engine.types import Selection, SMOResult, SolverState

__all__ = [
    "run", "init_state", "gauss_seidel_pairs", "has_converged",
    "make_provider", "PrecomputedGram", "OnTheFlyGram", "PallasGram",
    "ShardedGram", "raw_scores_blocked", "SINGLE_PASS_MAX", "BLOCK",
    "make_selector", "PaperSelector", "BlockSelector",
    "ShardedBlockSelector",
    "LocalComm", "MeshComm", "LOCAL_COMM", "CollectiveLedger",
    "CollectiveRecord", "recover_rhos", "slab_margin",
    "violation", "solver_stats_fresh", "solver_stats_prev",
    "Selection", "SMOResult", "SolverState",
    "SolverArtifact", "WarmStart", "WarmStartInfo", "artifact_from_result",
    "match_rows", "prepare_warm_start", "row_hashes",
]
