"""Warm-start solver state as a first-class, checkpointable artifact.

The SMO decomposition is naturally warm-startable: the driver's
``SolverState`` (gamma, f-cache) is a valid restart point for any nearby
problem — the slab box (two bound sets) makes feasibility easy to
restore, and under small data deltas only a small part of the active set
actually moves. This module makes that restart point public:

* ``SolverArtifact`` — everything a later solve needs to warm-start from
  a finished fit: gamma, the final f-cache, the training rows, per-row
  content hashes (for overlap matching against new data), the concrete
  spec and precision. ``save``/``load`` round-trip it through one
  ``.npz`` file, so a serving fleet can checkpoint its restart points.
* ``prepare_warm_start(prev, X_new, spec)`` — align a prior artifact
  with a *new* training set (rows appended, expired, or both), seed
  gamma from the overlapping rows, clip it back into the new slab box,
  repair the equality constraint with a minimal-touch water-fill, and
  emit the sparse **correction set** whose single fused ``fupdate``
  sweep turns the prior f-cache into the new problem's f-cache — no
  O(m^2) recompute.

The f-cache algebra: let C be the *assumed* configuration — the prior
gamma carried over to the surviving rows (zero on appended rows) plus
the prior gamma still sitting on the expired rows. The prior f-cache IS
the score of every surviving row under C, appended rows get their score
under C in one O(dm * m) pass, and the warm seed ``gamma0`` differs
from C only on a sparse set: clipped coordinates, water-fill touches,
and the expired rows (whose coefficient must go to zero). One rank-s
update f += k(X, X_corr) @ delta — the same fused Pallas ``fupdate``
kernel the hot loop runs — lands every row on K_new @ gamma0 exactly
(up to f32 reassociation). Total warm-start cost is
O((dm + s) * m * d) against the cold O(m^2 * d) init, with s the number
of changed coordinates (typically the bound-SV count).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

# Coefficients smaller than this are "zero" for correction purposes: an
# expired row carrying |gamma| below it never contributed measurably to
# any score, so it needs no correction column.
_GAMMA_ZERO = 1e-12


class WarmStart(NamedTuple):
    """The pure-array warm seed a solver facade threads into the engine.

    A jit-traversable pytree: the facades pass it as a traced argument,
    and ``GramProvider.reconcile_scores`` folds ``x_corr``/``delta``
    into ``f_seed`` with one fused sweep. Build it with
    ``prepare_warm_start`` — the invariant the engine relies on is
    ``f_seed + k(X_new, x_corr) @ delta == K_new @ gamma0``.
    """

    gamma0: Array    # (m,) feasible warm gamma for the NEW problem
    f_seed: Array    # (m,) scores of the assumed (prior) configuration
    x_corr: Array    # (s, d) rows whose coefficient changed vs assumed
    delta: Array     # (s,) the coefficient deltas


@dataclasses.dataclass(frozen=True)
class WarmStartInfo:
    """Host-side accounting for one prepared warm start (not a pytree)."""

    m: int             # new problem size
    m_prev: int        # prior problem size
    n_overlap: int     # new rows seeded from the prior fit
    n_fresh: int       # appended rows (no prior gamma/f)
    n_expired: int     # prior rows absent from the new set
    n_corr: int        # correction columns in the fused sweep
    overlap_frac: float  # n_overlap / m — the fallback-routing signal


def row_hashes(X) -> np.ndarray:
    """Per-row 64-bit content hashes of the f32 view of ``X``.

    The f32 cast mirrors what every solver facade does to its input, so
    the same logical rows hash equal regardless of the caller's dtype.
    blake2b (not a positional sample): a hash collision here would seed
    a *wrong f-cache*, which — unlike a wrong gamma seed — the solver
    trusts rather than repairs.
    """
    a = np.ascontiguousarray(np.asarray(X, np.float32))
    if a.ndim != 2:
        raise ValueError(f"expected (m, d) rows, got shape {a.shape}")
    out = np.empty(a.shape[0], np.uint64)
    for i, row in enumerate(a):
        out[i] = int.from_bytes(
            hashlib.blake2b(row.tobytes(), digest_size=8).digest(), "little")
    return out


def match_rows(prev_hashes: np.ndarray, new_hashes: np.ndarray
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """One-to-one alignment of new rows onto prior rows by content hash.

    Returns ``(new_ov, prev_ov, new_fresh, prev_expired)`` index arrays:
    ``new[new_ov[k]]`` is the same row as ``prev[prev_ov[k]]``;
    duplicated rows match multiset-style (each prior copy is consumed at
    most once, so a row appearing twice before and once after counts one
    overlap and one expiry).
    """
    pool: dict = {}
    for j, h in enumerate(prev_hashes.tolist()):
        pool.setdefault(h, []).append(j)
    new_ov, prev_ov, new_fresh = [], [], []
    for i, h in enumerate(new_hashes.tolist()):
        js = pool.get(h)
        if js:
            new_ov.append(i)
            prev_ov.append(js.pop())
        else:
            new_fresh.append(i)
    expired = sorted(j for js in pool.values() for j in js)
    return (np.asarray(new_ov, np.int64), np.asarray(prev_ov, np.int64),
            np.asarray(new_fresh, np.int64), np.asarray(expired, np.int64))


def clip_to_box(gamma: np.ndarray, *, hi: float, lo: float,
                total: float) -> np.ndarray:
    """Project a gamma seed into the new slab box and repair the equality.

    Clip first (restores the box), then water-fill the equality residual
    into the coordinates with the MOST slack first — the minimal-touch
    repair, so the correction set the f-cache sweep must fold stays
    sparse (a proportional redistribution would touch every row).
    """
    g = np.clip(np.asarray(gamma, np.float64), lo, hi)
    r = total - float(g.sum())
    if abs(r) <= 1e-12 * max(1.0, abs(total)):
        return g.astype(np.float32)
    slack = (hi - g) if r > 0 else (g - lo)
    step = 1.0 if r > 0 else -1.0
    order = np.argsort(-slack, kind="stable")
    need = abs(r)
    for i in order:
        if need <= 0:
            break
        take = min(need, float(slack[i]))
        g[i] += step * take
        need -= take
    if need > 1e-9 * max(1.0, abs(total)):
        raise ValueError(
            f"cannot restore sum(gamma) == {total}: the box has "
            f"insufficient slack (residual {need:.3e}) — the spec is "
            "infeasible for this m")
    return g.astype(np.float32)


@dataclasses.dataclass
class SolverArtifact:
    """A finished fit packaged as a restart point (checkpointable).

    ``gamma``/``f`` are the solver's final dual vector and f-cache over
    ``X`` (the f32 training rows as the facade saw them); ``hashes`` are
    ``row_hashes(X)``, precomputed so registry-scale refresh loops never
    re-hash an unchanged fleet member. ``spec`` is concrete (hashable)
    and ``precision`` records the Gram tile dtype of the fit — warm
    starts prepared from this artifact round correction rows to the same
    tiles, so the fused sweep agrees bit-for-bit with the provider's
    Gram entries.
    """

    gamma: np.ndarray    # (m,) f32
    f: np.ndarray        # (m,) f32 final f-cache (K @ gamma)
    rho1: float
    rho2: float
    X: np.ndarray        # (m, d) f32 training rows
    hashes: np.ndarray   # (m,) uint64 row content hashes
    spec: object         # concrete SlabSpec
    precision: str = "f32"

    @property
    def m(self) -> int:
        return int(self.X.shape[0])

    def support_mask(self, threshold: float = 1e-7) -> np.ndarray:
        return np.abs(self.gamma) > threshold

    def save(self, path: str) -> None:
        """Checkpoint to one ``.npz`` (spec flattened to scalars)."""
        k = self.spec.kernel
        np.savez(
            path, gamma=self.gamma, f=self.f, X=self.X, hashes=self.hashes,
            rho=np.asarray([self.rho1, self.rho2], np.float64),
            spec_scalars=np.asarray(
                [self.spec.nu1, self.spec.nu2, self.spec.eps, k.gamma,
                 k.coef0, float(k.degree)], np.float64),
            kernel_name=np.asarray(k.name),
            precision=np.asarray(self.precision))

    @classmethod
    def load(cls, path: str) -> "SolverArtifact":
        from repro.core.kernel_fn import KernelFn
        from repro.core.ocssvm import SlabSpec
        z = np.load(path, allow_pickle=False)
        nu1, nu2, eps, kg, kc, kd = (float(v) for v in z["spec_scalars"])
        spec = SlabSpec(nu1=nu1, nu2=nu2, eps=eps,
                        kernel=KernelFn(name=str(z["kernel_name"]),
                                        gamma=kg, coef0=kc, degree=int(kd)))
        rho1, rho2 = (float(v) for v in z["rho"])
        return cls(gamma=z["gamma"], f=z["f"], rho1=rho1, rho2=rho2,
                   X=z["X"], hashes=z["hashes"], spec=spec,
                   precision=str(z["precision"]))


def artifact_from_result(res, *, precision: str = "f32",
                         hashes: Optional[np.ndarray] = None
                         ) -> SolverArtifact:
    """Package an ``SMOResult`` as a restart point.

    Facades populate ``res.f`` (the final f-cache) — when a caller hands
    a result from an older path without it, the cache is rebuilt with
    one blocked K @ gamma pass (O(m^2 d) flops but O(m) memory; still a
    single pass, not a solve).
    """
    from repro.core.engine.gram import raw_scores_blocked
    from repro.core.ocssvm import concrete_spec
    model = res.model
    X = np.asarray(model.X, np.float32)
    f = res.f
    if f is None:
        f = raw_scores_blocked(jnp.asarray(X), model.gamma,
                               concrete_spec(model.spec).kernel)
    return SolverArtifact(
        gamma=np.asarray(model.gamma, np.float32),
        f=np.asarray(f, np.float32),
        rho1=float(model.rho1), rho2=float(model.rho2), X=X,
        hashes=hashes if hashes is not None else row_hashes(X),
        spec=concrete_spec(model.spec), precision=precision)


def prepare_warm_start(prev: SolverArtifact, X_new, spec, *,
                       precision: Optional[str] = None
                       ) -> Tuple[WarmStart, WarmStartInfo]:
    """Align a prior fit with a new training set and build the warm seed.

    Host-side (concrete shapes): matching, clipping and the equality
    repair run in numpy; the appended rows' seed scores are the one
    O(dm * m * d) jnp pass. The returned ``WarmStart`` satisfies
    ``f_seed + k(X_new, x_corr) @ delta == K_new @ gamma0`` (up to f32
    reassociation), which is exactly what
    ``GramProvider.reconcile_scores`` folds with one fused sweep.

    ``precision`` defaults to the artifact's — correction rows are
    rounded to those tiles so the sweep sees the same Gram entries the
    provider streams.
    """
    from repro.core.ocssvm import concrete_spec
    from repro.kernels.precision import round_to_tile
    spec = concrete_spec(spec)
    if precision is None:
        precision = prev.precision
    X32 = np.ascontiguousarray(np.asarray(X_new, np.float32))
    m = X32.shape[0]
    hi, lo, total = spec.upper(m), spec.lower(m), spec.total()

    new_ov, prev_ov, new_fresh, prev_exp = match_rows(prev.hashes,
                                                      row_hashes(X32))
    # Assumed configuration C: prior gamma on surviving rows (0 on
    # appended rows) + prior gamma still sitting on the expired rows.
    g_assumed = np.zeros(m, np.float32)
    g_assumed[new_ov] = prev.gamma[prev_ov]
    f_seed = np.zeros(m, np.float32)
    f_seed[new_ov] = prev.f[prev_ov]

    prev_exp = prev_exp[np.abs(prev.gamma[prev_exp]) > _GAMMA_ZERO]
    Xr = round_to_tile(jnp.asarray(X32), precision)
    X_exp = round_to_tile(
        jnp.asarray(prev.X[prev_exp].reshape(-1, X32.shape[1])), precision)
    g_exp = prev.gamma[prev_exp].astype(np.float32)

    if new_fresh.size:
        # Appended rows' score under C: one O(dm * (m + e) * d) pass.
        Xf = Xr[jnp.asarray(new_fresh)]
        s_fresh = spec.kernel.cross(Xf, Xr) @ jnp.asarray(g_assumed)
        if prev_exp.size:
            s_fresh = s_fresh + spec.kernel.cross(Xf, X_exp) @ jnp.asarray(
                g_exp)
        f_seed[new_fresh] = np.asarray(s_fresh, np.float32)

    gamma0 = clip_to_box(g_assumed, hi=hi, lo=lo, total=total)
    moved = np.nonzero(gamma0 != g_assumed)[0]
    x_corr = jnp.concatenate(
        [Xr[jnp.asarray(moved)].reshape(-1, X32.shape[1]), X_exp], axis=0)
    delta = jnp.concatenate(
        [jnp.asarray((gamma0 - g_assumed)[moved]), jnp.asarray(-g_exp)])

    warm = WarmStart(gamma0=jnp.asarray(gamma0), f_seed=jnp.asarray(f_seed),
                     x_corr=x_corr, delta=delta)
    info = WarmStartInfo(
        m=m, m_prev=prev.m, n_overlap=int(new_ov.size),
        n_fresh=int(new_fresh.size), n_expired=int(prev_exp.size),
        n_corr=int(delta.shape[0]),
        overlap_frac=float(new_ov.size) / max(m, 1))
    return warm, info
