"""Selector — the pluggable working-set-selection axis of the engine.

A selector looks at the current ``SolverState`` and returns a ``Selection``
of 2P rows (grow half first, shrink half second) for the Gauss-Seidel pair
solve. Its ``criterion`` attribute names the termination test the driver
applies: ``"kkt"`` (paper Algorithm 1: stop when at most one violator) or
``"gap"`` (Keerthi MVP duality gap <= tol).

* ``PaperSelector``      — the paper's eq. 56 heuristic: b = argmax
  |f_bar| among KKT violators, a = argmax |f_bar(b) - f_bar(a)| among
  partners whose clipped step is nonzero (without the movability mask the
  iteration deadlocks on bound-blocked pairs).
* ``BlockSelector``      — top-P Keerthi working set: the P smallest
  scores that can grow x the P largest that can shrink (disjoint). P=1 is
  the classic maximal-violating pair, and the pair update the driver
  applies is exactly the paper's analytic 2-variable rule.
* ``ShardedBlockSelector`` — BlockSelector under shard_map: every shard
  proposes local top-P candidates; one all_gather of the tiny packed
  candidate set (O(P d) per shard, independent of m) makes the global
  selection identical on every device.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.engine.stats import slab_margin, violation
from repro.core.engine.types import Selection, SolverState

Array = jax.Array
_TINY = 1e-12


class PaperSelector:
    """One violating pair per iteration, the paper's eq. 56 heuristic."""

    criterion = "kkt"

    def __init__(self, provider, *, hi: float, lo: float, m: int,
                 tol: float):
        self.provider = provider
        self.hi, self.lo, self.m, self.tol = hi, lo, m, tol

    def select(self, s: SolverState) -> Selection:
        hi, lo = self.hi, self.lo
        dtype = s.f.dtype
        neg = jnp.asarray(-jnp.inf, dtype)
        tiny = jnp.asarray(_TINY, dtype)

        v = violation(s.gamma, s.f, s.rho1, s.rho2, hi=hi, lo=lo, m=self.m)
        fbar = slab_margin(s.f, s.rho1, s.rho2)
        b = jnp.argmax(jnp.where(v > self.tol, jnp.abs(fbar), neg))

        # Candidate step size against every partner a (needs row b).
        kb = self.provider.column(b)
        diagK = self.provider.diag()
        eta_den = jnp.maximum(diagK + diagK[b] - 2.0 * kb, tiny)
        t = s.gamma + s.gamma[b]
        L = jnp.maximum(t - hi, lo)
        H = jnp.minimum(hi, t - lo)
        gb_t = s.gamma[b] + (s.f - s.f[b]) / eta_den
        movable = jnp.abs(jnp.clip(gb_t, L, H) - s.gamma[b]) > tiny * 10
        gap_score = jnp.where(movable, jnp.abs(fbar[b] - fbar), neg)
        gap_score = gap_score.at[b].set(neg)
        a = jnp.argmax(gap_score)

        ids = jnp.stack([b, a]).astype(jnp.int32)
        # kb is already paid for; add ka so the driver's rank-2 f update
        # reuses both columns instead of recomputing them.
        rows = jnp.stack([kb, self.provider.column(a)], axis=1)
        return Selection(ids=ids, gamma=s.gamma[ids], f=s.f[ids],
                         X=self.provider.X[ids], rows=rows)


class BlockSelector:
    """Top-P maximal-violating pairs in one vectorized sweep (P=1 == MVP)."""

    criterion = "gap"

    def __init__(self, provider, *, P: int, hi: float, lo: float):
        self.provider = provider
        self.P = P
        self.hi, self.lo = hi, lo
        self.bnd = 1e-8 * (hi - lo)

    def select(self, s: SolverState) -> Selection:
        neg = jnp.asarray(-jnp.inf, s.f.dtype)
        up = s.gamma < self.hi - self.bnd
        dn = s.gamma > self.lo + self.bnd
        # P "grow" coordinates: smallest scores among movable-up.
        _, up_idx = jax.lax.top_k(jnp.where(up, -s.f, neg), self.P)
        # P "shrink" coordinates: largest scores among movable-down,
        # excluding the grow set (disjointness).
        dn_score = jnp.where(dn, s.f, neg).at[up_idx].set(neg)
        _, dn_idx = jax.lax.top_k(dn_score, self.P)
        ids = jnp.concatenate([up_idx, dn_idx]).astype(jnp.int32)
        return Selection(ids=ids, gamma=s.gamma[ids], f=s.f[ids],
                         X=self.provider.X[ids])


class ShardedBlockSelector:
    """Globally-consistent block selection from per-shard candidates.

    ``comm`` is the facade's ``MeshComm`` over the data axes; the one
    per-iteration candidate gather routes through it so the attached
    ``CollectiveLedger`` accounts its O(P d) payload.
    """

    criterion = "gap"

    def __init__(self, X_local: Array, *, P: int, hi: float, lo: float,
                 gids: Array, valid: Array, comm):
        self.X = X_local
        self.P = P
        self.hi, self.lo = hi, lo
        self.bnd = 1e-8 * (hi - lo)
        self.gids = gids
        self.valid = valid
        self.comm = comm
        self.axes = comm.axes

    def select(self, s: SolverState) -> Selection:
        P = self.P
        dtype = s.f.dtype
        neg = jnp.asarray(-jnp.inf, dtype)
        up = self.valid & (s.gamma < self.hi - self.bnd)
        dn = self.valid & (s.gamma > self.lo + self.bnd)

        # Local candidates.
        up_val, up_i = jax.lax.top_k(jnp.where(up, -s.f, neg), P)
        dn_val, dn_i = jax.lax.top_k(jnp.where(dn, s.f, neg), P)

        # Pack both candidate sides into ONE matrix so selection costs a
        # single all-gather instead of ten (ids ride as f32 — exact below
        # 2^24 rows; the solver is latency-bound at scale).
        def pack(idx, val):
            return jnp.concatenate(
                [val[:, None], self.gids[idx].astype(dtype)[:, None],
                 s.gamma[idx][:, None], s.f[idx][:, None], self.X[idx]],
                axis=1)                          # (P, 4 + d)

        cand = jnp.stack([pack(up_i, up_val), pack(dn_i, dn_val)])
        cand_g = self.comm.all_gather(cand, tiled=False)
        # (n_shards, 2, P, 4+d) -> per side (n_shards*P, 4+d)
        cg = cand_g.transpose(1, 0, 2, 3).reshape(2, -1, cand.shape[-1])
        uv, uid = cg[0, :, 0], cg[0, :, 1].astype(jnp.int32)
        ug, uf, uX = cg[0, :, 2], cg[0, :, 3], cg[0, :, 4:]
        dv, did = cg[1, :, 0], cg[1, :, 1].astype(jnp.int32)
        dg, df_, dX = cg[1, :, 2], cg[1, :, 3], cg[1, :, 4:]

        _, usel = jax.lax.top_k(uv, P)          # global top-P grows
        up_ids = uid[usel]
        # Exclude grow picks from shrink candidates (disjoint pairs).
        clash = (did[:, None] == up_ids[None, :]).any(axis=1)
        _, dsel = jax.lax.top_k(jnp.where(clash, neg, dv), P)

        ids = jnp.concatenate([up_ids, did[dsel]])
        return Selection(
            ids=ids,
            gamma=jnp.concatenate([ug[usel], dg[dsel]]),
            f=jnp.concatenate([uf[usel], df_[dsel]]),
            X=jnp.concatenate([uX[usel], dX[dsel]], axis=0))


def make_selector(selection: str, provider, *, P: int, hi: float, lo: float,
                  m: int, tol: float):
    """Build a local selector by name ("sharded" is constructed explicitly
    by the distributed facade)."""
    if selection == "paper":
        return PaperSelector(provider, hi=hi, lo=lo, m=m, tol=tol)
    if selection == "mvp":
        return BlockSelector(provider, P=1, hi=hi, lo=lo)
    if selection == "block":
        return BlockSelector(provider, P=P, hi=hi, lo=lo)
    raise ValueError(f"unknown selection {selection!r}")
