"""The one SMO driver: Gauss-Seidel pair solve + the lax.while_loop.

Every solver facade (sequential paper SMO, blocked, sharded, shrinking
rounds) runs THIS loop — the provider decides how Gram rows are produced,
the selector decides which rows move, and the stall/patience/gap logic
lives here exactly once.

Each iteration:

1. ``selector.select`` picks a 2P working set (grow half, shrink half),
2. ``gauss_seidel_pairs`` runs the paper's analytic 2-variable update
   (eq. 35-39) over the P pairs against the small (2P, 2P) Gram block,
   keeping the selected scores exact — a true block-coordinate-descent
   step, monotone on the dual, same fixed points as Algorithm 1,
3. the provider folds the step back: a rank-2P f-cache update (the Pallas
   ``fupdate`` kernel under ``gram_mode="pallas"``) and a gamma scatter,
4. ``stats_fn`` re-estimates rho1/rho2 and the convergence diagnostics.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.engine.types import Selection, SolverState

Array = jax.Array
_TINY = 1e-12

# stats_fn(gamma, f, rho1_prev, rho2_prev, recompute_rho)
#   -> (rho1, rho2, n_viol, max_viol, gap)
StatsFn = Callable[..., tuple]


def gauss_seidel_pairs(sel: Selection, Kblk: Array, dsl: Array, *,
                       hi: float, lo: float) -> Array:
    """Solve the P analytic 2-variable subproblems sequentially.

    Pair k couples row k (grow side) with row P+k (shrink side). Every
    step moves on the equality hyperplane and is clipped to the box, so
    feasibility is exact; the selected scores are updated against the
    (2P, 2P) block so each step sees the previous pairs' moves.
    Returns delta = gamma_sel_final - gamma_sel_0, shape (2P,).
    """
    P = sel.n_pairs
    tiny = jnp.asarray(_TINY, sel.f.dtype)

    def inner(k, carry):
        g_sel, f_sel = carry
        ib, ia = k, P + k
        eta = 1.0 / jnp.maximum(dsl[ia] + dsl[ib] - 2.0 * Kblk[ia, ib],
                                tiny)
        t = g_sel[ia] + g_sel[ib]
        L = jnp.maximum(t - hi, lo)
        H = jnp.minimum(hi, t - lo)
        gb_new = jnp.clip(g_sel[ib] + eta * (f_sel[ia] - f_sel[ib]), L, H)
        dgb = gb_new - g_sel[ib]
        # Degenerate pair (duplicate index from top_k ties): freeze.
        dgb = jnp.where(sel.ids[ia] == sel.ids[ib], 0.0, dgb)
        g_sel = g_sel.at[ib].add(dgb).at[ia].add(-dgb)
        f_sel = f_sel + dgb * (Kblk[:, ib] - Kblk[:, ia])
        return g_sel, f_sel

    g_fin, _ = jax.lax.fori_loop(0, P, inner, (sel.gamma, sel.f))
    return g_fin - sel.gamma


def init_state(provider, stats_fn: StatsFn, gamma0: Array,
               f_offset: Optional[Array] = None,
               ledger=None, warm=None) -> SolverState:
    """Score the initial gamma and measure the starting diagnostics.

    f_offset: constant per-row score contribution from coordinates OUTSIDE
    this problem (the shrinking driver freezes bound coordinates and solves
    the active subset; their kernel contribution rides along here).
    warm: optional ``engine.state.WarmStart`` — instead of the O(m^2)
    K @ gamma0 pass, the f-cache is RECONCILED from the prior fit's
    f_seed with one fused rank-s sweep over the correction set
    (``provider.reconcile_scores``, the Pallas ``fupdate`` kernel under
    the pallas/sharded providers). The caller passes
    ``gamma0 == warm.gamma0`` (its local slice when sharded) — the
    invariant ``reconcile_scores(warm) == K @ gamma0`` is what
    ``state.prepare_warm_start`` constructs.
    ledger: optional ``CollectiveLedger`` — everything traced here is
    one-time work, so it is tagged phase="init".
    """
    if ledger is not None:
        ledger.set_phase("init")
    if warm is not None:
        f = provider.reconcile_scores(warm)
    else:
        f = provider.init_scores(gamma0)
    if f_offset is not None:
        f = f + f_offset.astype(f.dtype)
    zero = jnp.zeros((), f.dtype)
    # Two passes: the first recovers rho, the second measures diagnostics
    # against it (free on a single device; 2 extra collectives sharded).
    rho1, rho2, _, _, _ = stats_fn(gamma0, f, zero, zero, True)
    rho1, rho2, n_viol, max_viol, gap = stats_fn(gamma0, f, rho1, rho2, True)
    return SolverState(gamma0, f, rho1, rho2,
                       jnp.zeros((), jnp.int32), n_viol, max_viol, gap,
                       jnp.zeros((), jnp.int32))


def run(provider, selector, stats_fn: StatsFn, state0: SolverState, *,
        hi: float, lo: float, tol: float, max_iters: int, patience: int,
        rho_every: int = 1, ledger=None) -> SolverState:
    """Iterate select -> pair-solve -> rank-2P update until converged.

    Termination (selector.criterion):
      "kkt" — paper Algorithm 1: at most one KKT violator (or a uniformly
              small max violation — same optimum);
      "gap" — Keerthi MVP duality gap <= tol.
    Both additionally stop at max_iters or after ``patience`` consecutive
    zero-progress steps (bound-blocked working sets).

    ledger: optional ``CollectiveLedger``. The while_loop body is traced
    exactly once, so collectives recorded from here on are tagged
    phase="iter" — the per-iteration collective bill.
    """
    if ledger is not None:
        ledger.set_phase("iter")
    criterion = selector.criterion
    tiny = jnp.asarray(_TINY, state0.f.dtype)

    def not_done(s: SolverState):
        if criterion == "kkt":
            unconverged = (s.n_viol > 1) & (s.max_viol > tol)
        else:
            unconverged = s.gap > tol
        return (s.it < max_iters) & unconverged & (s.stall < patience)

    def body(s: SolverState):
        sel = provider.prepare(selector.select(s))
        Kblk = provider.block(sel)
        dsl = provider.diag_sel(sel)
        delta = gauss_seidel_pairs(sel, Kblk, dsl, hi=hi, lo=lo)

        gamma_new = provider.scatter(s.gamma, sel, delta)
        f_new = provider.apply_update(s.f, sel, delta)

        recompute = (rho_every == 1) | ((s.it + 1) % rho_every == 0)
        r1, r2, n_viol, max_viol, gap = stats_fn(
            gamma_new, f_new, s.rho1, s.rho2, recompute)

        progressed = jnp.max(jnp.abs(delta)) > tiny * 10
        stall = jnp.where(progressed, 0, s.stall + 1).astype(jnp.int32)
        return SolverState(gamma_new, f_new, r1, r2, s.it + 1,
                           n_viol, max_viol, gap, stall)

    return jax.lax.while_loop(not_done, body, state0)


def has_converged(s: SolverState, criterion: str, tol: float) -> Array:
    if criterion == "kkt":
        return (s.n_viol <= 1) | (s.max_viol <= tol)
    return s.gap <= tol
