"""Mercer kernel functions for the OCSSVM dual.

All kernels expose three access patterns the SMO solver needs:

* ``gram(X)``        — full m x m Gram matrix (small-m / test path only).
* ``cross(X, Y)``    — m x n cross-kernel block (decision function, blocked SMO).
* ``rows(X, idx)``   — k(X, X[idx]) rows computed on the fly (large-m path;
                       this is what the Pallas ``fupdate`` kernel fuses).

Everything is pure jnp and jit-friendly; the kernel choice is static.
"""
from __future__ import annotations

import dataclasses
import jax
import jax.numpy as jnp

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class KernelFn:
    """A Mercer kernel with static name and traced hyper-parameters.

    name: one of {"linear", "rbf", "poly"}.
    gamma: RBF width / poly scale (ignored for linear).
    coef0, degree: poly parameters.
    """

    name: str = "linear"
    gamma: float = 1.0
    coef0: float = 0.0
    degree: int = 3

    # -- pytree plumbing (name/degree static; gamma/coef0 traced) ----------
    def tree_flatten(self):
        return (self.gamma, self.coef0), (self.name, self.degree)

    @classmethod
    def tree_unflatten(cls, aux, children):
        gamma, coef0 = children
        name, degree = aux
        return cls(name=name, gamma=gamma, coef0=coef0, degree=degree)

    # -- core evaluations ---------------------------------------------------
    def cross(self, X: Array, Y: Array) -> Array:
        """K[i, j] = k(X[i], Y[j]); shapes (m, d), (n, d) -> (m, n)."""
        if self.name == "linear":
            return X @ Y.T
        if self.name == "rbf":
            # ||x - y||^2 = ||x||^2 + ||y||^2 - 2 x.y, computed in f32.
            xx = jnp.sum(X * X, axis=-1, keepdims=True)
            yy = jnp.sum(Y * Y, axis=-1, keepdims=True)
            sq = xx + yy.T - 2.0 * (X @ Y.T)
            return jnp.exp(-self.gamma * jnp.maximum(sq, 0.0))
        if self.name == "poly":
            return (self.gamma * (X @ Y.T) + self.coef0) ** self.degree
        raise ValueError(f"unknown kernel {self.name!r}")

    def gram(self, X: Array) -> Array:
        return self.cross(X, X)

    def rows(self, X: Array, Xsel: Array) -> Array:
        """k(X, Xsel) -> (m, k). ``Xsel`` is a gathered (k, d) block."""
        return self.cross(X, Xsel)

    def diag(self, X: Array) -> Array:
        """k(x_i, x_i) for every row — needed for eta without the Gram."""
        if self.name == "linear":
            return jnp.sum(X * X, axis=-1)
        if self.name == "rbf":
            return jnp.ones((X.shape[0],), X.dtype)
        if self.name == "poly":
            return (self.gamma * jnp.sum(X * X, axis=-1) + self.coef0) ** self.degree
        raise ValueError(f"unknown kernel {self.name!r}")


def linear() -> KernelFn:
    return KernelFn(name="linear")


def rbf(gamma: float = 1.0) -> KernelFn:
    return KernelFn(name="rbf", gamma=gamma)


def poly(gamma: float = 1.0, coef0: float = 1.0, degree: int = 3) -> KernelFn:
    return KernelFn(name="poly", gamma=gamma, coef0=coef0, degree=degree)
