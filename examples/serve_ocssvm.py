"""Batched OCSSVM scoring service — the serving half of the paper system.

Everything goes through the ``repro.serve`` subsystem: the warm-model
cache fits on miss and packs the support set for the decision kernel
once; the scorer pads every batch to a bucket so each size hits a cached
executable; the service micro-batches queued requests into one launch.

    PYTHONPATH=src python examples/serve_ocssvm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.core import SlabSpec, rbf
from repro.data import make_toy
from repro.serve import ScoringService


def main():
    spec = SlabSpec(nu1=0.5, nu2=0.05, eps=0.5, kernel=rbf(gamma=0.5))
    X, _ = make_toy(jax.random.PRNGKey(0), 2000)

    t0 = time.perf_counter()
    sm = repro.serve(X, spec, offsets="quantile", P=16, tol=1e-3)
    cold = time.perf_counter() - t0
    print(f"model: {sm.n_sv} SVs (packed {tuple(sm.t_pad.shape)}), "
          f"slab [{float(sm.rho1):.4f}, {float(sm.rho2):.4f}], "
          f"cold fit+pack {cold*1e3:.0f} ms")

    t0 = time.perf_counter()
    repro.serve(X, spec, offsets="quantile", P=16, tol=1e-3)  # cache hit
    print(f"warm re-serve: {(time.perf_counter() - t0)*1e3:.2f} ms "
          f"(cache {repro.serve.default_cache().hits} hits / "
          f"{repro.serve.default_cache().misses} misses)")

    svc = ScoringService(sm.scorer())
    for batch_size in (64, 256, 1024):
        q, yq = make_toy(jax.random.PRNGKey(1), batch_size)
        svc.score(np.asarray(q))               # warm the bucket executable
        scores = svc.score(np.asarray(q))
        s = svc.stats[batch_size]
        acc = float((jnp.where(scores >= 0, 1, -1) == yq).mean())
        print(f"batch={batch_size:5d}: {s.last_s*1e3:7.2f} ms "
              f"({s.last_s/batch_size*1e6:6.1f} us/query) acc={acc:.3f}")

    # micro-batching: many small requests coalesce into one launch
    reqs = [np.asarray(make_toy(jax.random.PRNGKey(10 + i), 48)[0])
            for i in range(8)]
    for q in reqs:
        svc.submit(q)
    launches = svc.flush()
    print(f"micro-batch: {len(reqs)} x 48-row requests -> "
          f"{launches} launch(es)")
    for line in svc.stats_lines():
        print("  " + line)

    # cross-check against the model's jnp reference path
    q, _ = make_toy(jax.random.PRNGKey(2), 128)
    np.testing.assert_allclose(np.asarray(sm.score(np.asarray(q))),
                               np.asarray(sm.model.decision_function(q)),
                               rtol=2e-4, atol=2e-4)
    print("pallas == jnp reference: OK")


if __name__ == "__main__":
    main()
