"""Batched OCSSVM scoring service — the serving half of the paper system.

Fits a slab once, then serves batched scoring requests through the Pallas
``decision`` kernel (the TPU hot path; interpret mode on CPU).

    PYTHONPATH=src python examples/serve_ocssvm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.core import SlabSpec, rbf, with_quantile_offsets
from repro.data import make_toy
from repro.kernels import decision


def main():
    spec = SlabSpec(nu1=0.5, nu2=0.05, eps=0.5, kernel=rbf(gamma=0.5))
    X, _ = make_toy(jax.random.PRNGKey(0), 2000)
    res = repro.fit(X, spec, P=16, tol=1e-3)   # auto provider+selector
    model = with_quantile_offsets(res.model)  # beyond-paper: usable slab
    print(f"model: {int(jnp.sum(jnp.abs(model.gamma) > 1e-7))} SVs, "
          f"slab [{float(model.rho1):.4f}, {float(model.rho2):.4f}]")

    # batched scoring via the Pallas decision kernel
    def serve(queries):
        return decision(queries, model.X, model.gamma, model.rho1,
                        model.rho2, spec.kernel)

    for batch_size in (64, 256, 1024):
        q, yq = make_toy(jax.random.PRNGKey(1), batch_size)
        scores = serve(q)
        jax.block_until_ready(scores)
        t0 = time.perf_counter()
        scores = serve(q)
        jax.block_until_ready(scores)
        dt = time.perf_counter() - t0
        acc = float((jnp.where(scores >= 0, 1, -1) == yq).mean())
        print(f"batch={batch_size:5d}: {dt*1e3:7.2f} ms "
              f"({dt/batch_size*1e6:6.1f} us/query) acc={acc:.3f}")
    # cross-check against the model's jnp reference path
    q, _ = make_toy(jax.random.PRNGKey(2), 128)
    np.testing.assert_allclose(np.asarray(serve(q)),
                               np.asarray(model.decision_function(q)),
                               rtol=2e-4, atol=2e-4)
    print("pallas == jnp reference: OK")


if __name__ == "__main__":
    main()
