"""End-to-end training driver: a llama-family model on the synthetic
pipeline with checkpointing + fault-tolerant supervision.

Default is a CPU-sized ~10M-param model for a quick demo; --params-100m
selects the ~100M config used for the real few-hundred-step run.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses

import jax

from repro.configs import get_arch
from repro.configs.base import LayerSpec
from repro.data.synthetic import SyntheticPipeline
from repro.models.transformer import init_params
from repro.runtime.fault_tolerance import FaultTolerantLoop
from repro.train.train_step import init_train_state, make_train_step


def small_config(full_100m: bool):
    base = get_arch("llama3.2-3b")
    if full_100m:
        # 103M params: 2*49152*640 embeddings + 10 layers
        return dataclasses.replace(
            base, n_layers=10, d_model=640, n_heads=10, n_kv_heads=5,
            head_dim=64, d_ff=2560, vocab_size=49152,
            layer_pattern=(LayerSpec("full"),), param_dtype="float32",
            remat="none")
    return dataclasses.replace(
        base, n_layers=4, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=1024, vocab_size=8192, layer_pattern=(LayerSpec("full"),),
        param_dtype="float32", remat="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--params-100m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = small_config(args.params_100m)
    print(f"model: {cfg.param_count()/1e6:.1f}M params, "
          f"{cfg.n_layers}L x {cfg.d_model}d")

    params = init_params(cfg, jax.random.PRNGKey(0))
    state = init_train_state(cfg, params)
    step = jax.jit(make_train_step(cfg, peak_lr=3e-3, warmup_steps=20,
                                   total_steps=args.steps))
    pipe = SyntheticPipeline(cfg, batch=args.batch, seq_len=args.seq_len,
                             seed=0)
    loop = FaultTolerantLoop(step, state, pipe, args.ckpt_dir,
                             save_every=50)
    loop.run(args.steps)
    first = loop.metrics_log[0]
    last = loop.metrics_log[-1]
    print(f"step {first['step']}: loss {first['loss']:.3f}")
    print(f"step {last['step']}: loss {last['loss']:.3f} "
          f"({last['step_time_s']*1000:.0f} ms/step)")
    print(f"checkpoints in {args.ckpt_dir}; restarts={loop.restarts}")


if __name__ == "__main__":
    main()
