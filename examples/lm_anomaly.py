"""Open-set recognition with the paper's classifier on LM features —
the OCSSVM slab head as a first-class framework feature.

1. Briefly train a small LM on "in-distribution" synthetic text (narrow
   token marginal).
2. Pool final hidden states as features.
3. Fit the slab with the blocked SMO solver.
4. Score held-out ID and OOD sequences; report separation (AUC).

    PYTHONPATH=src python examples/lm_anomaly.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_arch
from repro.configs.base import LayerSpec
from repro.core import SlabSpec, fit_head, rbf
from repro.models.transformer import forward, init_params
from repro.train.train_step import init_train_state, make_train_step


def main():
    cfg = dataclasses.replace(
        get_arch("llama3.2-3b"), n_layers=4, d_model=128, n_heads=4,
        n_kv_heads=2, head_dim=32, d_ff=512, vocab_size=2048,
        layer_pattern=(LayerSpec("full"),), param_dtype="float32",
        remat="none")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)

    def id_batch(k, n):   # in-distribution: narrow token range
        return jax.random.randint(k, (n, 32), 0, 256)

    def ood_batch(k, n):  # OOD: tokens from the other end of the vocab
        return jax.random.randint(k, (n, 32), cfg.vocab_size - 256,
                                  cfg.vocab_size)

    # 1. brief LM training on ID data
    state = init_train_state(cfg, params)
    step = jax.jit(make_train_step(cfg, peak_lr=3e-3, warmup_steps=10,
                                   total_steps=60))
    for i in range(60):
        k = jax.random.fold_in(key, i)
        toks = id_batch(k, 16)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        state, m = step(state, batch)
    print(f"LM trained: final loss {float(m['loss']):.3f}")

    # 2. features = mean-pooled final hidden state (pre-unembed)
    def features(tokens):
        logits, _, _ = forward(state.params, cfg, tokens=tokens)
        # cheap backbone feature proxy: top-64 logit dims, mean pooled
        return logits[..., :64].mean(axis=1)

    k1, k2, k3 = jax.random.split(key, 3)
    F_train = features(id_batch(k1, 256))
    F_id = features(id_batch(k2, 128))
    F_ood = features(ood_batch(k3, 128))

    # 3. slab head (paper's classifier, blocked SMO)
    spec = SlabSpec(nu1=0.2, nu2=0.1, eps=0.3, kernel=rbf(gamma=0.05))
    head = fit_head(F_train, spec, solver="blocked", P=8, tol=1e-3)
    print(f"head fitted: iters={int(head.result.iters)} "
          f"converged={bool(head.result.converged)}")

    # 4. separation
    s_id = np.asarray(head.score(F_id))
    s_ood = np.asarray(head.score(F_ood))
    auc = float(np.mean(s_id[:, None] > s_ood[None, :]))
    print(f"ID score mean {s_id.mean():+.4f} | OOD score mean "
          f"{s_ood.mean():+.4f} | AUC = {auc:.3f}")


if __name__ == "__main__":
    main()
