"""Quickstart: train a One-Class Slab SVM with the paper's SMO.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

import repro
from repro.configs.ocssvm_paper import PAPER_SPEC
from repro.core import SlabSpec, mcc, rbf, with_quantile_offsets
from repro.data import make_toy


def main():
    X, y = make_toy(jax.random.PRNGKey(0), 1000)

    print("== paper-faithful SMO (Algorithm 1, paper's linear protocol) ==")
    res = repro.fit(X, PAPER_SPEC, strategy="paper", tol=1e-3)
    print(f"iters={int(res.iters)} converged={bool(res.converged)} "
          f"rho1={float(res.model.rho1):.4f} rho2={float(res.model.rho2):.4f}")
    print(f"train MCC = {float(mcc(y, res.model.predict(X))):.3f} "
          f"(paper Table 1 reports 0.13 at m=1000)")

    print("== blocked TPU-native SMO (engine auto strategy, P=16, RBF) ==")
    spec = SlabSpec(nu1=0.3, nu2=0.05, eps=0.4, kernel=rbf(gamma=0.8))
    res_b = repro.fit(X, spec, P=16, tol=1e-3)
    model = with_quantile_offsets(res_b.model)   # primal-consistent slab
    print(f"iters={int(res_b.iters)} converged={bool(res_b.converged)} "
          f"MCC={float(mcc(y, model.predict(X))):.3f}")

    # score new points
    Xq, yq = make_toy(jax.random.PRNGKey(1), 200)
    scores = model.decision_function(Xq)
    acc = float((model.predict(Xq) == yq).mean())
    print(f"held-out: accuracy={acc:.3f} "
          f"scores range [{float(scores.min()):.3f}, {float(scores.max()):.3f}]")


if __name__ == "__main__":
    main()
