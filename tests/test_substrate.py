"""Substrate units: optimizers, schedules, data pipeline, gradient
compression, HLO analyzer, sharding rules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.data.synthetic import SyntheticPipeline
from repro.optim import adafactor, adamw
from repro.optim.compression import compress
from repro.optim.schedules import warmup_cosine
from repro.utils import hlo_analysis


# --- optimizers -----------------------------------------------------------

def _quadratic_steps(opt, n=200, lr=0.1):
    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    state = opt.init(params)
    for _ in range(n):
        grads = {"w": 2.0 * params["w"]}     # d/dw ||w||^2
        params, state = opt.update(grads, state, params, lr=lr)
    return float(jnp.abs(params["w"]).max())


def test_adamw_converges_quadratic():
    assert _quadratic_steps(adamw, lr=0.05) < 0.05


def test_adafactor_converges_quadratic():
    assert _quadratic_steps(adafactor, lr=0.05) < 0.05


def test_adafactor_state_is_factored():
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((64,))}
    st = adafactor.init(params)
    assert st.vr["w"].shape == (64,)
    assert st.vc["w"].shape == (32,)
    assert st.vr["b"].shape == (64,)


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(jnp.asarray(s), peak_lr=1.0,
                               warmup_steps=10, total_steps=100))
           for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(1.0)
    assert lrs[-1] == pytest.approx(0.1, abs=1e-5)


# --- gradient compression -------------------------------------------------

def test_compress_error_feedback_is_lossless_in_the_limit():
    g = jnp.asarray(np.random.default_rng(0).standard_normal(1000),
                    dtype=jnp.float32)
    err = jnp.zeros_like(g)
    total_deq = jnp.zeros_like(g)
    # applying the same gradient repeatedly: error feedback means the
    # cumulative dequantized sum tracks the cumulative true sum
    for i in range(50):
        q, scale, err = compress(g, err)
        total_deq = total_deq + q.astype(jnp.float32) * scale
    rel = float(jnp.abs(total_deq - 50 * g).max() / jnp.abs(g).max())
    assert rel < 0.1


# --- data pipeline --------------------------------------------------------

def test_pipeline_determinism_and_resume():
    cfg = ARCHS["llama3.2-3b"].reduced()
    p1 = SyntheticPipeline(cfg, batch=2, seq_len=16, seed=7)
    batches = [p1.next_batch() for _ in range(4)]
    # resume from a checkpointed cursor
    p2 = SyntheticPipeline(cfg, batch=2, seq_len=16, seed=7)
    p2.load_state_dict({"seed": 7, "step": 2})
    b2 = p2.next_batch()
    np.testing.assert_array_equal(np.asarray(batches[2]["tokens"]),
                                  np.asarray(b2["tokens"]))


def test_pipeline_modalities():
    for arch in ("musicgen-large", "internvl2-26b"):
        cfg = ARCHS[arch].reduced()
        b = SyntheticPipeline(cfg, batch=2, seq_len=16, seed=0).next_batch()
        if cfg.frontend == "audio":
            assert b["embeds"].shape == (2, 16, cfg.d_model)
        else:
            assert b["vision_embeds"].shape == (2, cfg.n_frontend_tokens,
                                                cfg.d_model)
            assert b["tokens"].shape[1] == 16 - cfg.n_frontend_tokens


# --- HLO analyzer ---------------------------------------------------------

def test_hlo_analyzer_scales_while_loops():
    def f(x, w):
        def body(c, _):
            return jnp.maximum(c @ w, 0.0), None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    cost = hlo_analysis.analyze(compiled.as_text())
    expected = 10 * 2 * 64 * 128 * 128
    assert cost.flops == pytest.approx(expected, rel=0.05)


def test_hlo_analyzer_shape_parsing():
    assert hlo_analysis.shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert hlo_analysis.shape_bytes("bf16[2,4]") == 16
    assert hlo_analysis.shape_bytes("(f32[8], s32[2])") == 40
    assert hlo_analysis.shape_dims("bf16[2,3,4]{2,1,0}") == [2, 3, 4]


# --- sharding rules -------------------------------------------------------

def test_param_specs_divisibility_fallback():
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_test_mesh
    from repro.sharding.specs import make_param_specs
    mesh = make_test_mesh((1, 1), ("data", "model"))
    cfg = ARCHS["llama3.2-3b"].reduced()
    from repro.launch.specs import params_sds
    specs = make_param_specs(params_sds(cfg), mesh, fsdp=True)
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert all(isinstance(s, P) for s in leaves)


def test_moe_param_spec_no_duplicate_axes():
    """Regression: jamba's 16-expert MoE produced PartitionSpec with
    'model' mapped twice (experts AND ff)."""
    import jax.tree_util as jtu
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_test_mesh
    from repro.launch.specs import params_sds
    from repro.sharding.specs import make_param_specs
    mesh = make_test_mesh((1, 1), ("data", "model"))
    for arch in ("jamba-1.5-large-398b", "mixtral-8x22b", "arctic-480b"):
        cfg = ARCHS[arch]
        specs = make_param_specs(params_sds(cfg), mesh, fsdp=True)
        for path, s in jtu.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))[0]:
            flat = [a for part in s if part
                    for a in (part if isinstance(part, tuple) else (part,))]
            assert len(flat) == len(set(flat)), (path, s)
