"""End-to-end behaviour tests: the paper's technique as a first-class
feature of the framework (backbone features -> OCSSVM slab head -> OOD
scores), plus the full train->checkpoint->serve loop on a reduced arch."""
import jax
import numpy as np

from repro.configs import ARCHS
from repro.core import SlabSpec, fit_head, mcc, pool_features, rbf
from repro.data.synthetic import SyntheticPipeline
from repro.models.transformer import forward, init_params
from repro.train.serve_step import greedy_generate
from repro.train.train_step import init_train_state, make_train_step


def test_train_loss_decreases():
    cfg = ARCHS["llama3.2-3b"].reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    state = init_train_state(cfg, params)
    step = jax.jit(make_train_step(cfg, peak_lr=1e-2, warmup_steps=5,
                                   total_steps=100))
    pipe = SyntheticPipeline(cfg, batch=4, seq_len=32, seed=0)
    losses = []
    for _ in range(25):
        state, m = step(state, pipe.next_batch())
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5


def test_greedy_generate_shapes():
    cfg = ARCHS["musicgen-large"].reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)
    out = greedy_generate(cfg, params, prompt, n_new=6)
    assert out.shape == (2, 6)
    assert int(out.max()) < cfg.padded_vocab


def test_ocssvm_head_on_backbone_features():
    """The paper's integration: slab head over LM hidden states separates
    in-distribution text from corrupted/OOD text."""
    cfg = ARCHS["llama3.2-3b"].reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)

    def features(tokens):
        # pre-logits hidden state via a forward hook: reuse logits path and
        # take the unembedding input by re-running without the head
        logits, _, _ = forward(params, cfg, tokens=tokens)
        return pool_features(logits[..., :64], "mean")  # low-dim proxy

    # in-distribution: low token ids (narrow marginal); OOD: uniform ids
    k1, k2, k3 = jax.random.split(key, 3)
    toks_in = jax.random.randint(k1, (96, 16), 0, 40)
    toks_in2 = jax.random.randint(k2, (48, 16), 0, 40)
    toks_out = jax.random.randint(k3, (48, 16),
                                  cfg.vocab_size - 40, cfg.vocab_size)

    spec = SlabSpec(nu1=0.2, nu2=0.1, eps=0.3, kernel=rbf(gamma=0.05))
    head = fit_head(features(toks_in), spec, solver="blocked", tol=1e-3)

    s_in = np.asarray(head.score(features(toks_in2)))
    s_out = np.asarray(head.score(features(toks_out)))
    # in-distribution scores rank above OOD (AUC > 0.8)
    auc = float(np.mean(s_in[:, None] > s_out[None, :]))
    assert auc > 0.8, f"AUC={auc}"


def test_paper_protocol_mini():
    """Paper Section 4 protocol at reduced size: linear kernel,
    nu1=.5 nu2=.01 eps=2/3 — converges and produces a valid MCC."""
    from repro.configs.ocssvm_paper import PAPER_SPEC
    from repro.core import solve_smo
    from repro.data import make_toy
    X, y = make_toy(jax.random.PRNGKey(0), 300)
    res = solve_smo(X, PAPER_SPEC, selection="paper", tol=1e-3,
                    max_iters=50_000)
    assert bool(res.converged)
    m = float(mcc(y, res.model.predict(X)))
    assert -1.0 <= m <= 1.0
