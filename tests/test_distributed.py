"""Distribution tests that need >1 host device run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count (jax locks the device
count at first import, and the main pytest process must stay 1-device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 4) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = REPO_SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_distributed_smo_matches_single_device():
    res = _run("""
        import json
        import jax, jax.numpy as jnp
        from repro.core import SlabSpec, rbf, solve_blocked, dual_objective
        from repro.core.distributed_smo import solve_blocked_distributed
        from repro.data import make_toy
        X, _ = make_toy(jax.random.PRNGKey(1), 256)
        spec = SlabSpec(nu1=0.5, nu2=0.05, eps=0.5, kernel=rbf(gamma=0.5))
        K = spec.kernel.gram(X.astype(jnp.float32))
        mesh = jax.make_mesh((4,), ("data",))
        rd = solve_blocked_distributed(X, spec, mesh, data_axes=("data",),
                                       P_pairs=8, tol=1e-4)
        rs = solve_blocked(X, spec, P=8, tol=1e-4)
        print(json.dumps({
            "obj_dist": float(dual_objective(rd.model.gamma, K)),
            "obj_single": float(dual_objective(rs.model.gamma, K)),
            "sum_dist": float(rd.model.gamma.sum()),
            "expected_sum": spec.total(),
            "converged": bool(rd.converged),
        }))
    """)
    assert res["converged"]
    assert abs(res["sum_dist"] - res["expected_sum"]) < 1e-4
    assert res["obj_dist"] == pytest.approx(res["obj_single"], abs=2e-3)


def test_distributed_smo_multi_axis_pod_mesh():
    res = _run("""
        import json
        import jax, jax.numpy as jnp
        from repro.core import SlabSpec, rbf, dual_objective, solve_qp
        from repro.core.distributed_smo import solve_blocked_distributed
        from repro.data import make_toy
        X, _ = make_toy(jax.random.PRNGKey(2), 240)   # pad test: 240 % 8 = 0
        spec = SlabSpec(nu1=0.4, nu2=0.1, eps=0.5, kernel=rbf(gamma=0.8))
        K = spec.kernel.gram(X.astype(jnp.float32))
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        rd = solve_blocked_distributed(X, spec, mesh,
                                       data_axes=("pod", "data"),
                                       P_pairs=4, tol=1e-4)
        qp = solve_qp(X, spec, max_iters=50000, tol=1e-10)
        print(json.dumps({
            "obj_dist": float(dual_objective(rd.model.gamma, K)),
            "obj_qp": float(qp.objective),
            "converged": bool(rd.converged),
        }))
    """, devices=8)
    assert res["converged"]
    assert res["obj_dist"] == pytest.approx(res["obj_qp"], abs=3e-3)


def test_sharded_train_step_matches_single_device():
    """pjit train step on a (2,2) mesh == unsharded result."""
    res = _run("""
        import json
        import jax, jax.numpy as jnp
        from repro.configs import ARCHS
        from repro.launch.mesh import make_test_mesh
        from repro.launch.specs import (batch_sds_and_shardings,
                                        train_state_shardings)
        from repro.sharding.specs import make_constrain
        from repro.models.transformer import init_params
        from repro.train.train_step import make_train_step, init_train_state
        from repro.data.synthetic import SyntheticPipeline

        cfg = ARCHS["minitron-8b"].reduced()
        params = init_params(cfg, jax.random.PRNGKey(0))
        pipe = SyntheticPipeline(cfg, batch=4, seq_len=16, seed=0)
        batch = pipe.next_batch()

        # single-device reference
        s0 = init_train_state(cfg, params)
        step0 = jax.jit(make_train_step(cfg, peak_lr=1e-3, warmup_steps=2,
                                        total_steps=10))
        s0, m0 = step0(s0, batch)

        mesh = make_test_mesh((2, 2), ("data", "model"))
        constrain = make_constrain(mesh, fsdp=True)
        shd = train_state_shardings(cfg, mesh, fsdp=True)
        _, bshd = batch_sds_and_shardings(cfg, mesh, 4, 16)
        with mesh:
            step1 = jax.jit(make_train_step(cfg, peak_lr=1e-3,
                                            warmup_steps=2, total_steps=10,
                                            constrain=constrain),
                            in_shardings=(shd, bshd),
                            out_shardings=(shd, None))
            s1 = jax.device_put(init_train_state(cfg, params), shd)
            batch1 = {k: jax.device_put(v, bshd[k]) for k, v in batch.items()}
            s1, m1 = step1(s1, batch1)
        diff = max(float(jnp.abs(a - b).max()) for a, b in
                   zip(jax.tree.leaves(s0.params), jax.tree.leaves(s1.params)))
        print(json.dumps({"loss0": float(m0["loss"]),
                          "loss1": float(m1["loss"]),
                          "max_param_diff": diff}))
    """)
    assert res["loss0"] == pytest.approx(res["loss1"], abs=2e-3)
    assert res["max_param_diff"] < 5e-2


def test_moe_shard_map_matches_global_path():
    """The shard_map MoE (production) == the dense global path."""
    res = _run("""
        import json, dataclasses
        import jax, jax.numpy as jnp
        from repro.configs import ARCHS
        from repro.launch.mesh import make_test_mesh
        from repro.sharding.specs import make_constrain
        from repro.models.moe import moe_forward, moe_init

        d, E = 16, 4
        key = jax.random.PRNGKey(0)
        p = moe_init(key, d, E, 32, "swiglu", jnp.float32)
        x = jax.random.normal(key, (4, 8, d), jnp.float32)
        # global path (no ctx)
        y0, aux0 = moe_forward(p, x, n_experts=E, top_k=2,
                               capacity_factor=float(E), mlp_type="swiglu")
        mesh = make_test_mesh((2, 2), ("data", "model"))
        constrain = make_constrain(mesh, fsdp=False)
        with mesh:
            y1, aux1 = jax.jit(lambda p, x: moe_forward(
                p, x, n_experts=E, top_k=2, capacity_factor=float(E),
                mlp_type="swiglu", constrain=constrain))(p, x)
        print(json.dumps({
            "max_diff": float(jnp.abs(y0 - y1).max()),
            "aux0": float(aux0), "aux1": float(aux1)}))
    """)
    assert res["max_diff"] < 5e-4
    # aux is computed per data shard then averaged (GShard computes the
    # balance loss per group) — close to, but not identical with, the
    # global-batch statistic.
    assert res["aux0"] == pytest.approx(res["aux1"], rel=0.25, abs=0.05)


def test_compressed_gradient_allreduce():
    res = _run("""
        import json
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.optim.compression import init_error_state, psum_compressed
        from repro.utils.compat import shard_map
        mesh = jax.make_mesh((4,), ("data",))
        g = {"w": jnp.arange(32, dtype=jnp.float32).reshape(4, 8) / 7.0}
        err = init_error_state(g)

        def f(g, err):
            return psum_compressed(g, err, ("data",))

        out, new_err = shard_map(
            f, mesh=mesh, in_specs=(P("data", None), P("data", None)),
            out_specs=(P("data", None), P("data", None)),
            check_vma=False)(g, err)
        # mean over the data axis of identical shards == original/1? no:
        # shards differ; compare against the true mean of shards
        true_mean = g["w"].reshape(4, 1, 8).mean(axis=0)
        # each shard holds the mean of the 4 device-local rows
        errmax = float(jnp.abs(out["w"] - jnp.tile(true_mean, (4, 1))).max())
        rel = errmax / float(jnp.abs(true_mean).max())
        print(json.dumps({"rel_err": rel}))
    """)
    # single-shot int8 quantization error; the error-feedback residual
    # cancels it across steps (test_substrate asserts the cumulative
    # stream is lossless to <10%)
    assert res["rel_err"] < 0.3
