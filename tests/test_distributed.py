"""Distribution tests that need >1 host device run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count (jax locks the device
count at first import, and the main pytest process must stay 1-device)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

# The documented per-dtype tolerance floors, shared with the kernel and
# engine parity suites (importing jax here is fine — the main process
# just has to stay single-device, which importing does not change).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.kernels.precision import truth_tolerance  # noqa: E402

from conftest import REPO_SRC, run_forced_devices as _run  # noqa: E402


def test_distributed_smo_matches_single_device():
    res = _run("""
        import json
        import jax, jax.numpy as jnp
        from repro.core import SlabSpec, rbf, solve_blocked, dual_objective
        from repro.core.distributed_smo import solve_blocked_distributed
        from repro.data import make_toy
        X, _ = make_toy(jax.random.PRNGKey(1), 256)
        spec = SlabSpec(nu1=0.5, nu2=0.05, eps=0.5, kernel=rbf(gamma=0.5))
        K = spec.kernel.gram(X.astype(jnp.float32))
        mesh = jax.make_mesh((4,), ("data",))
        rd = solve_blocked_distributed(X, spec, mesh, data_axes=("data",),
                                       P_pairs=8, tol=1e-4)
        rs = solve_blocked(X, spec, P=8, tol=1e-4)
        print(json.dumps({
            "obj_dist": float(dual_objective(rd.model.gamma, K)),
            "obj_single": float(dual_objective(rs.model.gamma, K)),
            "sum_dist": float(rd.model.gamma.sum()),
            "expected_sum": spec.total(),
            "converged": bool(rd.converged),
        }))
    """)
    assert res["converged"]
    assert abs(res["sum_dist"] - res["expected_sum"]) < 1e-4
    assert res["obj_dist"] == pytest.approx(res["obj_single"], abs=2e-3)


def test_distributed_smo_multi_axis_pod_mesh():
    res = _run("""
        import json
        import jax, jax.numpy as jnp
        from repro.core import SlabSpec, rbf, dual_objective, solve_qp
        from repro.core.distributed_smo import solve_blocked_distributed
        from repro.data import make_toy
        X, _ = make_toy(jax.random.PRNGKey(2), 240)   # pad test: 240 % 8 = 0
        spec = SlabSpec(nu1=0.4, nu2=0.1, eps=0.5, kernel=rbf(gamma=0.8))
        K = spec.kernel.gram(X.astype(jnp.float32))
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        rd = solve_blocked_distributed(X, spec, mesh,
                                       data_axes=("pod", "data"),
                                       P_pairs=4, tol=1e-4)
        qp = solve_qp(X, spec, max_iters=50000, tol=1e-10)
        print(json.dumps({
            "obj_dist": float(dual_objective(rd.model.gamma, K)),
            "obj_qp": float(qp.objective),
            "converged": bool(rd.converged),
        }))
    """, devices=8)
    assert res["converged"]
    assert res["obj_dist"] == pytest.approx(res["obj_qp"], abs=3e-3)


def test_sharded_train_step_matches_single_device():
    """pjit train step on a (2,2) mesh == unsharded result."""
    res = _run("""
        import json
        import jax, jax.numpy as jnp
        from repro.configs import ARCHS
        from repro.launch.mesh import make_test_mesh
        from repro.launch.specs import (batch_sds_and_shardings,
                                        train_state_shardings)
        from repro.sharding.specs import make_constrain
        from repro.models.transformer import init_params
        from repro.train.train_step import make_train_step, init_train_state
        from repro.data.synthetic import SyntheticPipeline

        cfg = ARCHS["minitron-8b"].reduced()
        params = init_params(cfg, jax.random.PRNGKey(0))
        pipe = SyntheticPipeline(cfg, batch=4, seq_len=16, seed=0)
        batch = pipe.next_batch()

        # single-device reference
        s0 = init_train_state(cfg, params)
        step0 = jax.jit(make_train_step(cfg, peak_lr=1e-3, warmup_steps=2,
                                        total_steps=10))
        s0, m0 = step0(s0, batch)

        mesh = make_test_mesh((2, 2), ("data", "model"))
        constrain = make_constrain(mesh, fsdp=True)
        shd = train_state_shardings(cfg, mesh, fsdp=True)
        _, bshd = batch_sds_and_shardings(cfg, mesh, 4, 16)
        with mesh:
            step1 = jax.jit(make_train_step(cfg, peak_lr=1e-3,
                                            warmup_steps=2, total_steps=10,
                                            constrain=constrain),
                            in_shardings=(shd, bshd),
                            out_shardings=(shd, None))
            s1 = jax.device_put(init_train_state(cfg, params), shd)
            batch1 = {k: jax.device_put(v, bshd[k]) for k, v in batch.items()}
            s1, m1 = step1(s1, batch1)
        diff = max(float(jnp.abs(a - b).max()) for a, b in
                   zip(jax.tree.leaves(s0.params), jax.tree.leaves(s1.params)))
        print(json.dumps({"loss0": float(m0["loss"]),
                          "loss1": float(m1["loss"]),
                          "max_param_diff": diff}))
    """)
    assert res["loss0"] == pytest.approx(res["loss1"], abs=2e-3)
    assert res["max_param_diff"] < 5e-2


def test_moe_shard_map_matches_global_path():
    """The shard_map MoE (production) == the dense global path."""
    res = _run("""
        import json, dataclasses
        import jax, jax.numpy as jnp
        from repro.configs import ARCHS
        from repro.launch.mesh import make_test_mesh
        from repro.sharding.specs import make_constrain
        from repro.models.moe import moe_forward, moe_init

        d, E = 16, 4
        key = jax.random.PRNGKey(0)
        p = moe_init(key, d, E, 32, "swiglu", jnp.float32)
        x = jax.random.normal(key, (4, 8, d), jnp.float32)
        # global path (no ctx)
        y0, aux0 = moe_forward(p, x, n_experts=E, top_k=2,
                               capacity_factor=float(E), mlp_type="swiglu")
        mesh = make_test_mesh((2, 2), ("data", "model"))
        constrain = make_constrain(mesh, fsdp=False)
        with mesh:
            y1, aux1 = jax.jit(lambda p, x: moe_forward(
                p, x, n_experts=E, top_k=2, capacity_factor=float(E),
                mlp_type="swiglu", constrain=constrain))(p, x)
        print(json.dumps({
            "max_diff": float(jnp.abs(y0 - y1).max()),
            "aux0": float(aux0), "aux1": float(aux1)}))
    """)
    assert res["max_diff"] < 5e-4
    # aux is computed per data shard then averaged (GShard computes the
    # balance loss per group) — close to, but not identical with, the
    # global-batch statistic.
    assert res["aux0"] == pytest.approx(res["aux1"], rel=0.25, abs=0.05)


def test_sharded_shrinking_matches_blocked_and_collective_budget():
    """The row-sharded shrinking repack driver must land on the same slab
    as the single-device blocked solver for every (kernel, precision)
    cell — objective AND both offsets, within the documented per-dtype
    truth tolerances plus the solver-convergence floor — and the engine's
    collective-bytes ledger must certify the O(P d) per-iteration budget:
    bytes independent of m, bounded by c * P * d with c covering the
    candidate-packing constant (4 scalar lanes per row) and the shard
    fan-in. One subprocess covers the whole matrix: jax start-up is paid
    once."""
    res = _run("""
        import json
        import jax, jax.numpy as jnp
        from repro.core import (SlabSpec, rbf, linear, solve_blocked,
                                dual_objective)
        from repro.core.distributed_smo import solve_blocked_distributed
        from repro.core.engine import CollectiveLedger
        from repro.core.shrinking import solve_sharded_shrinking
        from repro.data import make_toy
        from repro.launch.mesh import make_solver_mesh

        mesh, axes = make_solver_mesh()
        kernels = {"rbf": rbf(gamma=0.5), "linear": linear()}
        out = {"cells": {}}
        X, _ = make_toy(jax.random.PRNGKey(2), 1024)
        for kname, kern in kernels.items():
            spec = SlabSpec(nu1=0.5, nu2=0.05, eps=0.5, kernel=kern)
            K = spec.kernel.gram(X.astype(jnp.float32))
            for precision in ("f32", "bf16"):
                r_shr = solve_sharded_shrinking(
                    X, spec, mesh, data_axes=axes, P_pairs=8, tol=1e-4,
                    warm_iters=60, precision=precision)
                r_blk = solve_blocked(X, spec, P=8, tol=1e-4,
                                      precision=precision)
                out["cells"][f"{kname}-{precision}"] = {
                    "obj_shr": float(dual_objective(r_shr.model.gamma, K)),
                    "obj_blk": float(dual_objective(r_blk.model.gamma, K)),
                    "rho_shr": [float(r_shr.model.rho1),
                                float(r_shr.model.rho2)],
                    "rho_blk": [float(r_blk.model.rho1),
                                float(r_blk.model.rho2)],
                    "converged": bool(r_shr.converged),
                }

        # Collective budget: per-iteration bytes from the stats-hook
        # ledger must not depend on m and must stay <= c * P * d.
        spec = SlabSpec(nu1=0.5, nu2=0.05, eps=0.5, kernel=rbf(gamma=0.5))
        P_pairs, d = 8, X.shape[1]
        iter_bytes = {}
        for m in (256, 2048):
            Xm, _ = make_toy(jax.random.PRNGKey(3), m)
            led = CollectiveLedger()
            solve_blocked_distributed(Xm, spec, mesh, data_axes=axes,
                                      P_pairs=P_pairs, tol=1e-3,
                                      max_outer=50, ledger=led)
            iter_bytes[m] = led.iteration_bytes
        n_shards = 1
        for ax in axes:
            n_shards *= int(mesh.shape[ax])
        out["iter_bytes"] = iter_bytes
        out["P"] = P_pairs
        out["d"] = d
        out["n_shards"] = n_shards

        # Pod-mesh wiring: multi_pod=True on 8 devices must give the
        # scaled-down (2, 4) ("pod", "data") topology and land on the
        # same optimum as the single-device solver.
        mesh2, axes2 = make_solver_mesh(multi_pod=True)
        Xs, _ = make_toy(jax.random.PRNGKey(3), 256)
        Ks = spec.kernel.gram(Xs.astype(jnp.float32))
        r_pod = solve_blocked_distributed(Xs, spec, mesh2,
                                          data_axes=axes2, P_pairs=8,
                                          tol=1e-4)
        r_loc = solve_blocked(Xs, spec, P=8, tol=1e-4)
        out["pod"] = {
            "axes": list(axes2),
            "shape": [int(mesh2.shape[a]) for a in axes2],
            "obj_pod": float(dual_objective(r_pod.model.gamma, Ks)),
            "obj_loc": float(dual_objective(r_loc.model.gamma, Ks)),
            "converged": bool(r_pod.converged),
        }
        print(json.dumps(out))
    """, devices=8)
    pod = res["pod"]
    assert pod["axes"] == ["pod", "data"] and pod["shape"] == [2, 4]
    assert pod["converged"]
    assert pod["obj_pod"] == pytest.approx(pod["obj_loc"], abs=2e-3)
    for cell, c in res["cells"].items():
        assert c["converged"], cell
        # floors mirror tests/test_engine_parity.py (SOLVER_ATOL_FLOOR on
        # top of the per-dtype kernel tolerances)
        precision = cell.split("-")[1]
        tol_obj = truth_tolerance(precision, np.asarray([c["obj_blk"]]))
        np.testing.assert_allclose(
            c["obj_shr"], c["obj_blk"], rtol=tol_obj["rtol"],
            atol=max(tol_obj["atol"], 5e-3), err_msg=cell)
        tol_rho = truth_tolerance(precision, np.asarray(c["rho_blk"]))
        np.testing.assert_allclose(
            np.asarray(c["rho_shr"]), np.asarray(c["rho_blk"]),
            rtol=tol_rho["rtol"], atol=max(tol_rho["atol"], 5e-3),
            err_msg=cell)

    # O(P d) budget: the candidate gather packs (value, gid, gamma, f)
    # plus the d features per row, both sides, every shard — so
    # c = 8 * n_shards * (1 + 4/d) covers it with 2x headroom; the fused
    # psum/pmax pair adds O(1). Crucially the bill is IDENTICAL across m.
    bytes_by_m = set(res["iter_bytes"].values())
    assert len(bytes_by_m) == 1, f"iter bytes vary with m: {res['iter_bytes']}"
    P_pairs, d, n_shards = res["P"], res["d"], res["n_shards"]
    budget = 4 * n_shards * P_pairs * (d + 4) * 4 + 256
    assert bytes_by_m.pop() <= budget


def test_two_process_jax_distributed_smoke():
    """2-process jax.distributed bring-up on CPU: both processes must
    initialize against one coordinator, see the global 2-device topology,
    and — where the jax build supports cross-process CPU collectives —
    agree on a process_allgather. jax 0.4.37 (the CI floor) reports
    multiprocess CPU computations as unimplemented; the smoke still gates
    coordinator + topology there."""
    import socket

    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()

    child = textwrap.dedent("""
        import json, sys
        import jax
        pid = int(sys.argv[1])
        jax.distributed.initialize(
            coordinator_address=f"127.0.0.1:{port}",
            num_processes=2, process_id=pid)
        import jax.numpy as jnp
        allgather = None
        try:
            import jax.experimental.multihost_utils as mhu
            g = mhu.process_allgather(jnp.full((1,), float(pid + 1)))
            allgather = [float(x) for x in g.ravel()]
        except Exception as e:
            if "aren't implemented on the CPU backend" not in str(e):
                raise
        print(json.dumps({
            "pid": pid,
            "processes": jax.process_count(),
            "devices": jax.device_count(),
            "local_devices": jax.local_device_count(),
            "allgather": allgather,
        }))
    """.replace("{port}", str(port)))

    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)   # 1 local CPU device per process
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = REPO_SRC
    procs = [subprocess.Popen([sys.executable, "-c", child, str(i)],
                              stdout=subprocess.PIPE,
                              stderr=subprocess.PIPE, text=True, env=env)
             for i in range(2)]
    results = []
    for p in procs:
        out, err = p.communicate(timeout=300)
        assert p.returncode == 0, err[-3000:]
        results.append(json.loads(out.strip().splitlines()[-1]))
    for r in results:
        assert r["processes"] == 2
        assert r["devices"] == 2          # global view spans both procs
        assert r["local_devices"] == 1
        if r["allgather"] is not None:
            assert r["allgather"] == [1.0, 2.0]


def test_compressed_gradient_allreduce():
    res = _run("""
        import json
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.optim.compression import init_error_state, psum_compressed
        from repro.utils.compat import shard_map
        mesh = jax.make_mesh((4,), ("data",))
        g = {"w": jnp.arange(32, dtype=jnp.float32).reshape(4, 8) / 7.0}
        err = init_error_state(g)

        def f(g, err):
            return psum_compressed(g, err, ("data",))

        out, new_err = shard_map(
            f, mesh=mesh, in_specs=(P("data", None), P("data", None)),
            out_specs=(P("data", None), P("data", None)),
            check_vma=False)(g, err)
        # mean over the data axis of identical shards == original/1? no:
        # shards differ; compare against the true mean of shards
        true_mean = g["w"].reshape(4, 1, 8).mean(axis=0)
        # each shard holds the mean of the 4 device-local rows
        errmax = float(jnp.abs(out["w"] - jnp.tile(true_mean, (4, 1))).max())
        rel = errmax / float(jnp.abs(true_mean).max())
        print(json.dumps({"rel_err": rel}))
    """)
    # single-shot int8 quantization error; the error-feedback residual
    # cancels it across steps (test_substrate asserts the cumulative
    # stream is lossless to <10%)
    assert res["rel_err"] < 0.3
