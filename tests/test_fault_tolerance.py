"""Fault-tolerant loop: crash/restart determinism, straggler detection,
data-pipeline cursor resume."""
import jax

from repro.configs import ARCHS
from repro.data.synthetic import SyntheticPipeline
from repro.models.transformer import init_params
from repro.runtime.fault_tolerance import FaultTolerantLoop, HeartbeatTable
from repro.train.train_step import init_train_state, make_train_step


def _setup(tmp_path, injector=None, save_every=4):
    cfg = ARCHS["llama3.2-3b"].reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, peak_lr=1e-3, warmup_steps=2,
                                   total_steps=100))
    pipe = SyntheticPipeline(cfg, batch=2, seq_len=16, seed=0)
    return FaultTolerantLoop(step, init_train_state(cfg, params), pipe,
                             str(tmp_path), save_every=save_every,
                             failure_injector=injector)


def test_restart_resumes_and_replays_deterministically(tmp_path):
    fails = {6, 11}

    def injector(s):
        if s in fails:
            fails.discard(s)
            raise RuntimeError("injected")

    loop = _setup(tmp_path, injector)
    loop.run(14)
    assert loop.restarts == 2
    by_step = {}
    for m in loop.metrics_log:
        if m["step"] in by_step:
            assert abs(by_step[m["step"]] - m["loss"]) < 1e-5
        by_step[m["step"]] = m["loss"]
    assert set(by_step) == set(range(14))


def test_too_many_failures_raises(tmp_path):
    def injector(s):
        raise RuntimeError("always failing")

    loop = _setup(tmp_path, injector)
    loop.max_restarts = 3
    try:
        loop.run(5)
        raised = False
    except RuntimeError:
        raised = True
    assert raised
    assert loop.restarts == 4


def test_data_cursor_resumes(tmp_path):
    loop = _setup(tmp_path, save_every=2)
    loop.run(4)
    # pipeline cursor advanced once per executed step
    assert loop.pipeline.cursor.step == 4


def test_heartbeat_straggler_detection():
    hb = HeartbeatTable(n_nodes=4, timeout_s=5.0, straggler_factor=2.0)
    now = 1000.0
    for node in range(4):
        for i in range(5):
            hb.beat(node, step_time=1.0 if node != 2 else 3.5,
                    now=now + i)
    assert hb.stragglers() == [2]
    # node 3 stops beating (others beat at now+4, timeout 5s)
    hb.last_beat[3] = now - 100
    assert hb.dead_nodes(now=now + 5) == [3]
