"""Async serving front-end tests: driver, awaitables, shm fleet, and
the serving-layer bugfix sweep.

Three regression groups that FAIL on the pre-async admission layer:

* dead-deadline inline flush — a submit onto a window whose deadline
  already passed must flush at submit time, not queue behind a poll()
  that may never come;
* ``fit_update`` with a ``gamma0``-carrying recipe / an engine whose
  incremental structures raise ``NotImplementedError`` mid-update must
  take the documented cold-refit fallback (counted in refresh_modes),
  not surface a traceback;
* cold (compile-laden) launches must not skew ``BucketStats`` deadline
  estimates;
* the per-shape compile trap: numpy requests must pad AND unpad
  host-side (no per-request-shape device programs), and the deadline
  estimate must charge the observed additive per-window flush overhead.

Plus the tentpole: driver lifecycle (start → storm → stop drains all),
driver-crash propagation to awaiting callers, asyncio awaitables, and
the shared-memory fleet (bitwise attach parity, refcounting, leader
death). Policy tests run on the manual fake clock; driver-thread tests
use the real clock with generous timeouts (the driver is event-driven,
so they wait on completion, never on a fixed sleep).
"""
import asyncio
import json
import os
import subprocess
import sys
import threading
import time

import jax
import numpy as np
import pytest

import repro
from repro import api
from repro.core import SlabSpec, rbf
from repro.data import make_toy
from repro.serve import (AdmissionController, AsyncDriver, BatchScorer,
                         BucketStats, DriverCrashed, ModelRegistry,
                         ScoringService, ShmKeyError, shm_registry)
from repro.serve.async_driver import serve_async

SPEC = SlabSpec(nu1=0.5, nu2=0.05, eps=0.5, kernel=rbf(gamma=0.5))
M = 48
FIT_KW = dict(tol=1e-2, max_outer=60)


class ManualClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _wait(pred, timeout=20.0):
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout:
        if pred():
            return True
        time.sleep(0.01)
    return False


@pytest.fixture()
def X():
    return make_toy(jax.random.PRNGKey(5), M)[0]


@pytest.fixture()
def registry(X):
    reg = ModelRegistry()
    reg.register("a", X, SPEC, **FIT_KW)
    return reg


def _q(X, n=3, seed=0):
    rng = np.random.default_rng(seed)
    base = np.asarray(X[:n], np.float32)
    return base + rng.normal(scale=0.01, size=base.shape).astype(np.float32)


# -- satellite 1: dead-deadline inline flush ---------------------------------

def test_submit_onto_dead_deadline_flushes_inline(registry, X):
    """REGRESSION: pre-PR, a window whose deadline passed while nobody
    polled kept queueing new arrivals — the miss grew unbounded."""
    clock = ManualClock()
    ctrl = AdmissionController(registry, clock=clock, max_batch=128)
    ctrl.service("a")                       # pay the fit up front
    h1 = ctrl.submit("a", _q(X), deadline=5.0)
    assert not h1.flushed                   # future deadline: coalesce
    clock.advance(10.0)                     # deadline passes; NOBODY polls
    h2 = ctrl.submit("a", _q(X, seed=1), deadline=clock.t + 100.0)
    assert h1.done and h2.done              # inline flush served BOTH
    stats = ctrl.stats_dict()["a"]["windows"]
    assert stats["inline_flushes"] == 1
    assert stats["flushed_requests"] == 2


def test_submit_own_deadline_already_passed_flushes_inline(registry, X):
    """The degenerate case: the request is born dead (e.g. its deadline
    passed during a long fit-on-first-use) — it must be served NOW."""
    clock = ManualClock(t=50.0)
    ctrl = AdmissionController(registry, clock=clock, max_batch=128)
    h = ctrl.submit("a", _q(X), deadline=10.0)      # already in the past
    assert h.done
    assert h.result().shape == (3,)


def test_future_deadline_still_coalesces(registry, X):
    """The inline flush is for DEAD deadlines only — deadline pressure
    with a live deadline stays poll()'s job (due() policy)."""
    clock = ManualClock()
    ctrl = AdmissionController(registry, clock=clock, max_batch=128)
    ctrl.service("a")
    h = ctrl.submit("a", _q(X), deadline=1.0)
    assert not h.flushed
    assert ctrl.queued_rows("a") == 3


# -- satellite 2: fit_update cold-refit fallback -----------------------------

def test_refresh_with_gamma0_recipe_does_not_traceback(X):
    """REGRESSION: pre-PR, a recipe registered with a gamma0 fit kwarg
    cold-fitted fine but any warm refresh died on the solvers' "pass
    warm= or gamma0=, not both" ValueError."""
    reg = ModelRegistry()
    m0 = repro.fit(np.asarray(X), SPEC, **FIT_KW)
    g0 = np.asarray(m0.model.gamma)
    reg.register("a", X, SPEC, gamma0=g0, **FIT_KW)
    reg.get("a")
    app = _q(X, n=4, seed=7)
    reg.refresh("a", append=app, mode="warm")       # pre-PR: ValueError
    counts = reg.refresh_modes["a"]
    assert counts["warm"] + counts["cold"] == 1


def test_fit_update_same_size_gamma0_routes_cold(X):
    Xh = np.asarray(X)
    m0 = repro.fit(Xh, SPEC, **FIT_KW)
    g0 = np.asarray(m0.model.gamma)
    stats = {}
    repro.fit_update(m0, Xh, stats_out=stats, gamma0=g0, **FIT_KW)
    assert stats["mode"] == "cold"
    assert stats["fallback"] == "gamma0_conflict"


def test_fit_update_stale_gamma0_dropped_keeps_warm_route(X):
    Xh = np.asarray(X)
    m0 = repro.fit(Xh, SPEC, **FIT_KW)
    g0 = np.asarray(m0.model.gamma)                 # sized for OLD data
    X2 = np.concatenate([Xh, _q(X, n=4, seed=8)])
    stats = {}
    repro.fit_update(m0, X2, stats_out=stats, gamma0=g0, **FIT_KW)
    assert stats["mode"] == "warm"
    assert stats["fallback"] == "gamma0_stale_dropped"


def test_fit_update_warm_notimplemented_falls_back_cold(X, monkeypatch):
    """An engine whose incremental structures cannot mutate mid-update
    (ShardedGram.append_rows raises NotImplementedError) must degrade to
    the documented cold refit, recorded in stats_out."""
    Xh = np.asarray(X)
    m0 = repro.fit(Xh, SPEC, **FIT_KW)
    real_fit = api.fit

    def no_warm_fit(Xa, spec=None, **kw):
        if kw.get("warm_start") is not None:
            raise NotImplementedError(
                "append_rows is not supported on ShardedGram")
        return real_fit(Xa, spec, **kw)

    monkeypatch.setattr(api, "fit", no_warm_fit)
    X2 = np.concatenate([Xh, _q(X, n=2, seed=9)])
    stats = {}
    res = api.fit_update(m0, X2, stats_out=stats, **FIT_KW)
    assert stats["mode"] == "cold"
    assert stats["fallback"].startswith("warm_unsupported")
    assert res.model.X.shape[0] == X2.shape[0]


# -- satellite 3: cold launches excluded from estimates ----------------------

def test_bucket_stats_cold_excluded_from_mean():
    """REGRESSION: pre-PR the first compile-laden launch entered the
    mean the admission deadline policy reads — one 5 s compile made
    every post-refresh window flush pathologically early."""
    s = BucketStats()
    s.record(64, 1, 5.0, cold=True)         # trace+compile launch
    s.record(64, 1, 0.010)
    s.record(64, 1, 0.030)
    assert s.batches == 3 and s.cold_batches == 1
    assert s.mean_latency_s == pytest.approx(0.020)   # warm-only
    assert s.total_s == pytest.approx(5.040)          # throughput keeps all


def test_bucket_stats_cold_only_falls_back_to_cold_mean():
    s = BucketStats()
    s.record(64, 1, 2.0, cold=True)
    assert s.mean_latency_s == pytest.approx(2.0)     # over-estimate =
    #                                                   flush early, safe


def test_service_marks_first_unwarmed_launch_cold(registry, X):
    clock = ManualClock()
    sm = registry.get("a")
    svc = ScoringService(BatchScorer(sm), clock=clock)
    svc.submit(_q(X))
    svc.flush()
    svc.submit(_q(X, seed=1))
    svc.flush()
    (stats,) = svc.stats.values()
    assert stats.batches == 2 and stats.cold_batches == 1


def test_warmup_suppresses_cold_marking(registry, X):
    clock = ManualClock()
    sm = registry.get("a")
    svc = ScoringService(BatchScorer(sm), clock=clock)
    svc.warmup()
    svc.submit(_q(X))
    svc.flush()
    (stats,) = svc.stats.values()
    assert stats.batches == 1 and stats.cold_batches == 0


# -- continuous windows ------------------------------------------------------

def test_window_reopens_after_flush(registry, X):
    clock = ManualClock()
    ctrl = AdmissionController(registry, clock=clock, max_batch=128)
    ctrl.submit("a", _q(X))
    ctrl.flush_model("a")
    ctrl.submit("a", _q(X, seed=1))         # lands in a FRESH window
    assert ctrl.queued_rows("a") == 3
    w = ctrl.stats_dict()["a"]["windows"]
    assert w["opened"] == 2 and w["flushed"] == 1
    assert w["flushed_rows"] == 3 and w["max_rows"] == 3


def test_submit_during_inflight_flush_lands_in_next_window(registry, X):
    """Late arrivals join the next launch instead of blocking on the
    in-flight flush-and-wait cycle."""
    clock = ManualClock()
    ctrl = AdmissionController(registry, clock=clock, max_batch=128)
    svc = ctrl.service("a")
    entered = threading.Event()
    release = threading.Event()
    real_flush = svc.flush

    def slow_flush():
        entered.set()
        release.wait(10.0)
        return real_flush()

    svc.flush = slow_flush
    ctrl.submit("a", _q(X))
    t = threading.Thread(target=ctrl.flush_model, args=("a",))
    t.start()
    assert entered.wait(10.0)
    # flush is mid-launch under the model lock; admission must not block
    h2 = ctrl.submit("a", _q(X, seed=1))
    assert ctrl.queued_rows("a") == 3 and not h2.flushed
    release.set()
    t.join(10.0)
    assert ctrl.queued_rows("a") == 3       # window 2 untouched by flush 1
    ctrl.flush_model("a")
    assert h2.done


def test_next_due_time_tracks_earliest_window(registry, X):
    clock = ManualClock()
    ctrl = AdmissionController(registry, clock=clock, max_batch=128,
                               max_wait_s=50.0)
    assert ctrl.next_due_time() is None
    ctrl.service("a")
    ctrl.submit("a", _q(X), deadline=30.0)
    assert ctrl.next_due_time() == pytest.approx(30.0)  # no latency obs
    ctrl.submit("a", _q(X, seed=1), deadline=12.0)
    assert ctrl.next_due_time() == pytest.approx(12.0)


# -- driver lifecycle --------------------------------------------------------

def test_driver_start_storm_stop_drains_everything(registry, X):
    ctrl = AdmissionController(registry, max_batch=4096)
    ctrl.service("a")
    far = time.monotonic() + 3600.0         # never due on its own
    handles = []
    with AsyncDriver(ctrl) as driver:
        assert driver.alive
        for i in range(24):
            handles.append(ctrl.submit("a", _q(X, seed=i), deadline=far))
    # context exit = stop(drain=True): nothing silently dropped
    assert all(h.done for h in handles)
    assert sum(h.result().shape[0] for h in handles) == 24 * 3


def test_driver_flushes_on_deadline_without_any_polling(registry, X):
    ctrl = AdmissionController(registry, max_batch=4096)
    ctrl.service("a")                       # keep the fit out of the window
    driver = AsyncDriver(ctrl).start()
    try:
        h = ctrl.submit("a", _q(X), deadline=time.monotonic() + 0.2)
        assert not h.done                   # really queued, nobody polls
        assert _wait(lambda: h.done)        # the DRIVER flushed it
        assert h.result().shape == (3,)
    finally:
        driver.stop()
    assert not driver.alive


def test_driver_exception_aborts_pending_and_surfaces(registry, X,
                                                      monkeypatch):
    ctrl = AdmissionController(registry, max_batch=4096)
    ctrl.service("a")

    def boom():
        raise RuntimeError("poll exploded")

    monkeypatch.setattr(ctrl, "poll", boom)
    driver = AsyncDriver(ctrl).start()
    h = ctrl.submit("a", _q(X), deadline=time.monotonic() + 0.1)
    assert _wait(lambda: driver.crashed is not None)
    assert _wait(lambda: h.done)
    with pytest.raises(DriverCrashed) as ei:
        h.result()
    assert isinstance(ei.value.cause, RuntimeError)
    with pytest.raises(DriverCrashed):
        driver.stop()
    with pytest.raises(DriverCrashed):
        driver.start()                      # no silent restart of a corpse


def test_driver_crash_does_not_mask_body_exception(registry, X,
                                                   monkeypatch):
    """REGRESSION: __exit__ promised to prefer the body's exception,
    but stop() unconditionally re-raised the crash — DriverCrashed
    replaced the in-flight body exception (demoted to __context__)."""
    ctrl = AdmissionController(registry, max_batch=4096)
    ctrl.service("a")

    def boom():
        raise RuntimeError("poll exploded")

    monkeypatch.setattr(ctrl, "poll", boom)
    with pytest.raises(ValueError, match="body failed first"):
        with AsyncDriver(ctrl) as driver:
            ctrl.submit("a", _q(X), deadline=time.monotonic() + 0.05)
            assert _wait(lambda: driver.crashed is not None)
            raise ValueError("body failed first")
    assert driver.crashed is not None       # still diagnosable after


def test_driver_crash_still_raises_on_clean_body_exit(registry, X,
                                                      monkeypatch):
    ctrl = AdmissionController(registry, max_batch=4096)
    ctrl.service("a")

    def boom():
        raise RuntimeError("poll exploded")

    monkeypatch.setattr(ctrl, "poll", boom)
    with pytest.raises(DriverCrashed):
        with AsyncDriver(ctrl) as driver:
            ctrl.submit("a", _q(X), deadline=time.monotonic() + 0.05)
            assert _wait(lambda: driver.crashed is not None)


def test_driver_rearms_on_earlier_deadline(registry, X):
    """A new submit with an EARLIER deadline must wake the parked driver
    — event-driven, not a fixed poll interval."""
    ctrl = AdmissionController(registry, max_batch=4096)
    ctrl.service("a")
    driver = AsyncDriver(ctrl).start()
    try:
        h_far = ctrl.submit("a", _q(X), deadline=time.monotonic() + 3600)
        h_near = ctrl.submit("a", _q(X, seed=1),
                             deadline=time.monotonic() + 0.2)
        assert _wait(lambda: h_near.done)
        assert h_far.done                   # same window, same flush
    finally:
        driver.stop()


# -- awaitables --------------------------------------------------------------

def test_submit_async_resolves_via_driver(registry, X):
    ctrl = AdmissionController(registry, max_batch=4096)
    sm = registry.get("a")
    qs = [_q(X, seed=i) for i in range(4)]
    expected = [np.asarray(sm.score(q)) for q in qs]

    async def main():
        futs = [ctrl.submit_async("a", q,
                                  deadline=time.monotonic() + 0.2)
                for q in qs]
        return await asyncio.gather(*futs)

    with AsyncDriver(ctrl):
        got = asyncio.run(main())
    for g, e in zip(got, expected):
        np.testing.assert_array_equal(np.asarray(g), e)


def test_serve_async_coroutine_front_door(registry, X):
    ctrl = AdmissionController(registry, max_batch=4096)

    async def main():
        return await serve_async("a", _q(X), controller=ctrl,
                                 deadline=time.monotonic() + 0.2)

    with AsyncDriver(ctrl):
        out = asyncio.run(main())
    assert np.asarray(out).shape == (3,)


def test_submit_async_propagates_flush_error(registry, X):
    """A request that becomes unservable at flush time must reject the
    future, not hang it."""
    ctrl = AdmissionController(registry, max_batch=4096)
    svc = ctrl.service("a")

    def bad_submit(q):
        raise ValueError("feature dim moved under the request")

    async def main():
        fut = ctrl.submit_async("a", _q(X))
        svc.submit = bad_submit
        ctrl.flush_model("a")
        with pytest.raises(ValueError):
            await fut

    asyncio.run(main())


# -- shm fleet ---------------------------------------------------------------

def test_shm_attach_scores_bitwise_identical(registry, X, tmp_path):
    sm = registry.get("a")
    q = _q(X, n=7, seed=3)
    ref = np.asarray(sm.score(q))
    lease = shm_registry.publish(sm, "fleet-key", dir=str(tmp_path))
    try:
        sm2, lease2 = shm_registry.attach("fleet-key", dir=str(tmp_path))
        with lease2:
            got = np.asarray(sm2.score(q))
        assert got.tobytes() == ref.tobytes()       # bitwise, not approx
    finally:
        lease.close()


def test_shm_refcount_attach_detach_unlinks_at_zero(registry, X, tmp_path):
    sm = registry.get("a")
    d = str(tmp_path)
    lease = shm_registry.publish(sm, "k", dir=d)
    _, lease2 = shm_registry.attach("k", dir=d)
    assert shm_registry.live_refs("k", dir=d) == 2
    lease2.close()
    lease2.close()                          # double close is a no-op
    assert shm_registry.live_refs("k", dir=d) == 1
    lease.close()
    assert shm_registry.live_refs("k", dir=d) == 0
    with pytest.raises(ShmKeyError):        # segment + manifest gone
        shm_registry.attach("k", dir=d)


def test_shm_leader_death_is_pruned(registry, X, tmp_path):
    """A publisher that dies WITHOUT detaching must not strand the
    refcount: its pid entry is liveness-pruned, and the last live
    holder still unlinks."""
    sm = registry.get("a")
    d = str(tmp_path)
    lease = shm_registry.publish(sm, "k", dir=d)
    # forge the leader's death: replace our pid with one that is gone
    # (a finished subprocess's pid is as dead as a crashed leader's)
    proc = subprocess.Popen([sys.executable, "-c", "pass"])
    proc.wait()                             # reaped: the pid is dead
    dead_pid = proc.pid
    if shm_registry._pid_alive(dead_pid):
        pytest.skip("could not obtain a dead pid")
    refs = tmp_path / f"{shm_registry._digest('k')}.refs"
    refs.write_text('{"pids": [%d]}' % dead_pid)
    assert shm_registry.live_refs("k", dir=d) == 0
    sm2, lease2 = shm_registry.attach("k", dir=d)   # revives the fleet
    assert shm_registry.live_refs("k", dir=d) == 1
    lease2.close()                                  # last LIVE holder out
    with pytest.raises(ShmKeyError):
        shm_registry.attach("k", dir=d)
    lease._shm.close()                              # our stale mapping
    lease.closed = True


def test_attach_untracks_from_resource_tracker(registry, X, tmp_path,
                                               monkeypatch):
    """REGRESSION: on POSIX CPython 3.8-3.12, ``SharedMemory.__init__``
    registers with the resource_tracker unconditionally — for ATTACH
    too, not just create. Pre-fix only the create path untracked, so an
    attached worker's tracker unlinked the live segment when that
    worker's process tree exited, out from under surviving leaseholders
    (masked in forked tests, which share one tracker). Every open must
    leave the tracker balanced for this segment, and unregisters must
    never outrun registers (tracker-daemon KeyError tracebacks)."""
    from multiprocessing import resource_tracker
    events = []
    real_reg = resource_tracker.register
    real_unreg = resource_tracker.unregister
    monkeypatch.setattr(
        resource_tracker, "register",
        lambda name, rtype: (events.append((+1, name, rtype)),
                             real_reg(name, rtype)))
    monkeypatch.setattr(
        resource_tracker, "unregister",
        lambda name, rtype: (events.append((-1, name, rtype)),
                             real_unreg(name, rtype)))
    sm = registry.get("a")
    d = str(tmp_path)
    lease = shm_registry.publish(sm, "tracker-k", dir=d)
    seg = lease._shm.name

    def balance():
        total = 0
        for s, name, rtype in events:
            if rtype == "shared_memory" and name.lstrip("/") == seg:
                total += s
                assert total >= 0           # no unmatched UNREGISTER
        return total

    assert balance() == 0                   # create path untracks
    _, lease2 = shm_registry.attach("tracker-k", dir=d)
    assert balance() == 0                   # THE regression: attach too
    lease2.close()
    lease.close()                           # last out: unlink path
    assert balance() == 0                   # re-register/unlink balanced


def test_attached_worker_exit_does_not_unlink_segment(registry, X,
                                                      tmp_path):
    """End-to-end cross-process version of the tracker regression: a
    worker in a SEPARATE process tree (its own resource_tracker —
    forked test children share the parent's, which masked the bug)
    attaches, detaches cleanly, and exits. Pre-fix, the worker's
    tracker unlinked the segment at exit, out from under the
    publisher's live lease."""
    import repro as repro_pkg
    sm = registry.get("a")
    d = str(tmp_path)
    lease = shm_registry.publish(sm, "worker-k", dir=d)
    code = (
        "from repro.serve import shm_registry\n"
        f"sm, lease = shm_registry.attach('worker-k', dir={d!r})\n"
        "lease.close()\n"
    )
    src_dir = os.path.dirname(os.path.dirname(repro_pkg.__file__))
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               PYTHONPATH=os.pathsep.join(
                   [src_dir, os.environ.get("PYTHONPATH", "")]))
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert res.returncode == 0, res.stderr
    try:
        # the worker's exit (and its tracker's cleanup) must not have
        # taken the fleet down with it
        sm2, lease2 = shm_registry.attach("worker-k", dir=d)
        lease2.close()
    finally:
        lease.close()


def test_flock_retries_on_unlinked_lock_inode(tmp_path):
    """REGRESSION: last-lease cleanup unlinks the .lock file; a
    contender that had already opened (and then flocked) the dying
    inode held a lock no fresh opener contends on — two processes in
    the refcount critical section at once. ``_flock`` must detect that
    the locked fd no longer IS the path and retry on the new file."""
    import fcntl
    lock = tmp_path / "x.lock"
    f = open(lock, "a+")
    fcntl.flock(f, fcntl.LOCK_EX)
    f.write("doomed inode")     # marker: only the OLD inode carries it
    f.flush()                   # (inode NUMBERS get recycled; bytes don't)
    seen = {}

    def contender():
        with shm_registry._flock(lock):
            seen["content"] = lock.read_text()

    t = threading.Thread(target=contender)
    t.start()
    time.sleep(0.3)             # contender opened the doomed inode and
    #                             is parked in flock()
    lock.unlink()               # cleanup retires the inode UNDER the lock
    fcntl.flock(f, fcntl.LOCK_UN)
    f.close()
    t.join(10.0)
    assert not t.is_alive()
    assert seen["content"] == ""            # body ran on the fresh inode


def test_attach_or_publish_builds_once(registry, X, tmp_path):
    sm = registry.get("a")
    d = str(tmp_path)
    builds = []

    def build():
        builds.append(1)
        return sm

    sm1, l1 = shm_registry.attach_or_publish("k", build, dir=d)
    sm2, l2 = shm_registry.attach_or_publish("k", build, dir=d)
    assert len(builds) == 1
    q = _q(X, seed=4)
    assert (np.asarray(sm2.score(q)).tobytes()
            == np.asarray(sm.score(q)).tobytes())
    l1.close()
    l2.close()


# -- CLI ---------------------------------------------------------------------

def test_cli_quota_shed_does_not_crash(tmp_path):
    """REGRESSION: ``submit_stream`` rebound ``rejected`` without
    ``nonlocal``, so the first QuotaExceededError raised
    UnboundLocalError — the CLI crashed in exactly the load-shedding
    scenario its own usage examples document."""
    from repro.launch import serve_slab
    out_json = tmp_path / "stats.json"
    serve_slab.main(["--m", "48", "--requests", "8", "--min-batch", "8",
                     "--max-batch", "64", "--models", "a=rbf:0.5",
                     "--quota", "6", "--tol", "1e-2",
                     "--json", str(out_json)])
    stats = json.loads(out_json.read_text())
    assert stats["rejected"] >= 1           # quota actually bound
    assert stats["admitted"] + stats["rejected"] == 8


# -- per-shape compile trap + flush-overhead estimates -----------------------

def test_numpy_requests_score_to_numpy_host_side(registry, X):
    """REGRESSION: pre-PR the scorer unpadded with a DEVICE slice
    ``out[:n]`` — one fresh trace+compile per distinct (n, bucket) pair,
    ~10-30ms on every continuously-varying admission window. The fix
    keeps numpy requests (the service boundary) on the host for the
    unpad, so numpy in must mean numpy out; jax callers keep a device
    result."""
    scorer = BatchScorer(registry.get("a"))
    q = _q(X, n=5, seed=6)
    out_np = scorer.score(q)
    assert isinstance(out_np, np.ndarray)
    out_dev = scorer.score(jax.numpy.asarray(q))
    assert isinstance(out_dev, jax.Array)
    np.testing.assert_allclose(out_np, np.asarray(out_dev), rtol=1e-6)


def test_estimate_charges_observed_flush_overhead(registry, X):
    """REGRESSION: the deadline estimate summed per-launch bucket means
    only — the per-window non-launch cost (drain/pad/scatter) is
    ADDITIVE, so for a fast model no multiplicative safety factor could
    cover it and windows flushed too late. The estimate must charge the
    service's observed mean flush overhead once per window."""
    clock = ManualClock()
    ctrl = AdmissionController(registry, clock=clock,
                               fallback_latency_s=0.010, safety_factor=1.0)
    svc = ctrl.service("a")
    base = ctrl.estimate_latency_s("a", rows=3)
    assert svc.mean_flush_overhead_s == 0.0      # nothing observed yet
    svc.flush_groups, svc.flush_overhead_s = 4, 4 * 0.025
    assert svc.mean_flush_overhead_s == pytest.approx(0.025)
    assert ctrl.estimate_latency_s("a", rows=3) == pytest.approx(base + 0.025)


def test_flush_overhead_recorded_under_real_clock(registry, X):
    """A real flush must move the overhead counters (the manual-clock
    test above pins the math; this pins the recording seam)."""
    ctrl = AdmissionController(registry)
    svc = ctrl.service("a")
    ctrl.submit("a", _q(X, seed=8))
    ctrl.flush_model("a")
    assert svc.flush_groups == 1
    assert svc.flush_overhead_s >= 0.0
    assert svc.mean_flush_overhead_s == svc.flush_overhead_s
