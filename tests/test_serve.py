"""Serving-subsystem tests: bucket-edge parity against the model's jnp
reference, warm-cache semantics, micro-batching scatter, the sharded
scorer (subprocess, forced host devices), and the fit -> PallasGram
``interpret`` plumbing."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import OCSSVMModel, SlabSpec, compact_support, rbf
from repro.data import make_toy
from repro.serve import (BUCKETS, ModelCache, ScoringService, bucket_for,
                         pack_model)

SPEC = SlabSpec(nu1=0.5, nu2=0.05, eps=0.5, kernel=rbf(gamma=0.5))
M = 96

# every bucket boundary (63/64/65, ...), non-multiples of the query tile,
# single row, and a beyond-top-bucket size that exercises chunking
PARITY_SIZES = [1, 63, 64, 65, 200, 255, 256, 257, 1000]


@pytest.fixture(scope="module")
def served():
    X, _ = make_toy(jax.random.PRNGKey(5), M)
    return repro.serve(X, SPEC, cache=ModelCache(), tol=1e-3)


def _ref(sm, q):
    return np.asarray(sm.model.decision_function(jnp.asarray(q, jnp.float32)))


@pytest.mark.parametrize("n", PARITY_SIZES)
def test_scorer_parity_bucket_edges(served, n):
    q, _ = make_toy(jax.random.PRNGKey(n), n)
    out = served.score(np.asarray(q))
    assert out.shape == (n,)
    np.testing.assert_allclose(np.asarray(out), _ref(served, q),
                               rtol=2e-4, atol=2e-4)


def test_scorer_chunks_beyond_top_bucket(served):
    n = BUCKETS[-1] + 70    # one full top-bucket chunk + a remainder chunk
    q, _ = make_toy(jax.random.PRNGKey(77), n)
    out = served.score(np.asarray(q))
    assert out.shape == (n,)
    np.testing.assert_allclose(np.asarray(out), _ref(served, q),
                               rtol=2e-4, atol=2e-4)


def test_cache_distinguishes_array_kwargs():
    """Array-valued fit kwargs (warm starts) are content-fingerprinted:
    reprs truncate with '...' and would collide."""
    from repro.serve.model_cache import _kwarg_key
    a = np.zeros((2000,), np.float32)
    b = a.copy()
    b[1000] = 1.0
    assert repr(a) == repr(b)                      # the trap
    assert _kwarg_key(a) != _kwarg_key(b)
    assert _kwarg_key(a) == _kwarg_key(a.copy())


def test_fit_interpret_forces_pallas_mode_small_m():
    """An explicit interpret override must reach the Pallas provider even
    below the precomputed-Gram threshold."""
    from repro.api import _auto_gram_mode
    assert _auto_gram_mode(100) == "precomputed"
    assert _auto_gram_mode(100, interpret=True) == "pallas"
    assert _auto_gram_mode(100, interpret=False) == "pallas"


def test_service_counts_chunked_launches(served):
    """A single oversized request is several kernel launches; the
    counters must say so."""
    svc = ScoringService(served.scorer())
    n = BUCKETS[-1] + 70
    q = np.asarray(make_toy(jax.random.PRNGKey(88), n)[0])
    svc.submit(q)
    assert svc.flush() == 2
    assert svc.stats[BUCKETS[-1]].batches == 2
    assert svc.stats[BUCKETS[-1]].queries == n


def test_scorer_device_array_input(served):
    q, _ = make_toy(jax.random.PRNGKey(9), 33)
    np.testing.assert_allclose(np.asarray(served.score(q)), _ref(served, q),
                               rtol=2e-4, atol=2e-4)


def test_zero_support_vector_model():
    """All-zero gamma packs to an all-padding tile; every query scores the
    constant (0 - rho1) * (rho2 - 0)."""
    X = jnp.asarray(np.random.default_rng(0).normal(size=(40, 3)),
                    jnp.float32)
    model = OCSSVMModel(gamma=jnp.zeros((40,)), rho1=jnp.float32(0.2),
                        rho2=jnp.float32(0.8), X=X, spec=SPEC)
    sm = pack_model(model)
    assert sm.n_sv == 0
    q = np.random.default_rng(1).normal(size=(65, 3)).astype(np.float32)
    out = np.asarray(sm.score(q))
    np.testing.assert_allclose(out, np.full((65,), -0.2 * 0.8),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(out, _ref(sm, q), rtol=1e-6, atol=1e-6)


def test_compact_support_drops_only_tiny_gammas():
    X, _ = make_toy(jax.random.PRNGKey(3), 32)
    gamma = jnp.zeros((32,)).at[jnp.asarray([3, 7, 20])].set(
        jnp.asarray([0.4, -0.2, 0.3]))
    model = OCSSVMModel(gamma=gamma, rho1=jnp.float32(0.0),
                        rho2=jnp.float32(1.0), X=X, spec=SPEC)
    small = compact_support(model)
    assert small.X.shape == (3, X.shape[1])
    np.testing.assert_allclose(np.asarray(small.gamma), [0.4, -0.2, 0.3])
    q, _ = make_toy(jax.random.PRNGKey(4), 10)
    np.testing.assert_allclose(np.asarray(small.decision_function(q)),
                               np.asarray(model.decision_function(q)),
                               rtol=1e-5, atol=1e-6)


def test_bucket_for_policy():
    assert [bucket_for(n) for n in (1, 63, 64, 65, 256, 257, 4096, 9999)] \
        == [64, 64, 64, 256, 256, 1024, 4096, 4096]
    with pytest.raises(ValueError):
        bucket_for(0)


def test_scorer_rejects_bad_shapes(served):
    with pytest.raises(ValueError):
        served.scorer().score(np.zeros((4, 7), np.float32))  # wrong d
    with pytest.raises(ValueError):
        served.scorer().score(np.zeros((4,), np.float32))    # not 2-D


def test_cache_hits_skip_fit(monkeypatch):
    from repro import api
    calls = {"n": 0}
    real_fit = api.fit

    def counting_fit(*args, **kwargs):
        calls["n"] += 1
        return real_fit(*args, **kwargs)

    monkeypatch.setattr(api, "fit", counting_fit)
    cache = ModelCache()
    X, _ = make_toy(jax.random.PRNGKey(5), M)
    sm1 = cache.get_or_fit(X, SPEC, tol=1e-3)
    sm2 = cache.get_or_fit(X, SPEC, tol=1e-3)
    assert sm2 is sm1 and calls["n"] == 1
    assert (cache.hits, cache.misses) == (1, 1)
    # a different spec, data, or fit kwarg is a different model
    cache.get_or_fit(X, SPEC, tol=1e-4)
    spec2 = SlabSpec(nu1=0.4, nu2=0.05, eps=0.5, kernel=rbf(gamma=0.5))
    cache.get_or_fit(X, spec2, tol=1e-3)
    X2, _ = make_toy(jax.random.PRNGKey(6), M)
    cache.get_or_fit(X2, SPEC, tol=1e-3)
    assert calls["n"] == 4 and cache.misses == 4


def test_cache_lru_eviction():
    cache = ModelCache(maxsize=2)
    X, _ = make_toy(jax.random.PRNGKey(5), 48)
    for nu1 in (0.3, 0.4, 0.5):
        cache.get_or_fit(
            X, SlabSpec(nu1=nu1, nu2=0.05, eps=0.5, kernel=rbf(gamma=0.5)),
            tol=1e-2, max_outer=50)
    assert len(cache) == 2
    # the oldest entry (nu1=0.3) was evicted -> a re-request misses
    cache.get_or_fit(
        X, SlabSpec(nu1=0.3, nu2=0.05, eps=0.5, kernel=rbf(gamma=0.5)),
        tol=1e-2, max_outer=50)
    assert cache.misses == 4


def test_service_microbatch_scatter_parity(served):
    """Queued requests coalesce into one launch and every handle gets
    exactly its own rows back."""
    svc = ScoringService(served.scorer())
    sizes = (5, 48, 63, 100)
    reqs = [np.asarray(make_toy(jax.random.PRNGKey(40 + i), n)[0])
            for i, n in enumerate(sizes)]
    handles = [svc.submit(q) for q in reqs]
    assert svc.queued_rows == sum(sizes)
    launches = svc.flush()
    assert launches == 1          # 216 rows coalesce under the top bucket
    for q, h in zip(reqs, handles):
        assert h.done
        np.testing.assert_allclose(np.asarray(h.result()), _ref(served, q),
                                   rtol=2e-4, atol=2e-4)
    b = bucket_for(sum(sizes))
    assert svc.stats[b].batches == 1
    assert svc.stats[b].requests == len(sizes)
    assert svc.stats[b].queries == sum(sizes)
    assert svc.stats[b].total_s > 0


def test_service_groups_respect_max_batch(served):
    svc = ScoringService(served.scorer(), max_batch=128)
    for i in range(4):
        svc.submit(np.asarray(make_toy(jax.random.PRNGKey(50 + i), 40)[0]))
    # 40+40 fits under 128, a third 40 would not: two groups of two
    assert svc.flush() == 2
    assert sum(s.requests for s in svc.stats.values()) == 4
    assert sum(s.batches for s in svc.stats.values()) == 2


def test_service_result_triggers_flush(served):
    svc = ScoringService(served.scorer())
    q = np.asarray(make_toy(jax.random.PRNGKey(60), 10)[0])
    h = svc.submit(q)
    assert not h.done
    np.testing.assert_allclose(np.asarray(h.result()), _ref(served, q),
                               rtol=2e-4, atol=2e-4)
    assert h.done and not svc._queue


def test_fit_threads_interpret_to_pallas_provider(monkeypatch):
    """repro.fit(..., interpret=True) must reach the PallasGram provider —
    the deterministic CPU-CI hook for the pallas path."""
    from repro.core.engine import gram as engine_gram
    seen = {}
    real = engine_gram.PallasGram.__init__

    def spying_init(self, X, kernel, interpret=None):
        seen["interpret"] = interpret
        real(self, X, kernel, interpret=interpret)

    monkeypatch.setattr(engine_gram.PallasGram, "__init__", spying_init)
    X, _ = make_toy(jax.random.PRNGKey(5), M)
    res = repro.fit(X, SPEC, strategy="blocked", gram_mode="pallas",
                    interpret=True, tol=1e-2, max_outer=64)
    assert seen["interpret"] is True
    assert np.isfinite(float(res.gap))


def test_fit_distributed_rejects_interpret():
    X, _ = make_toy(jax.random.PRNGKey(5), 32)
    with pytest.raises(ValueError):
        repro.fit(X, SPEC, strategy="distributed", mesh=object(),
                  interpret=True)


def test_example_has_no_direct_kernel_imports():
    """Acceptance: the example runs through the serve subsystem only."""
    path = os.path.join(os.path.dirname(__file__), "..", "examples",
                        "serve_ocssvm.py")
    with open(path) as fh:
        src = fh.read()
    assert "repro.kernels" not in src
    assert "repro.serve" in src or "repro.serve(" in src


def test_sharded_scorer_matches_local():
    """shard_map'd scoring over a forced 4-device host mesh must agree
    with the local bucketed path (subprocess: the main pytest process
    stays 1-device)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    code = textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp
        import numpy as np
        import repro
        from repro.core import SlabSpec, rbf
        from repro.data import make_toy
        X, _ = make_toy(jax.random.PRNGKey(5), 96)
        spec = SlabSpec(nu1=0.5, nu2=0.05, eps=0.5, kernel=rbf(gamma=0.5))
        sm = repro.serve(X, spec, tol=1e-3)
        mesh = jax.make_mesh((4,), ("data",))
        q, _ = make_toy(jax.random.PRNGKey(7), 130)   # not a shard multiple
        local = np.asarray(sm.score(np.asarray(q)))
        sharded = np.asarray(sm.score(np.asarray(q), mesh=mesh))
        # beyond one sharded launch's capacity (4 * top bucket): must chunk
        scorer = sm.scorer(mesh=mesh)
        nbig = scorer.chunk_rows() + 60
        qb, _ = make_toy(jax.random.PRNGKey(8), nbig)
        big = np.asarray(scorer.score(np.asarray(qb)))
        ref = np.asarray(sm.model.decision_function(
            jnp.asarray(qb, jnp.float32)))
        print(json.dumps({
            "max_abs_diff": float(np.max(np.abs(local - sharded))),
            "n": int(sharded.shape[0]),
            "big_n": int(big.shape[0]),
            "big_max_abs_diff": float(np.max(np.abs(big - ref)))}))
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["n"] == 130
    assert res["max_abs_diff"] < 1e-5
    assert res["big_n"] == 4 * 4096 + 60
    assert res["big_max_abs_diff"] < 1e-4
