"""Serving-subsystem tests: bucket-edge parity against the model's jnp
reference, warm-cache semantics, micro-batching scatter, the sharded
scorer (subprocess, forced host devices), and the fit -> PallasGram
``interpret`` plumbing."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import OCSSVMModel, SlabSpec, compact_support, rbf
from repro.data import make_toy
from repro.serve import (BUCKETS, ModelCache, ScoringService, bucket_for,
                         pack_model)

SPEC = SlabSpec(nu1=0.5, nu2=0.05, eps=0.5, kernel=rbf(gamma=0.5))
M = 96


class TickClock:
    """Fake service clock: every call advances a fixed step, so each
    timed launch reads exactly ``step`` seconds — latency assertions
    become equalities instead of wall-clock-dependent inequalities."""

    def __init__(self, step=1e-3):
        self.t = 0.0
        self.step = step

    def __call__(self):
        self.t += self.step
        return self.t

# every bucket boundary (63/64/65, ...), non-multiples of the query tile,
# single row, and a beyond-top-bucket size that exercises chunking
PARITY_SIZES = [1, 63, 64, 65, 200, 255, 256, 257, 1000]


@pytest.fixture(scope="module")
def served():
    X, _ = make_toy(jax.random.PRNGKey(5), M)
    return repro.serve(X, SPEC, cache=ModelCache(), tol=1e-3)


def _ref(sm, q):
    return np.asarray(sm.model.decision_function(jnp.asarray(q, jnp.float32)))


@pytest.mark.parametrize("n", PARITY_SIZES)
def test_scorer_parity_bucket_edges(served, n):
    q, _ = make_toy(jax.random.PRNGKey(n), n)
    out = served.score(np.asarray(q))
    assert out.shape == (n,)
    np.testing.assert_allclose(np.asarray(out), _ref(served, q),
                               rtol=2e-4, atol=2e-4)


def test_scorer_chunks_beyond_top_bucket(served):
    n = BUCKETS[-1] + 70    # one full top-bucket chunk + a remainder chunk
    q, _ = make_toy(jax.random.PRNGKey(77), n)
    out = served.score(np.asarray(q))
    assert out.shape == (n,)
    np.testing.assert_allclose(np.asarray(out), _ref(served, q),
                               rtol=2e-4, atol=2e-4)


def test_cache_distinguishes_array_kwargs():
    """Array-valued fit kwargs (warm starts) are content-fingerprinted:
    reprs truncate with '...' and would collide."""
    from repro.serve.model_cache import _kwarg_key
    a = np.zeros((2000,), np.float32)
    b = a.copy()
    b[1000] = 1.0
    assert repr(a) == repr(b)                      # the trap
    assert _kwarg_key(a) != _kwarg_key(b)
    assert _kwarg_key(a) == _kwarg_key(a.copy())


def test_fit_interpret_forces_pallas_mode_small_m():
    """An explicit interpret override must reach the Pallas provider even
    below the precomputed-Gram threshold."""
    from repro.api import _auto_gram_mode
    assert _auto_gram_mode(100) == "precomputed"
    assert _auto_gram_mode(100, interpret=True) == "pallas"
    assert _auto_gram_mode(100, interpret=False) == "pallas"


def test_service_counts_chunked_launches(served):
    """A single oversized request is several kernel launches, and each
    launch is filed under the bucket that actually served it: the full
    chunk under the top bucket, the 70-row remainder under ITS bucket
    (256), not lumped under the top one. The injected tick clock makes
    the latency counters exact (one step per launch) instead of
    wall-clock-dependent."""
    clock = TickClock(step=1e-3)
    svc = ScoringService(served.scorer(), clock=clock)
    n = BUCKETS[-1] + 70
    q = np.asarray(make_toy(jax.random.PRNGKey(88), n)[0])
    svc.submit(q)
    assert svc.flush() == 2
    top = svc.stats[BUCKETS[-1]]
    rem = svc.stats[bucket_for(70)]
    assert (top.batches, top.queries, top.requests) == (1, BUCKETS[-1], 1)
    assert (rem.batches, rem.queries, rem.requests) == (1, 70, 0)
    assert top.total_s == pytest.approx(clock.step)
    assert rem.total_s == pytest.approx(clock.step)
    assert top.mean_latency_s == pytest.approx(clock.step)
    assert rem.last_s == pytest.approx(clock.step)


def test_service_default_clock_is_monotonic():
    """No direct time.* calls in the hot loop: all BucketStats timing
    goes through the injectable clock, defaulting to time.monotonic."""
    import time as _time

    svc = ScoringService(_FakeScorer())
    assert svc.clock is _time.monotonic


def test_service_chunked_scatter_parity(served):
    """Chunk-by-chunk scoring inside flush must still hand every handle
    exactly its own rows."""
    svc = ScoringService(served.scorer())
    n = BUCKETS[-1] + 70
    q = np.asarray(make_toy(jax.random.PRNGKey(89), n)[0])
    h = svc.submit(q)
    svc.flush()
    np.testing.assert_allclose(np.asarray(h.result()), _ref(served, q),
                               rtol=2e-4, atol=2e-4)


def test_service_queue_is_deque():
    """The queue must not be a list: list.pop(0) makes a deep drain
    O(n^2)."""
    from collections import deque
    svc = ScoringService.__new__(ScoringService)
    ScoringService.__init__(svc, scorer=_FakeScorer())
    assert isinstance(svc._queue, deque)


class _FakeScorer:
    """Minimal stand-in so queue-structure tests need no fitted model."""

    def _check(self, q):
        pass

    def chunk_rows(self):
        return BUCKETS[-1]

    def bucket_used(self, n):
        return bucket_for(n)

    def launch_plan(self, n):
        cap = self.chunk_rows()
        sizes = [cap] * (n // cap) + ([n % cap] if n % cap else [])
        return [(r, bucket_for(r)) for r in sizes]

    def score(self, q):
        return jnp.zeros((q.shape[0],), jnp.float32)


def test_scorer_device_array_input(served):
    q, _ = make_toy(jax.random.PRNGKey(9), 33)
    np.testing.assert_allclose(np.asarray(served.score(q)), _ref(served, q),
                               rtol=2e-4, atol=2e-4)


def test_zero_support_vector_model():
    """All-zero gamma packs to an all-padding tile; every query scores the
    constant (0 - rho1) * (rho2 - 0)."""
    X = jnp.asarray(np.random.default_rng(0).normal(size=(40, 3)),
                    jnp.float32)
    model = OCSSVMModel(gamma=jnp.zeros((40,)), rho1=jnp.float32(0.2),
                        rho2=jnp.float32(0.8), X=X, spec=SPEC)
    sm = pack_model(model)
    assert sm.n_sv == 0
    q = np.random.default_rng(1).normal(size=(65, 3)).astype(np.float32)
    out = np.asarray(sm.score(q))
    np.testing.assert_allclose(out, np.full((65,), -0.2 * 0.8),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(out, _ref(sm, q), rtol=1e-6, atol=1e-6)


def test_compact_support_drops_only_tiny_gammas():
    X, _ = make_toy(jax.random.PRNGKey(3), 32)
    gamma = jnp.zeros((32,)).at[jnp.asarray([3, 7, 20])].set(
        jnp.asarray([0.4, -0.2, 0.3]))
    model = OCSSVMModel(gamma=gamma, rho1=jnp.float32(0.0),
                        rho2=jnp.float32(1.0), X=X, spec=SPEC)
    small = compact_support(model)
    assert small.X.shape == (3, X.shape[1])
    np.testing.assert_allclose(np.asarray(small.gamma), [0.4, -0.2, 0.3])
    q, _ = make_toy(jax.random.PRNGKey(4), 10)
    np.testing.assert_allclose(np.asarray(small.decision_function(q)),
                               np.asarray(model.decision_function(q)),
                               rtol=1e-5, atol=1e-6)


def test_bucket_for_policy():
    assert [bucket_for(n) for n in (1, 63, 64, 65, 256, 257, 4096, 9999)] \
        == [64, 64, 64, 256, 256, 1024, 4096, 4096]
    with pytest.raises(ValueError):
        bucket_for(0)


def test_scorer_rejects_bad_shapes(served):
    with pytest.raises(ValueError):
        served.scorer().score(np.zeros((4, 7), np.float32))  # wrong d
    with pytest.raises(ValueError):
        served.scorer().score(np.zeros((4,), np.float32))    # not 2-D


def test_cache_hits_skip_fit(monkeypatch):
    from repro import api
    calls = {"n": 0}
    real_fit = api.fit

    def counting_fit(*args, **kwargs):
        calls["n"] += 1
        return real_fit(*args, **kwargs)

    monkeypatch.setattr(api, "fit", counting_fit)
    cache = ModelCache()
    X, _ = make_toy(jax.random.PRNGKey(5), M)
    sm1 = cache.get_or_fit(X, SPEC, tol=1e-3)
    sm2 = cache.get_or_fit(X, SPEC, tol=1e-3)
    assert sm2 is sm1 and calls["n"] == 1
    assert (cache.hits, cache.misses) == (1, 1)
    # a different spec, data, or fit kwarg is a different model
    cache.get_or_fit(X, SPEC, tol=1e-4)
    spec2 = SlabSpec(nu1=0.4, nu2=0.05, eps=0.5, kernel=rbf(gamma=0.5))
    cache.get_or_fit(X, spec2, tol=1e-3)
    X2, _ = make_toy(jax.random.PRNGKey(6), M)
    cache.get_or_fit(X2, SPEC, tol=1e-3)
    assert calls["n"] == 4 and cache.misses == 4


def test_cache_lru_eviction():
    cache = ModelCache(maxsize=2)
    X, _ = make_toy(jax.random.PRNGKey(5), 48)
    for nu1 in (0.3, 0.4, 0.5):
        cache.get_or_fit(
            X, SlabSpec(nu1=nu1, nu2=0.05, eps=0.5, kernel=rbf(gamma=0.5)),
            tol=1e-2, max_outer=50)
    assert len(cache) == 2
    # the oldest entry (nu1=0.3) was evicted -> a re-request misses
    cache.get_or_fit(
        X, SlabSpec(nu1=0.3, nu2=0.05, eps=0.5, kernel=rbf(gamma=0.5)),
        tol=1e-2, max_outer=50)
    assert cache.misses == 4


def test_service_microbatch_scatter_parity(served):
    """Queued requests coalesce into one launch and every handle gets
    exactly its own rows back."""
    clock = TickClock(step=2e-3)
    svc = ScoringService(served.scorer(), clock=clock)
    sizes = (5, 48, 63, 100)
    reqs = [np.asarray(make_toy(jax.random.PRNGKey(40 + i), n)[0])
            for i, n in enumerate(sizes)]
    handles = [svc.submit(q) for q in reqs]
    assert svc.queued_rows == sum(sizes)
    launches = svc.flush()
    assert launches == 1          # 216 rows coalesce under the top bucket
    for q, h in zip(reqs, handles):
        assert h.done
        np.testing.assert_allclose(np.asarray(h.result()), _ref(served, q),
                                   rtol=2e-4, atol=2e-4)
    b = bucket_for(sum(sizes))
    assert svc.stats[b].batches == 1
    assert svc.stats[b].requests == len(sizes)
    assert svc.stats[b].queries == sum(sizes)
    # one launch, one clock step — exact under the fake clock
    assert svc.stats[b].total_s == pytest.approx(clock.step)


def test_service_groups_respect_max_batch(served):
    svc = ScoringService(served.scorer(), max_batch=128)
    for i in range(4):
        svc.submit(np.asarray(make_toy(jax.random.PRNGKey(50 + i), 40)[0]))
    # 40+40 fits under 128, a third 40 would not: two groups of two
    assert svc.flush() == 2
    assert sum(s.requests for s in svc.stats.values()) == 4
    assert sum(s.batches for s in svc.stats.values()) == 2


def test_service_result_triggers_flush(served):
    svc = ScoringService(served.scorer())
    q = np.asarray(make_toy(jax.random.PRNGKey(60), 10)[0])
    h = svc.submit(q)
    assert not h.done
    np.testing.assert_allclose(np.asarray(h.result()), _ref(served, q),
                               rtol=2e-4, atol=2e-4)
    assert h.done and not svc._queue


def test_fit_threads_interpret_to_pallas_provider(monkeypatch):
    """repro.fit(..., interpret=True) must reach the PallasGram provider —
    the deterministic CPU-CI hook for the pallas path."""
    from repro.core.engine import gram as engine_gram
    seen = {}
    real = engine_gram.PallasGram.__init__

    def spying_init(self, X, kernel, interpret=None, precision="f32"):
        seen["interpret"] = interpret
        real(self, X, kernel, interpret=interpret, precision=precision)

    monkeypatch.setattr(engine_gram.PallasGram, "__init__", spying_init)
    X, _ = make_toy(jax.random.PRNGKey(5), M)
    res = repro.fit(X, SPEC, strategy="blocked", gram_mode="pallas",
                    interpret=True, tol=1e-2, max_outer=64)
    assert seen["interpret"] is True
    assert np.isfinite(float(res.gap))


def test_fit_sharded_rejects_gram_mode():
    """The sharded strategies own Gram access (per-shard Pallas fupdate);
    gram_mode must be rejected before any mesh work happens. interpret is
    NOT rejected anymore — it now reaches the per-shard kernel."""
    X, _ = make_toy(jax.random.PRNGKey(5), 32)
    with pytest.raises(ValueError):
        repro.fit(X, SPEC, strategy="distributed", mesh=object(),
                  gram_mode="pallas")
    with pytest.raises(ValueError):
        repro.fit(X, SPEC, strategy="sharded", gram_mode="precomputed")


def test_example_has_no_direct_kernel_imports():
    """Acceptance: the example runs through the serve subsystem only."""
    path = os.path.join(os.path.dirname(__file__), "..", "examples",
                        "serve_ocssvm.py")
    with open(path) as fh:
        src = fh.read()
    assert "repro.kernels" not in src
    assert "repro.serve" in src or "repro.serve(" in src


def test_sharded_scorer_matches_local():
    """shard_map'd scoring over a forced 4-device host mesh must agree
    with the local bucketed path (subprocess: the main pytest process
    stays 1-device)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    code = textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp
        import numpy as np
        import repro
        from repro.core import SlabSpec, rbf
        from repro.data import make_toy
        X, _ = make_toy(jax.random.PRNGKey(5), 96)
        spec = SlabSpec(nu1=0.5, nu2=0.05, eps=0.5, kernel=rbf(gamma=0.5))
        sm = repro.serve(X, spec, tol=1e-3)
        mesh = jax.make_mesh((4,), ("data",))
        q, _ = make_toy(jax.random.PRNGKey(7), 130)   # not a shard multiple
        local = np.asarray(sm.score(np.asarray(q)))
        sharded = np.asarray(sm.score(np.asarray(q), mesh=mesh))
        # beyond one sharded launch's capacity (4 * top bucket): must chunk
        scorer = sm.scorer(mesh=mesh)
        nbig = scorer.chunk_rows() + 60
        qb, _ = make_toy(jax.random.PRNGKey(8), nbig)
        big = np.asarray(scorer.score(np.asarray(qb)))
        ref = np.asarray(sm.model.decision_function(
            jnp.asarray(qb, jnp.float32)))
        print(json.dumps({
            "max_abs_diff": float(np.max(np.abs(local - sharded))),
            "n": int(sharded.shape[0]),
            "big_n": int(big.shape[0]),
            "big_max_abs_diff": float(np.max(np.abs(big - ref)))}))
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["n"] == 130
    assert res["max_abs_diff"] < 1e-5
    assert res["big_n"] == 4 * 4096 + 60
    assert res["big_max_abs_diff"] < 1e-4


# -- satellite regressions: herd / warmup / fingerprint / precision ---------

def test_cache_thundering_herd_single_fit(monkeypatch):
    """Two threads missing on the same key must run ONE fit: the loser
    blocks on the winner's in-flight entry instead of fitting again."""
    import threading
    import time as _time

    from repro import api

    calls = {"n": 0}
    real_fit = api.fit

    def slow_fit(*args, **kwargs):
        calls["n"] += 1
        _time.sleep(0.5)        # long enough for both threads to race
        return real_fit(*args, **kwargs)

    monkeypatch.setattr(api, "fit", slow_fit)
    cache = ModelCache()
    X, _ = make_toy(jax.random.PRNGKey(5), 48)
    results = {}
    barrier = threading.Barrier(2)

    def worker(name):
        barrier.wait()
        results[name] = cache.get_or_fit(X, SPEC, tol=1e-2, max_outer=50)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert calls["n"] == 1, "both threads ran the expensive fit"
    assert results[0] is results[1]
    assert cache.misses == 1 and cache.hits == 1


def test_cache_failed_fit_not_cached(monkeypatch):
    """A raising fit must not poison the key: the next caller re-fits."""
    from repro import api

    calls = {"n": 0}
    real_fit = api.fit

    def flaky_fit(*args, **kwargs):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("boom")
        return real_fit(*args, **kwargs)

    monkeypatch.setattr(api, "fit", flaky_fit)
    cache = ModelCache()
    X, _ = make_toy(jax.random.PRNGKey(5), 48)
    with pytest.raises(RuntimeError):
        cache.get_or_fit(X, SPEC, tol=1e-2, max_outer=50)
    sm = cache.get_or_fit(X, SPEC, tol=1e-2, max_outer=50)
    assert calls["n"] == 2 and sm is not None
    assert not cache._inflight


def test_warmup_compiles_the_served_path(served, monkeypatch):
    """warmup() must pre-compile the path score() will take: the sharded
    (shard_map) executables when a mesh is set — NOT the local bucket
    programs."""
    from repro.serve.scorer import BatchScorer

    mesh = jax.make_mesh((1,), ("data",))
    calls = {"sharded": [], "local": 0}
    real_sharded = BatchScorer._score_sharded

    def spy_sharded(self, q, n):
        calls["sharded"].append(n)
        return real_sharded(self, q, n)

    def spy_bucket(self, q_pad):
        calls["local"] += 1
        raise AssertionError("warmup with mesh hit the local bucket path")

    monkeypatch.setattr(BatchScorer, "_score_sharded", spy_sharded)
    monkeypatch.setattr(BatchScorer, "_score_bucket", spy_bucket)
    scorer = served.scorer(mesh=mesh)
    scorer.warmup()
    # one warm request per bucket, each landing on that per-shard bucket
    assert calls["sharded"] == list(BUCKETS)
    assert calls["local"] == 0


def test_warmup_local_matches_serving_buckets(served):
    """Local warmup still covers every bucket and a post-warmup score
    agrees with the reference."""
    scorer = served.scorer()
    scorer.warmup()
    q, _ = make_toy(jax.random.PRNGKey(91), 65)
    np.testing.assert_allclose(np.asarray(scorer.score(np.asarray(q))),
                               _ref(served, q), rtol=2e-4, atol=2e-4)


def test_fingerprint_array_edge_cases():
    from repro.serve import fingerprint_array

    # 0-d and 1-D inputs must fingerprint without tripping on a[0]
    f0 = fingerprint_array(np.float32(3.0))
    assert f0[0] == ()
    f1 = fingerprint_array(np.arange(7, dtype=np.float32))
    assert f1[0] == (7,)
    assert f0 != f1

    # same content, different layout -> equal fingerprints
    a = np.arange(24, dtype=np.float32).reshape(4, 6)
    fa = fingerprint_array(a)
    assert fingerprint_array(np.asfortranarray(a)) == fa
    wide = np.zeros((4, 12), np.float32)
    wide[:, ::2] = a
    b = wide[:, ::2]            # strided view, same logical content as a
    assert not b.flags.c_contiguous
    assert fingerprint_array(b) == fa
    assert fingerprint_array(np.ascontiguousarray(b)) == fa

    # different content / dtype / shape -> different fingerprints
    assert fingerprint_array(a + 1) != fa
    assert fingerprint_array(a.astype(np.float64)) != fa
    assert fingerprint_array(a.reshape(6, 4)) != fa


def test_fingerprint_array_sampling_above_budget(monkeypatch):
    """Above the byte budget an evenly strided row sample is hashed; the
    sample must still see content differences in sampled rows and be
    layout-invariant."""
    from repro.serve import model_cache

    monkeypatch.setattr(model_cache, "_HASH_SAMPLE_BYTES", 1 << 10)
    a = np.arange(4096, dtype=np.float32).reshape(256, 16)
    fa = model_cache.fingerprint_array(a)
    assert model_cache.fingerprint_array(np.asfortranarray(a)) == fa
    b = a.copy()
    b[0, 0] += 1.0          # row 0 is always in the sample
    assert model_cache.fingerprint_array(b) != fa
    # big 1-D inputs sample instead of hashing everything
    v = np.arange(1 << 12, dtype=np.float32)
    fv = model_cache.fingerprint_array(v)
    assert fv[0] == ((1 << 12),)
    assert model_cache.fingerprint_array(v * 0) != fv


@pytest.mark.parametrize("precision", ["bf16", "f16"])
def test_serving_precision_parity(precision):
    """A model served at 16-bit tile precision must match the f32
    reference within the documented per-dtype tolerance, and its packed
    support block must actually be stored in the 16-bit dtype."""
    from repro.kernels.precision import tile_dtype, truth_tolerance

    X, _ = make_toy(jax.random.PRNGKey(5), M)
    sm = repro.serve(X, SPEC, cache=ModelCache(), tol=1e-3,
                     precision=precision)
    assert sm.precision == precision
    assert sm.t_pad.dtype == tile_dtype(precision)
    q, _ = make_toy(jax.random.PRNGKey(11), 130)
    out = np.asarray(sm.score(np.asarray(q)))
    ref = _ref(sm, q)
    np.testing.assert_allclose(out, ref, **truth_tolerance(precision, ref))


def test_serving_precision_is_part_of_cache_key():
    cache = ModelCache()
    X, _ = make_toy(jax.random.PRNGKey(5), 48)
    sm32 = cache.get_or_fit(X, SPEC, tol=1e-2, max_outer=50)
    smbf = cache.get_or_fit(X, SPEC, tol=1e-2, max_outer=50,
                            precision="bf16")
    assert sm32 is not smbf
    assert cache.misses == 2
    assert sm32.t_pad.dtype == jnp.float32
    assert smbf.t_pad.dtype == jnp.bfloat16
    # same precision again -> hit
    assert cache.get_or_fit(X, SPEC, tol=1e-2, max_outer=50,
                            precision="bf16") is smbf
    assert cache.hits == 1


def test_serve_rejects_unknown_precision():
    X, _ = make_toy(jax.random.PRNGKey(5), 32)
    with pytest.raises(ValueError):
        repro.serve(X, SPEC, cache=ModelCache(), precision="int8")


def test_cache_clear_during_inflight_fit(monkeypatch):
    """clear() while a fit is in flight: the fit's waiter still gets a
    model, but nothing re-appears in the cleared cache."""
    import threading
    import time as _time

    from repro import api

    real_fit = api.fit
    started = threading.Event()

    def slow_fit(*args, **kwargs):
        started.set()
        _time.sleep(0.4)
        return real_fit(*args, **kwargs)

    monkeypatch.setattr(api, "fit", slow_fit)
    cache = ModelCache()
    X, _ = make_toy(jax.random.PRNGKey(5), 48)
    out = {}

    t = threading.Thread(
        target=lambda: out.update(
            sm=cache.get_or_fit(X, SPEC, tol=1e-2, max_outer=50)))
    t.start()
    started.wait(timeout=60)
    cache.clear()
    t.join(timeout=120)
    assert out["sm"] is not None        # the in-flight caller got a model
    assert len(cache) == 0              # ...but the cleared cache stayed empty
    assert not cache._inflight


def test_service_rejects_empty_request(served):
    """A zero-row request must fail fast at submit time, not crash a
    later flush with an unrelated concatenate error."""
    svc = ScoringService(served.scorer())
    with pytest.raises(ValueError):
        svc.submit(np.zeros((0, served.d), np.float32))
    assert not svc._queue
    assert svc.flush() == 0
