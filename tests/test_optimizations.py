"""Beyond-paper optimization correctness: fused CE, quantile offsets,
a2a MoE (multi-device, subprocess), solver stat fusion."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_fused_unembed_ce_matches_naive():
    from repro.train.train_step import cross_entropy, fused_unembed_ce
    k = jax.random.PRNGKey(0)
    h = jax.random.normal(k, (2, 6, 32), jnp.float32)
    W = jax.random.normal(k, (32, 4096), jnp.float32) * 0.1
    lb = jax.random.randint(k, (2, 6), 0, 4000)
    logits = jnp.where(jnp.arange(4096) < 4000, h @ W, -1e30)
    a = cross_entropy(logits, lb)
    b = fused_unembed_ce(h, W, lb, vocab_size=4000, chunk=512)
    assert float(jnp.abs(a - b)) < 1e-5
    # gradients agree too
    ga = jax.grad(lambda h: cross_entropy(
        jnp.where(jnp.arange(4096) < 4000, h @ W, -1e30), lb))(h)
    gb = jax.grad(lambda h: fused_unembed_ce(
        h, W, lb, vocab_size=4000, chunk=512))(h)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(gb),
                               rtol=1e-4, atol=1e-5)


def test_fused_ce_ignore_labels():
    from repro.train.train_step import IGNORE_LABEL, fused_unembed_ce
    k = jax.random.PRNGKey(1)
    h = jax.random.normal(k, (1, 4, 16), jnp.float32)
    W = jax.random.normal(k, (16, 512), jnp.float32)
    lb = jnp.array([[3, IGNORE_LABEL, 7, IGNORE_LABEL]])
    out = fused_unembed_ce(h, W, lb, vocab_size=512, chunk=128)
    assert np.isfinite(float(out))


def test_quantile_offsets_restore_slab():
    from repro.core import (SlabSpec, rbf, solve_blocked,
                            with_quantile_offsets)
    from repro.data import make_toy
    X, y = make_toy(jax.random.PRNGKey(0), 400)
    spec = SlabSpec(nu1=0.3, nu2=0.05, eps=0.4, kernel=rbf(gamma=0.8))
    res = solve_blocked(X, spec, P=8, tol=1e-3)
    fixed = with_quantile_offsets(res.model)
    # slab has positive width and quantile semantics hold
    assert float(fixed.rho2) > float(fixed.rho1)
    s = fixed.raw_scores(X)
    frac_below = float((s < fixed.rho1).mean())
    frac_above = float((s > fixed.rho2).mean())
    assert frac_below == pytest.approx(spec.nu1, abs=0.05)
    assert frac_above == pytest.approx(spec.nu2, abs=0.05)


def test_shrinking_reaches_same_optimum():
    from repro.core import SlabSpec, dual_objective, rbf, solve_blocked
    from repro.core.shrinking import solve_blocked_shrinking
    from repro.data import make_toy
    X, _ = make_toy(jax.random.PRNGKey(7), 768)
    spec = SlabSpec(nu1=0.5, nu2=0.05, eps=0.5, kernel=rbf(gamma=0.5))
    K = spec.kernel.gram(X.astype(jnp.float32))
    full = solve_blocked(X, spec, P=8, tol=1e-4)
    shr = solve_blocked_shrinking(X, spec, P=8, tol=1e-4)
    assert bool(shr.converged)
    o1 = float(dual_objective(full.model.gamma, K))
    o2 = float(dual_objective(shr.model.gamma, K))
    assert abs(o1 - o2) < 1e-4
    # constraints hold on the re-assembled full gamma
    g = shr.model.gamma
    assert float(jnp.sum(g)) == pytest.approx(spec.total(), abs=1e-4)
    assert float(jnp.max(g)) <= spec.upper(768) + 1e-6
    assert float(jnp.min(g)) >= spec.lower(768) - 1e-6


def _run(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = REPO_SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout.strip().splitlines()[-1]


def test_a2a_moe_matches_global():
    line = _run("""
        import json, dataclasses
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_test_mesh
        from repro.sharding.specs import make_constrain
        from repro.models.moe import moe_forward, moe_init
        d, E = 16, 4
        key = jax.random.PRNGKey(0)
        p = moe_init(key, d, E, 32, "swiglu", jnp.float32)
        x = jax.random.normal(key, (4, 8, d), jnp.float32)
        y0, _ = moe_forward(p, x, n_experts=E, top_k=2,
                            capacity_factor=float(E), mlp_type="swiglu")
        mesh = make_test_mesh((2, 2), ("data", "model"))
        constrain = make_constrain(mesh, fsdp=False)
        with mesh:
            y1, _ = jax.jit(lambda p, x: moe_forward(
                p, x, n_experts=E, top_k=2, capacity_factor=float(E),
                mlp_type="swiglu", impl="a2a", constrain=constrain))(p, x)
        print(float(jnp.abs(y0 - y1).max()))
    """)
    assert float(line) < 5e-4


def test_fused_stats_match_fresh_reference():
    """The 2-collective packed statistics bundle (solver_stats_prev, the
    sharded hot path) must agree with the straightforward fresh-rho
    implementation on arbitrary mid-optimization states — same rho,
    violator count, max violation, and MVP gap — when fed the same rho."""
    import numpy as np
    from repro.core import SlabSpec, engine, rbf

    spec = SlabSpec(nu1=0.4, nu2=0.08, eps=0.5, kernel=rbf(gamma=0.7))
    m = 160
    hi, lo = spec.upper(m), spec.lower(m)
    rng = np.random.default_rng(0)
    for trial in range(5):
        # arbitrary in-box gamma (some coordinates pinned to a bound) and
        # an unrelated score vector — a mid-optimization snapshot
        gamma = jnp.asarray(rng.uniform(lo, hi, m).astype(np.float32))
        pin = rng.random(m)
        gamma = jnp.where(jnp.asarray(pin < 0.2), hi, gamma)
        gamma = jnp.where(jnp.asarray(pin > 0.85), lo, gamma)
        f = jnp.asarray(rng.standard_normal(m).astype(np.float32)) * 0.1
        kw = dict(hi=hi, lo=lo, m=m, tol=1e-4)
        zero = jnp.zeros(())
        r1, r2, nv, mv, gap = engine.solver_stats_fresh(
            gamma, f, zero, zero, True, **kw)
        r1p, r2p, nvp, mvp_, gapp = engine.solver_stats_prev(
            gamma, f, r1, r2, True, **kw)
        assert float(r1) == pytest.approx(float(r1p), abs=1e-6)
        assert float(r2) == pytest.approx(float(r2p), abs=1e-6)
        assert int(nv) == int(nvp)
        assert float(mv) == pytest.approx(float(mvp_), abs=1e-6)
        assert float(gap) == pytest.approx(float(gapp), abs=1e-6)


def test_distributed_rho_every_reaches_same_optimum():
    """rho_every>1 (stale-rho iterations through the fused mesh stats)
    must still land on the rho_every=1 optimum."""
    line = _run("""
        import jax, jax.numpy as jnp
        from repro.core import SlabSpec, rbf, dual_objective
        from repro.core.distributed_smo import solve_blocked_distributed
        from repro.data import make_toy
        X, _ = make_toy(jax.random.PRNGKey(3), 256)
        spec = SlabSpec(nu1=0.5, nu2=0.05, eps=0.5, kernel=rbf(gamma=0.5))
        K = spec.kernel.gram(X.astype(jnp.float32))
        mesh = jax.make_mesh((4,), ("data",))
        a = solve_blocked_distributed(X, spec, mesh, data_axes=("data",),
                                      P_pairs=4, tol=1e-4, rho_every=1)
        b = solve_blocked_distributed(X, spec, mesh, data_axes=("data",),
                                      P_pairs=4, tol=1e-4, rho_every=4)
        oa = float(dual_objective(a.model.gamma, K))
        ob = float(dual_objective(b.model.gamma, K))
        print(abs(oa - ob))
    """)
    assert float(line) < 1e-4
