"""Autotune-table tests: round-trip through write_table, exact/nearest
lookup, the resolution precedence (explicit kwargs > REPRO_NO_AUTOTUNE >
table > defaults), bitwise parity of tuned vs default tile configs, the
table actually steering ``repro.fit(strategy="pallas")`` launches, and
the BENCH_autotune.json schema surviving check_regression's flattener.

jit caches by (shapes, statics): a table swap does NOT retrace a shape
that already compiled, so every test here uses its own fresh (m, d) to
force a trace under the table it installed (see kernels/tiling.py).
"""
import importlib
import importlib.util
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import rbf
from repro.core.ocssvm import SlabSpec
from repro.kernels import decision, fupdate, gram
from repro.kernels.autotune import (Cell, sweep, winners_to_entries,
                                    write_table)
from repro.kernels.tiling import (DEFAULT_CONFIGS, TUNED_TABLE_PATH,
                                  TileConfig, lookup_tuned, resolve_tiles,
                                  set_tuned_table)

REPO = os.path.join(os.path.dirname(__file__), "..")


def _entry(family="fupdate", m=512, d=16, precision="f32",
           backend="interpret", block_m=128, block_n=None, block_k=128,
           depth=2, **extra):
    e = dict(family=family, m=m, d=d, precision=precision, backend=backend,
             block_m=block_m, block_n=block_n, block_k=block_k, depth=depth)
    e.update(extra)
    return e


def _table(*entries):
    return {"version": 1, "entries": list(entries)}


@pytest.fixture(autouse=True)
def _restore_table():
    yield
    set_tuned_table(None)


# ---------------------------------------------------------------------------
# table loading / validation / round-trip
# ---------------------------------------------------------------------------

def test_write_table_roundtrip(tmp_path):
    path = tmp_path / "tuned.json"
    doc = write_table([_entry(block_m=256, best_s=1e-3)], path)
    assert path.exists() and len(doc["entries"]) == 1
    set_tuned_table(str(path))
    cfg = lookup_tuned("fupdate", 512, 16, "f32", "interpret")
    assert cfg == TileConfig(256, None, 128, 2, "table-exact")


def test_write_table_merges_on_key(tmp_path):
    path = tmp_path / "tuned.json"
    write_table([_entry(block_m=256), _entry(family="gram", block_n=128)],
                path)
    # same key -> replaced; new key -> appended
    doc = write_table([_entry(block_m=512),
                       _entry(m=1024, block_m=1024)], path)
    keys = {(e["family"], e["m"]) for e in doc["entries"]}
    assert keys == {("fupdate", 512), ("gram", 512), ("fupdate", 1024)}
    by_m = {e["m"]: e for e in doc["entries"] if e["family"] == "fupdate"}
    assert by_m[512]["block_m"] == 512 and by_m[1024]["block_m"] == 1024


@pytest.mark.parametrize("bad", [
    _entry(block_m=100),                       # not a lane multiple
    _entry(family="nope"),                     # unknown family
    _entry(depth=3),                           # depth not in DEPTHS
    _entry(block_n=256),                       # fupdate has no n axis
    _entry(family="decision", block_k=128, block_n=512),  # no k axis
    {k: v for k, v in _entry().items() if k != "block_m"},  # missing key
])
def test_bad_table_rejected_eagerly(bad):
    with pytest.raises(ValueError):
        set_tuned_table(_table(bad))


def test_lookup_exact_and_nearest():
    set_tuned_table(_table(_entry(m=512, block_m=128),
                           _entry(m=4096, block_m=512)))
    assert lookup_tuned("fupdate", 512, 16, "f32",
                        "interpret").source == "table-exact"
    near = lookup_tuned("fupdate", 700, 16, "f32", "interpret")
    assert near.source == "table-nearest" and near.block_m == 128
    # beyond the log-distance cap: both entries too far -> None
    assert lookup_tuned("fupdate", 512, 512, "f32", "interpret") is None
    # other precision / backend / family never match
    assert lookup_tuned("fupdate", 512, 16, "f16", "interpret") is None
    assert lookup_tuned("fupdate", 512, 16, "f32", "tpu") is None
    assert lookup_tuned("gram", 512, 16, "f32", "interpret") is None


def test_lookup_tie_prefers_larger_m():
    # m=1024 is log-equidistant from 512 and 2048
    set_tuned_table(_table(_entry(m=512, block_m=128),
                           _entry(m=2048, block_m=512)))
    assert lookup_tuned("fupdate", 1024, 16, "f32",
                        "interpret").block_m == 512


# ---------------------------------------------------------------------------
# resolution precedence
# ---------------------------------------------------------------------------

def test_explicit_kwargs_beat_table():
    set_tuned_table(_table(_entry(block_m=1024, block_k=128)))
    cfg = resolve_tiles("fupdate", m=512, d=16, precision="f32",
                        backend="interpret", block_m=256)
    # any explicit kwarg opts out of the table entirely: the rest come
    # from DEFAULT_CONFIGS (tk=512), not the table (tk=128)
    assert cfg == TileConfig(256, None, 512, 2, "explicit")


def test_env_escape_hatch_beats_table(monkeypatch):
    set_tuned_table(_table(_entry(block_m=1024)))
    monkeypatch.setenv("REPRO_NO_AUTOTUNE", "1")
    cfg = resolve_tiles("fupdate", m=512, d=16, precision="f32",
                        backend="interpret")
    assert cfg == DEFAULT_CONFIGS["fupdate"]
    # explicit kwargs still work under the hatch
    cfg = resolve_tiles("fupdate", m=512, d=16, precision="f32",
                        backend="interpret", block_k=128)
    assert cfg.block_k == 128 and cfg.source == "explicit"


def test_table_then_default():
    set_tuned_table(_table(_entry(block_m=1024, block_k=128)))
    hit = resolve_tiles("fupdate", m=512, d=16, precision="f32",
                        backend="interpret")
    assert (hit.block_m, hit.block_k) == (1024, 128)
    miss = resolve_tiles("fupdate", m=512, d=16, precision="f32",
                        backend="tpu")
    assert miss == DEFAULT_CONFIGS["fupdate"]


# ---------------------------------------------------------------------------
# bitwise parity: tuned configs change nothing but speed
# ---------------------------------------------------------------------------

def _bitwise(a, b):
    np.testing.assert_array_equal(np.asarray(a).view(np.uint32),
                                  np.asarray(b).view(np.uint32))


def test_gram_bitwise_tuned_vs_default():
    kern = rbf(gamma=0.35)
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    X = jax.random.normal(k1, (512, 16), jnp.float32)
    Y = jax.random.normal(k2, (512, 16), jnp.float32)
    base = gram(X, Y, kern, tm=256, tn=256, tk=512, interpret=True)
    tuned = gram(X, Y, kern, tm=512, tn=512, tk=128, interpret=True)
    _bitwise(base, tuned)


def test_fupdate_bitwise_tuned_vs_default():
    kern = rbf(gamma=0.35)
    keys = jax.random.split(jax.random.PRNGKey(8), 3)
    X = jax.random.normal(keys[0], (512, 16), jnp.float32)
    delta = jax.random.normal(keys[1], (16,), jnp.float32) * 0.1
    f = jax.random.normal(keys[2], (512,), jnp.float32)
    base = fupdate(X, X[:16], delta, f, kern, tm=512, tk=512,
                   interpret=True)
    tuned = fupdate(X, X[:16], delta, f, kern, tm=512, tk=128,
                    interpret=True)
    _bitwise(base, tuned)


def test_decision_bitwise_tuned_vs_default():
    kern = rbf(gamma=0.35)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(k1, (128, 16), jnp.float32)
    t = jax.random.normal(k2, (512, 16), jnp.float32)
    gv = jnp.abs(jax.random.normal(k3, (512,), jnp.float32))
    base = decision(q, t, gv, 0.1, 0.9, kern, tm=256, tn=512,
                    interpret=True)
    tuned = decision(q, t, gv, 0.1, 0.9, kern, tm=128, tn=512,
                     interpret=True)
    _bitwise(base, tuned)


# ---------------------------------------------------------------------------
# the table steers real launches (trace-time recorder)
# ---------------------------------------------------------------------------

@pytest.fixture
def fupdate_recorder(monkeypatch):
    """Record the (tm, tk) every fupdate_pallas launch traces with."""
    # importlib: ``repro.kernels.fupdate`` the *attribute* is the jit'd
    # function (re-exported over the subpackage), so plain dotted import
    # syntax can't reach the ops module
    fops = importlib.import_module("repro.kernels.fupdate.ops")
    real = fops.fupdate_pallas
    seen = []

    def spy(*args, **kwargs):
        seen.append((kwargs["tm"], kwargs["tk"]))
        return real(*args, **kwargs)

    monkeypatch.setattr(fops, "fupdate_pallas", spy)
    return seen


def test_kernel_launch_uses_synthetic_table(fupdate_recorder):
    # fresh shape (m=832, d=24) so the trace happens under this table
    set_tuned_table(_table(_entry(m=832, d=24, block_m=128, block_k=128)))
    kern = rbf(gamma=0.5)
    X = jax.random.normal(jax.random.PRNGKey(3), (832, 24), jnp.float32)
    fupdate(X, X[:8], jnp.ones((8,)) * 0.1, jnp.zeros((832,)), kern,
            interpret=True).block_until_ready()
    assert fupdate_recorder and fupdate_recorder[-1] == (128, 128)


def test_fit_pallas_uses_committed_table(fupdate_recorder):
    # m=576, d=16: a fresh shape that nearest-matches the committed
    # (fupdate, 512, 16, f32, interpret) row. The acceptance path: the
    # table on disk -> resolve_tiles -> the engine's fupdate launches.
    want = lookup_tuned("fupdate", 576, 16, "f32", "interpret")
    assert want is not None, "committed tuned_configs.json lost its " \
        "(fupdate, 512, 16, f32, interpret) row"
    assert want.source == "table-nearest"
    X = jax.random.normal(jax.random.PRNGKey(4), (576, 16), jnp.float32)
    res = repro.fit(X, SlabSpec(), strategy="pallas", interpret=True,
                    max_outer=3)
    assert res.model.gamma.shape == (576,)
    assert fupdate_recorder
    assert all(tmtk == (want.block_m, want.block_k)
               for tmtk in fupdate_recorder)


def test_fit_pallas_rejects_contradictory_gram_mode():
    X = jnp.zeros((64, 4))
    with pytest.raises(ValueError, match="pins gram_mode"):
        repro.fit(X, SlabSpec(), strategy="pallas",
                  gram_mode="precomputed")


def test_fit_bitwise_parity_table_vs_no_autotune():
    """REPRO_NO_AUTOTUNE=1 (fixed constants) and the committed table give
    bit-identical fits. Env + jit caches are per-process state, so each
    side runs in its own subprocess."""
    code = textwrap.dedent("""
        import hashlib, jax, jax.numpy as jnp, numpy as np
        import repro
        from repro.core.ocssvm import SlabSpec
        X = jax.random.normal(jax.random.PRNGKey(11), (640, 16),
                              jnp.float32)
        r = repro.fit(X, SlabSpec(), strategy="pallas", interpret=True,
                      max_outer=25)
        m = r.model
        h = hashlib.sha256(np.asarray(m.gamma).tobytes()).hexdigest()
        print(h, float(m.rho1), float(m.rho2))
    """)
    outs = []
    for no_autotune in ("0", "1"):
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
                   JAX_PLATFORMS="cpu", REPRO_NO_AUTOTUNE=no_autotune)
        p = subprocess.run([sys.executable, "-c", code],
                           capture_output=True, text=True, env=env,
                           timeout=600)
        assert p.returncode == 0, p.stderr[-3000:]
        outs.append(p.stdout.strip().splitlines()[-1])
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# the sweep itself + the bench JSON schema
# ---------------------------------------------------------------------------

def test_sweep_smoke_and_winner_entries(tmp_path):
    cell = Cell("gram", 256, 256, 8)
    result = sweep((cell,), mode="quick", precisions=("f32",), repeats=1,
                   interpret=True)
    assert result["backend"] == "interpret" and result["winners"]
    for row in result["candidates"]:
        assert row["bound"] in ("memory", "compute")
        assert row["time_s"] > 0 and row["depth"] == 2
    # winners must survive table validation end to end
    doc = write_table(winners_to_entries(result), tmp_path / "t.json")
    set_tuned_table(str(tmp_path / "t.json"))
    assert lookup_tuned("gram", 256, 8, "f32", "interpret") is not None


def test_committed_table_is_valid_and_loaded():
    assert TUNED_TABLE_PATH.exists(), \
        "src/repro/kernels/tuned_configs.json must be committed"
    set_tuned_table(None)
    with open(TUNED_TABLE_PATH) as fh:
        doc = json.load(fh)
    set_tuned_table(doc)   # eager validation of every committed entry
    for fam in ("gram", "fupdate", "decision"):
        assert lookup_tuned(fam, 512, 16, "f32", "interpret") is not None


def test_bench_json_gates_through_check_regression(tmp_path):
    spec = importlib.util.spec_from_file_location(
        "check_regression",
        os.path.join(REPO, "benchmarks", "check_regression.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    baseline = os.path.join(REPO, "results", "BENCH_autotune.json")
    r = mod.compare_pair(baseline, baseline, tolerance=0.25,
                         min_seconds=0.0005, gate_only=r"winners\[")
    # self-compare is clean; only winner rows are gated, candidates are
    # reported below the line
    assert r["ok"] and r["checked_timings"] > 0
    # nothing outside winners[...] is ever gated
    assert all("winners[" in e["path"] or "candidates[" in e["path"]
               for e in r["below_noise_floor"])
    assert any("candidates[" in e["path"] for e in r["below_noise_floor"])
    # a dropped winner row must fail even under --gate-only
    with open(baseline) as fh:
        doc = json.load(fh)
    doc["winners"] = doc["winners"][1:]
    pruned = tmp_path / "pruned.json"
    pruned.write_text(json.dumps(doc))
    r2 = mod.compare_pair(str(pruned), baseline, tolerance=0.25,
                          min_seconds=0.0005, gate_only=r"winners\[")
    assert not r2["ok"] and r2["missing_rows"]
