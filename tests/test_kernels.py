"""Pallas kernel tests: shape/dtype sweeps, assert_allclose vs ref.py
oracles, interpret=True (CPU) execution of the same BlockSpec tiling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import linear, poly, rbf
from repro.kernels import decision, fupdate, gram
from repro.kernels.decision.ref import decision_ref
from repro.kernels.fupdate.ref import fupdate_ref
from repro.kernels.gram.ref import gram_ref

KERNELS = [linear(), rbf(gamma=0.35), poly(gamma=0.2, coef0=1.0, degree=2)]
SHAPES = [(16, 8, 3), (100, 50, 7), (256, 256, 64), (300, 130, 129),
          (512, 600, 40)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("kern", KERNELS, ids=lambda k: k.name)
@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES, ids=str)
def test_gram_matches_ref(kern, shape, dtype):
    m, n, d = shape
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    X = jax.random.normal(k1, (m, d), dtype)
    Y = jax.random.normal(k2, (n, d), dtype)
    out = gram(X, Y, kern, interpret=True)
    ref = gram_ref(X, Y, kind=kern.name, gamma=kern.gamma,
                   coef0=kern.coef0, degree=kern.degree)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               **_tol(dtype))


@pytest.mark.parametrize("kern", KERNELS, ids=lambda k: k.name)
@pytest.mark.parametrize("m,d,s", [(64, 16, 2), (200, 33, 5), (512, 128, 16),
                                   (700, 64, 2)])
@pytest.mark.parametrize("dtype", DTYPES, ids=str)
def test_fupdate_matches_ref(kern, m, d, s, dtype):
    keys = jax.random.split(jax.random.PRNGKey(1), 4)
    X = jax.random.normal(keys[0], (m, d), dtype)
    Xs = X[:s]
    delta = jax.random.normal(keys[1], (s,), jnp.float32) * 0.1
    f = jax.random.normal(keys[2], (m,), jnp.float32)
    out = fupdate(X, Xs, delta, f, kern, interpret=True)
    ref = fupdate_ref(X, Xs, delta[:, None], f[:, None], kind=kern.name,
                      gamma=kern.gamma, coef0=kern.coef0,
                      degree=kern.degree)[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               **_tol(dtype))


@pytest.mark.parametrize("kern", KERNELS, ids=lambda k: k.name)
@pytest.mark.parametrize("nq,m,d", [(32, 64, 8), (150, 333, 20),
                                    (256, 512, 128)])
def test_decision_matches_ref(kern, nq, m, d):
    keys = jax.random.split(jax.random.PRNGKey(2), 3)
    Q = jax.random.normal(keys[0], (nq, d), jnp.float32)
    T = jax.random.normal(keys[1], (m, d), jnp.float32)
    gv = jax.random.normal(keys[2], (m,), jnp.float32) * 0.05
    out = decision(Q, T, gv, 0.2, 0.8, kern, interpret=True)
    ref = decision_ref(Q, T, gv[:, None], 0.2, 0.8, kind=kern.name,
                       gamma=kern.gamma, coef0=kern.coef0,
                       degree=kern.degree)[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_gram_tiling_variants():
    """Different BlockSpec tile sizes give identical results."""
    kern = rbf(gamma=0.5)
    X = jax.random.normal(jax.random.PRNGKey(3), (300, 70), jnp.float32)
    ref = gram_ref(X, X, kind="rbf", gamma=0.5)
    for tm, tn, tk in [(128, 128, 128), (256, 512, 512), (512, 256, 256)]:
        out = gram(X, X, kern, tm=tm, tn=tn, tk=tk, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5)


def test_fupdate_zero_delta_is_identity():
    kern = linear()
    X = jax.random.normal(jax.random.PRNGKey(4), (128, 32), jnp.float32)
    f = jax.random.normal(jax.random.PRNGKey(5), (128,), jnp.float32)
    out = fupdate(X, X[:4], jnp.zeros((4,)), f, kern, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(f), atol=1e-6)
