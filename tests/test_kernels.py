"""Pallas kernel tests: shape/dtype sweeps, assert_allclose vs ref.py
oracles, interpret=True (CPU) execution of the same BlockSpec tiling, and
the mixed-precision parity matrix
{f32, bf16, f16} x {gram, fupdate, decision_packed} x {rbf, linear, poly}
(dtype-matched refs at tight tolerance; f32-truth at the documented
per-dtype tolerance; precision="f32" bit-identical to the default path)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import linear, poly, rbf
from repro.kernels import decision, fupdate, gram
from repro.kernels.decision.ops import decision_packed
from repro.kernels.decision.ref import decision_ref
from repro.kernels.fupdate.ref import fupdate_ref
from repro.kernels.gram.ref import gram_ref
from repro.kernels.precision import (PRECISIONS, round_to_tile, tile_dtype,
                                     truth_tolerance)

KERNELS = [linear(), rbf(gamma=0.35), poly(gamma=0.2, coef0=1.0, degree=2)]
SHAPES = [(16, 8, 3), (100, 50, 7), (256, 256, 64), (300, 130, 129),
          (512, 600, 40)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("kern", KERNELS, ids=lambda k: k.name)
@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES, ids=str)
def test_gram_matches_ref(kern, shape, dtype):
    m, n, d = shape
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    X = jax.random.normal(k1, (m, d), dtype)
    Y = jax.random.normal(k2, (n, d), dtype)
    out = gram(X, Y, kern, interpret=True)
    ref = gram_ref(X, Y, kind=kern.name, gamma=kern.gamma,
                   coef0=kern.coef0, degree=kern.degree)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               **_tol(dtype))


@pytest.mark.parametrize("kern", KERNELS, ids=lambda k: k.name)
@pytest.mark.parametrize("m,d,s", [(64, 16, 2), (200, 33, 5), (512, 128, 16),
                                   (700, 64, 2)])
@pytest.mark.parametrize("dtype", DTYPES, ids=str)
def test_fupdate_matches_ref(kern, m, d, s, dtype):
    keys = jax.random.split(jax.random.PRNGKey(1), 4)
    X = jax.random.normal(keys[0], (m, d), dtype)
    Xs = X[:s]
    delta = jax.random.normal(keys[1], (s,), jnp.float32) * 0.1
    f = jax.random.normal(keys[2], (m,), jnp.float32)
    out = fupdate(X, Xs, delta, f, kern, interpret=True)
    ref = fupdate_ref(X, Xs, delta[:, None], f[:, None], kind=kern.name,
                      gamma=kern.gamma, coef0=kern.coef0,
                      degree=kern.degree)[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               **_tol(dtype))


@pytest.mark.parametrize("kern", KERNELS, ids=lambda k: k.name)
@pytest.mark.parametrize("nq,m,d", [(32, 64, 8), (150, 333, 20),
                                    (256, 512, 128)])
def test_decision_matches_ref(kern, nq, m, d):
    keys = jax.random.split(jax.random.PRNGKey(2), 3)
    Q = jax.random.normal(keys[0], (nq, d), jnp.float32)
    T = jax.random.normal(keys[1], (m, d), jnp.float32)
    gv = jax.random.normal(keys[2], (m,), jnp.float32) * 0.05
    out = decision(Q, T, gv, 0.2, 0.8, kern, interpret=True)
    ref = decision_ref(Q, T, gv[:, None], 0.2, 0.8, kind=kern.name,
                       gamma=kern.gamma, coef0=kern.coef0,
                       degree=kern.degree)[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_gram_tiling_variants():
    """Different BlockSpec tile sizes give identical results."""
    kern = rbf(gamma=0.5)
    X = jax.random.normal(jax.random.PRNGKey(3), (300, 70), jnp.float32)
    ref = gram_ref(X, X, kind="rbf", gamma=0.5)
    for tm, tn, tk in [(128, 128, 128), (256, 512, 512), (512, 256, 256)]:
        out = gram(X, X, kern, tm=tm, tn=tn, tk=tk, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5)


def test_fupdate_zero_delta_is_identity():
    kern = linear()
    X = jax.random.normal(jax.random.PRNGKey(4), (128, 32), jnp.float32)
    f = jax.random.normal(jax.random.PRNGKey(5), (128,), jnp.float32)
    out = fupdate(X, X[:4], jnp.zeros((4,)), f, kern, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(f), atol=1e-6)


@pytest.mark.parametrize("kern", KERNELS, ids=lambda k: k.name)
@pytest.mark.parametrize("precision", PRECISIONS)
def test_fupdate_pad_region_contributes_exactly_zero(kern, precision):
    """fupdate internally pads the selected block to a lane multiple (and
    rows/features to tile multiples) with zeros. The padded columns carry
    delta == 0, so they must contribute EXACTLY 0 to the f-cache — even
    for RBF, where a zero-padded selected row still has a nonzero kernel
    value against every x (exp(-gamma ||x||^2)), and even in bf16/f16,
    where the norms are computed from the rounded rows (a rounded zero row
    is still exactly zero, so the norms-of-rounded-rows path cannot leak
    a nonzero product into the padded columns). Asserted bitwise: the
    same call with MANUALLY zero-padded (xsel, delta) — crossing the 128
    lane boundary, so the pad geometry actually changes — must return
    f_new bit-for-bit identical to the unpadded call. This is what makes
    ShardedGram.apply_update's per-shard fupdate safe under tile
    rounding."""
    m, d, s = 96, 17, 5          # none of them tile-aligned
    keys = jax.random.split(jax.random.PRNGKey(7), 3)
    X = jax.random.normal(keys[0], (m, d), jnp.float32)
    Xs = X[:s]
    delta = jax.random.normal(keys[1], (s,), jnp.float32) * 0.1
    f = jax.random.normal(keys[2], (m,), jnp.float32)

    out = fupdate(X, Xs, delta, f, kern, interpret=True,
                  precision=precision)
    # Push the selected block past the next lane multiple with explicit
    # zero rows / zero deltas: fupdate now pads to 256 instead of 128.
    extra = 128
    Xs_pad = jnp.concatenate([Xs, jnp.zeros((extra, d), jnp.float32)])
    delta_pad = jnp.concatenate([delta, jnp.zeros((extra,), jnp.float32)])
    out_pad = fupdate(X, Xs_pad, delta_pad, f, kern, interpret=True,
                      precision=precision)
    assert bool(jnp.all(out == out_pad)), (
        f"zero-padded selected rows perturbed f ({precision})")


# -- mixed-precision parity matrix ------------------------------------------
# Each cell checks two things: (1) the Pallas kernel matches the
# dtype-parameterized ref at near-f32 tolerance (both see identical input
# rounding, so only accumulation order differs), and (2) the low-precision
# output is within the DOCUMENTED per-dtype tolerance of f32 truth — the
# bound docs/serving.md advertises.

_MATRIX_TOL = dict(rtol=5e-4, atol=5e-4)


def _matrix_data(m=200, n=130, d=70):
    keys = jax.random.split(jax.random.PRNGKey(42), 4)
    X = jax.random.normal(keys[0], (m, d), jnp.float32)
    Y = jax.random.normal(keys[1], (n, d), jnp.float32)
    gv = jax.random.normal(keys[2], (n,), jnp.float32) * 0.05
    f = jax.random.normal(keys[3], (m,), jnp.float32)
    return X, Y, gv, f


@pytest.mark.parametrize("kern", KERNELS, ids=lambda k: k.name)
@pytest.mark.parametrize("precision", PRECISIONS)
def test_precision_matrix_gram(kern, precision):
    X, Y, _, _ = _matrix_data()
    out = gram(X, Y, kern, interpret=True, precision=precision)
    ref = gram_ref(X, Y, kind=kern.name, gamma=kern.gamma,
                   coef0=kern.coef0, degree=kern.degree,
                   precision=precision)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               **_MATRIX_TOL)
    truth = gram_ref(X, Y, kind=kern.name, gamma=kern.gamma,
                     coef0=kern.coef0, degree=kern.degree)
    np.testing.assert_allclose(np.asarray(out), np.asarray(truth),
                               **truth_tolerance(precision, truth))


@pytest.mark.parametrize("kern", KERNELS, ids=lambda k: k.name)
@pytest.mark.parametrize("precision", PRECISIONS)
def test_precision_matrix_fupdate(kern, precision):
    X, _, _, f = _matrix_data()
    Xs = X[:6]
    delta = jnp.linspace(-0.1, 0.1, 6, dtype=jnp.float32)
    out = fupdate(X, Xs, delta, f, kern, interpret=True,
                  precision=precision)
    ref = fupdate_ref(X, Xs, delta[:, None], f[:, None], kind=kern.name,
                      gamma=kern.gamma, coef0=kern.coef0,
                      degree=kern.degree, precision=precision)[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               **_MATRIX_TOL)
    truth = fupdate_ref(X, Xs, delta[:, None], f[:, None], kind=kern.name,
                        gamma=kern.gamma, coef0=kern.coef0,
                        degree=kern.degree)[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(truth),
                               **truth_tolerance(precision, truth))


def _pack_for_decision(t, gv, precision, tn=512):
    """The pack_model layout at kernel level: t in the serving dtype,
    gamma/norms f32, rows padded to tn, features to 128."""
    m, d = t.shape
    m_pad = -(-m // tn) * tn
    d_pad = -(-d // 128) * 128
    t_pad = jnp.zeros((m_pad, d_pad), jnp.float32).at[:m, :d].set(t)
    t_pad = t_pad.astype(tile_dtype(precision))
    tf = t_pad.astype(jnp.float32)
    t_norms = jnp.sum(tf * tf, axis=-1, keepdims=True)
    gamma_pad = jnp.zeros((m_pad, 1), jnp.float32).at[:m, 0].set(gv)
    return t_pad, gamma_pad, t_norms, d_pad


@pytest.mark.parametrize("kern", KERNELS, ids=lambda k: k.name)
@pytest.mark.parametrize("precision", PRECISIONS)
def test_precision_matrix_decision_packed(kern, precision):
    X, Y, gv, _ = _matrix_data()
    t_pad, gamma_pad, t_norms, d_pad = _pack_for_decision(Y, gv, precision)
    nq = 100
    q_pad = jnp.zeros((256, d_pad), jnp.float32).at[:nq, :X.shape[1]].set(
        X[:nq])
    out = decision_packed(q_pad, t_pad, gamma_pad, t_norms, 0.2, 0.8,
                          kern, interpret=True, precision=precision)[:nq]
    ref = decision_ref(X[:nq], Y, gv[:, None], 0.2, 0.8, kind=kern.name,
                       gamma=kern.gamma, coef0=kern.coef0,
                       degree=kern.degree, precision=precision)[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               **_MATRIX_TOL)
    truth = decision_ref(X[:nq], Y, gv[:, None], 0.2, 0.8, kind=kern.name,
                         gamma=kern.gamma, coef0=kern.coef0,
                         degree=kern.degree)[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(truth),
                               **truth_tolerance(precision, truth))


@pytest.mark.parametrize("kern", KERNELS, ids=lambda k: k.name)
def test_precision_f32_bit_identical(kern):
    """precision="f32" must be a no-op: bitwise-equal outputs on every
    kernel family (guards the refactor and any future default change)."""
    X, Y, gv, f = _matrix_data()
    assert bool(jnp.all(
        gram(X, Y, kern, interpret=True) ==
        gram(X, Y, kern, interpret=True, precision="f32")))
    delta = jnp.linspace(-0.1, 0.1, 6, dtype=jnp.float32)
    assert bool(jnp.all(
        fupdate(X, X[:6], delta, f, kern, interpret=True) ==
        fupdate(X, X[:6], delta, f, kern, interpret=True,
                precision="f32")))
    assert bool(jnp.all(
        decision(X, Y, gv, 0.2, 0.8, kern, interpret=True) ==
        decision(X, Y, gv, 0.2, 0.8, kern, interpret=True,
                 precision="f32")))


def test_precision_rejects_unknown():
    X, Y, _, _ = _matrix_data(m=16, n=16, d=8)
    with pytest.raises(ValueError):
        gram(X, Y, KERNELS[0], interpret=True, precision="tf32")
    with pytest.raises(ValueError):
        round_to_tile(X, "int8")


def test_round_to_tile_halves_mantissa_not_values():
    """bf16/f16 round-trips quantize; f32 is the identity."""
    x = jnp.asarray([1.0, 1.0 + 2.0 ** -20, -3.14159], jnp.float32)
    assert bool(jnp.all(round_to_tile(x, "f32") == x))
    xb = round_to_tile(x, "bf16")
    assert xb[1] == xb[0]                      # 2^-20 is below bf16 ulp
    assert float(jnp.max(jnp.abs(xb - x))) <= 2.0 ** -8 * 3.2
    xh = round_to_tile(x, "f16")
    assert float(jnp.max(jnp.abs(xh - x))) <= 2.0 ** -11 * 3.2
