"""Property-based tests (hypothesis) on the solver invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

pytestmark = pytest.mark.slow

from repro.core import (SlabSpec, feasible_init, linear, rbf,  # noqa: E402
                        solve_blocked)
from repro.core.qp_baseline import project_box_hyperplane  # noqa: E402

jax.config.update("jax_platform_name", "cpu")


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(min_value=8, max_value=120),
    nu1=st.floats(min_value=0.15, max_value=0.9),
    nu2=st.floats(min_value=0.02, max_value=0.5),
    eps=st.floats(min_value=0.1, max_value=0.9),
)
def test_feasible_init_property(m, nu1, nu2, eps):
    spec = SlabSpec(nu1=nu1, nu2=nu2, eps=eps, kernel=linear())
    # The box must be able to hold the mass: m * hi >= 1 - eps.
    if m * spec.upper(m) < spec.total():
        return
    g = feasible_init(m, spec)
    assert abs(float(jnp.sum(g)) - spec.total()) < 1e-4 * max(1, m)
    assert float(jnp.max(g)) <= spec.upper(m) + 1e-7
    assert float(jnp.min(g)) >= spec.lower(m) - 1e-7


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    n=st.integers(min_value=2, max_value=64),
    lo=st.floats(min_value=-2.0, max_value=-0.01),
    hi=st.floats(min_value=0.01, max_value=2.0),
)
def test_projection_property(seed, n, lo, hi):
    """Projection lands in the set and is idempotent."""
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.standard_normal(n).astype(np.float32)) * 3
    total = float(np.clip(rng.uniform(n * lo, n * hi), n * lo, n * hi))
    p = project_box_hyperplane(v, lo, hi, total)
    assert float(jnp.min(p)) >= lo - 1e-4
    assert float(jnp.max(p)) <= hi + 1e-4
    assert abs(float(jnp.sum(p)) - total) < 1e-2 * max(1.0, abs(total))
    p2 = project_box_hyperplane(p, lo, hi, total)
    np.testing.assert_allclose(np.asarray(p), np.asarray(p2), atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_solver_invariants_random_data(seed):
    rng = np.random.default_rng(seed)
    m, d = 64, 5
    X = jnp.asarray(rng.standard_normal((m, d)).astype(np.float32))
    spec = SlabSpec(nu1=0.5, nu2=0.1, eps=0.5, kernel=rbf(gamma=0.7))
    res = solve_blocked(X, spec, P=4, tol=1e-3, max_outer=5000)
    g = res.model.gamma
    assert abs(float(jnp.sum(g)) - spec.total()) < 1e-3
    assert float(jnp.max(g)) <= spec.upper(m) + 1e-6
    assert float(jnp.min(g)) >= spec.lower(m) - 1e-6
    # scores consistent with the f maintained internally
    s = res.model.raw_scores(X)
    assert np.all(np.isfinite(np.asarray(s)))


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=9999),
    m=st.integers(min_value=16, max_value=96),
)
def test_kkt_violation_zero_at_qp_optimum(seed, m):
    """The 5-case KKT violation vanishes at the QP optimum."""
    import numpy as np
    from repro.core import solve_qp
    from repro.core.kkt import violation
    from repro.core.ocssvm import recover_rhos
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.standard_normal((m, 4)).astype(np.float32))
    spec = SlabSpec(nu1=0.5, nu2=0.1, eps=0.5, kernel=rbf(gamma=0.5))
    qp = solve_qp(X, spec, max_iters=30_000, tol=1e-12)
    f = spec.kernel.gram(X) @ qp.gamma
    r1, r2 = recover_rhos(qp.gamma, f, spec)
    v = violation(qp.gamma, f, r1, r2, spec)
    assert float(jnp.max(v)) < 5e-3


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=9999))
def test_decision_sign_consistency(seed):
    """predict == sign(decision_function) everywhere, incl. boundaries."""
    import numpy as np
    from repro.core import solve_blocked
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.standard_normal((64, 3)).astype(np.float32))
    spec = SlabSpec(nu1=0.4, nu2=0.1, eps=0.5, kernel=rbf(gamma=1.0))
    res = solve_blocked(X, spec, P=4, tol=1e-3)
    Q = jnp.asarray(rng.standard_normal((32, 3)).astype(np.float32))
    dec = np.asarray(res.model.decision_function(Q))
    pred = np.asarray(res.model.predict(Q))
    assert ((dec >= 0) == (pred == 1)).all()
