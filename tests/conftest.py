"""Shared test plumbing.

``run_forced_devices`` is the one subprocess harness for everything that
needs >1 jax device: jax pins the device count at first import, and the
main pytest process must stay single-device, so multi-device cells run
their payload in a child python with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` and report back as
a JSON line on stdout.
"""
import json
import os
import subprocess
import sys
import textwrap

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_forced_devices(code: str, devices: int = 4,
                       timeout: int = 900) -> dict:
    """Run ``code`` in a child python with ``devices`` forced host
    devices; returns the JSON object printed on its last stdout line."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = REPO_SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])
