"""Per-arch smoke tests: reduced config, one forward + one train step on
CPU, asserting output shapes and finiteness; decode-vs-full consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.data.synthetic import SyntheticPipeline
from repro.models.transformer import forward, init_cache, init_params
from repro.train.train_step import init_train_state, make_train_step

ARCH_IDS = sorted(ARCHS)


def _inputs(cfg, key, B, S):
    kwargs = {}
    if cfg.frontend == "audio":
        kwargs["embeds"] = jax.random.normal(key, (B, S, cfg.d_model),
                                             jnp.float32)
    else:
        nv = cfg.n_frontend_tokens
        kwargs["tokens"] = jax.random.randint(key, (B, S - nv), 0,
                                              cfg.vocab_size)
        if cfg.frontend == "vision":
            kwargs["vision_embeds"] = jax.random.normal(
                key, (B, nv, cfg.d_model), jnp.float32)
    return kwargs


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = ARCHS[arch].reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, S = 2, 16
    logits, _, aux = forward(params, cfg, **_inputs(cfg, key, B, S))
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = ARCHS[arch].reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    state = init_train_state(cfg, params)
    step = jax.jit(make_train_step(cfg, peak_lr=1e-3, warmup_steps=2,
                                   total_steps=10))
    pipe = SyntheticPipeline(cfg, batch=2, seq_len=16, seed=0)
    state, metrics = step(state, pipe.next_batch())
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state.step) == 1
    # lr is 0 at warmup step 0 — take a second step before checking that
    # params moved
    state, metrics = step(state, pipe.next_batch())
    before = jax.tree.leaves(params)[0]
    after = jax.tree.leaves(state.params)[0]
    assert not np.allclose(np.asarray(before), np.asarray(after))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full_forward(arch):
    cfg = ARCHS[arch].reduced()
    if cfg.n_experts:
        # avoid capacity-drop nondeterminism between batch shapes
        cfg = dataclasses.replace(cfg,
                                  capacity_factor=float(cfg.n_experts))
    key = jax.random.PRNGKey(42)
    params = init_params(cfg, key)
    B, S = 2, 20
    if cfg.frontend == "audio":
        embeds = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
        full, _, _ = forward(params, cfg, embeds=embeds)
        cache = init_cache(cfg, B, S, dtype=jnp.float32)
        _, cache, _ = forward(params, cfg, embeds=embeds[:, :S - 1],
                              cache=cache)
        last, _, _ = forward(params, cfg, embeds=embeds[:, S - 1:S],
                             cache=cache)
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        kwargs = {}
        if cfg.frontend == "vision":
            kwargs["vision_embeds"] = jax.random.normal(
                key, (B, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
        full, _, _ = forward(params, cfg, tokens=toks, **kwargs)
        cache = init_cache(cfg, B, S + cfg.n_frontend_tokens,
                           dtype=jnp.float32)
        _, cache, _ = forward(params, cfg, tokens=toks[:, :S - 1],
                              cache=cache, **kwargs)
        last, _, _ = forward(params, cfg, tokens=toks[:, S - 1:S],
                             cache=cache)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(full[:, -1]), atol=5e-4, rtol=1e-3)


def test_sliding_window_masks_history():
    """SWA with window w must ignore tokens beyond w."""
    cfg = dataclasses.replace(ARCHS["mixtral-8x22b"].reduced(), window=4,
                              n_experts=0,
                              layer_pattern=ARCHS["mixtral-8x22b"]
                              .reduced().layer_pattern)
    # make it dense (no experts) for simplicity
    from repro.configs.base import LayerSpec
    cfg = dataclasses.replace(cfg, layer_pattern=(LayerSpec("swa"),),
                              n_layers=2)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (1, 12), 0, cfg.vocab_size)
    logits1, _, _ = forward(params, cfg, tokens=toks)
    # perturb a token far outside the window of the last position
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab_size)
    logits2, _, _ = forward(params, cfg, tokens=toks2)
    np.testing.assert_allclose(np.asarray(logits1[0, -1]),
                               np.asarray(logits2[0, -1]), atol=1e-5)
    # but a token inside the window does change the output
    toks3 = toks.at[0, 10].set((toks[0, 10] + 1) % cfg.vocab_size)
    logits3, _, _ = forward(params, cfg, tokens=toks3)
    assert not np.allclose(np.asarray(logits1[0, -1]),
                           np.asarray(logits3[0, -1]), atol=1e-5)


def test_param_counts_match_published_sizes():
    expected = {
        "llama3.2-3b": (3.0e9, 4.2e9),
        "minitron-8b": (7.2e9, 8.6e9),
        "gemma3-27b": (26e9, 30e9),
        "deepseek-coder-33b": (31e9, 35e9),
        "musicgen-large": (1.9e9, 3.3e9),
        "arctic-480b": (450e9, 500e9),
        "mixtral-8x22b": (135e9, 145e9),
        "jamba-1.5-large-398b": (380e9, 410e9),
        "rwkv6-7b": (6.5e9, 7.9e9),
        "internvl2-26b": (18e9, 22e9),   # LLM backbone (ViT is stubbed)
    }
    for arch, (lo, hi) in expected.items():
        n = ARCHS[arch].param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.1f}B outside [{lo}, {hi}]"
