"""Checkpoint manager: roundtrip, atomicity, corruption detection, async,
elastic restore, SMO solver-state checkpointing."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager
from repro.checkpoint.manager import AsyncCheckpointer
from repro.configs import ARCHS
from repro.models.transformer import init_params
from repro.train.train_step import init_train_state


def _state(arch="llama3.2-3b"):
    cfg = ARCHS[arch].reduced()
    return init_train_state(cfg, init_params(cfg, jax.random.PRNGKey(0)))


def test_roundtrip(tmp_path):
    state = _state()
    manager.save(str(tmp_path), 7, state)
    restored, step = manager.restore_latest(str(tmp_path), state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_gc(tmp_path):
    state = {"x": jnp.arange(4)}
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 5, 9):
        ck.save(s, state)
    ck.wait()
    assert manager.latest_step(str(tmp_path)) == 9
    kept = sorted(os.listdir(tmp_path))
    assert len([d for d in kept if d.startswith("step_")]) == 2


def test_corruption_detected(tmp_path):
    state = {"x": jnp.arange(10)}
    path = manager.save(str(tmp_path), 3, state)
    npz = os.path.join(path, "arrays.npz")
    with open(npz, "r+b") as f:
        f.seek(30)
        f.write(b"\x00\x01\x02")
    with pytest.raises(IOError, match="corrupt"):
        manager.restore(str(tmp_path), 3, state)


def test_extra_metadata_roundtrip(tmp_path):
    state = {"x": jnp.zeros(3)}
    manager.save(str(tmp_path), 11, state, extra={"data": {"seed": 1,
                                                           "step": 42}})
    with open(os.path.join(tmp_path, "step_000000011",
                           "manifest.json")) as f:
        extra = json.load(f)["extra"]
    assert extra["data"]["step"] == 42


def test_smo_state_checkpointable(tmp_path):
    """Mid-solve SMO state (gamma, f) is an ordinary pytree."""
    from repro.core import SlabSpec, rbf, solve_blocked
    from repro.data import make_toy
    X, _ = make_toy(jax.random.PRNGKey(0), 64)
    spec = SlabSpec(nu1=0.5, nu2=0.1, eps=0.5, kernel=rbf(gamma=0.5))
    res = solve_blocked(X, spec, P=4, tol=1e-3, max_outer=3)
    tree = {"gamma": res.model.gamma, "rho1": res.model.rho1,
            "rho2": res.model.rho2}
    manager.save(str(tmp_path), 1, tree)
    restored, _ = manager.restore_latest(str(tmp_path), tree)
    np.testing.assert_array_equal(np.asarray(tree["gamma"]),
                                  np.asarray(restored["gamma"]))
    # warm-restart from the checkpoint converges
    res2 = solve_blocked(X, spec, P=4, tol=1e-3,
                         gamma0=restored["gamma"])
    assert bool(res2.converged)


def test_elastic_reshard_roundtrip(tmp_path):
    """Restore a checkpoint onto a different (1-device) mesh layout."""
    from repro.checkpoint.reshard import reshard_checkpoint
    from repro.launch.mesh import make_test_mesh
    state = _state()
    manager.save(str(tmp_path), 2, state.params)
    mesh = make_test_mesh((1, 1), ("data", "model"))
    restored = reshard_checkpoint(str(tmp_path), 2, state.params, mesh)
    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
