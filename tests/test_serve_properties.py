"""Property/stress layer over the serving service + admission windows.

Hypothesis drives random request streams — mixed sizes (including
groups beyond ``max_batch``), interleaved submit/flush, multiple models
— against models packed directly from hand-built ``OCSSVMModel``s (no
solver in the loop, so hundreds of examples stay cheap) and asserts the
two load-bearing invariants of the micro-batching layer:

* **scatter-back**: every handle gets exactly the scores its request
  would get from a direct ``BatchScorer.score`` call — coalescing,
  chunking, and routing must be invisible to the caller;
* **accounting**: per-bucket ``BucketStats`` add up — live rows scored
  equal rows submitted, requests served equal handles issued, and a
  zero-row submit is rejected before it can poison a flush.

Marked ``slow``: CI runs these in their own matrix cell.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.core import OCSSVMModel, SlabSpec, rbf  # noqa: E402
from repro.serve import (AdmissionController, ScoringService,  # noqa: E402
                         pack_model)

pytestmark = pytest.mark.slow

D = 3
MAX_BATCH = 64          # small cap so "oversized group" is cheap to hit


def _packed(seed: int, rho1: float = 0.2, rho2: float = 0.9,
            n_rows: int = 24):
    """A ServingModel straight from a hand-built model: no fit needed."""
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(n_rows, D)), jnp.float32)
    gamma = jnp.asarray(rng.uniform(-0.5, 1.0, size=(n_rows,)), jnp.float32)
    spec = SlabSpec(nu1=0.5, nu2=0.05, eps=0.5,
                    kernel=rbf(gamma=0.5 + 0.25 * (seed % 3)))
    model = OCSSVMModel(gamma=gamma, rho1=jnp.float32(rho1),
                       rho2=jnp.float32(rho2), X=X, spec=spec)
    return pack_model(model)


MODELS = {"m0": _packed(0), "m1": _packed(1, rho1=-0.3, rho2=0.4)}


class _StaticRegistry:
    """Registry stub for the controller: fixed packed models + quotas
    (the controller only needs ``get`` and ``quota``)."""

    def __init__(self, models, quotas=None):
        self._models = models
        self._quotas = quotas or {}

    def get(self, name):
        return self._models[name]

    def quota(self, name):
        return self._quotas.get(name)


def _request(seed: int, n: int) -> np.ndarray:
    return np.random.default_rng(seed).normal(size=(n, D)) \
        .astype(np.float32)


# One stream op: (size, flush_after?) — sizes beyond MAX_BATCH exercise
# the oversized-group chunking path.
OPS = st.lists(
    st.tuples(st.integers(min_value=1, max_value=3 * MAX_BATCH + 5),
              st.booleans()),
    min_size=1, max_size=8)


@settings(max_examples=12, deadline=None)
@given(ops=OPS)
def test_stream_scatter_back_matches_direct_score(ops):
    """Interleaved submit/flush, mixed sizes: every handle's rows equal a
    direct BatchScorer.score of its own request."""
    sm = MODELS["m0"]
    svc = ScoringService(sm.scorer(), max_batch=MAX_BATCH)
    handles = []
    for i, (n, flush_now) in enumerate(ops):
        q = _request(1000 + i, n)
        handles.append((q, svc.submit(q)))
        if flush_now:
            svc.flush()
    svc.flush()
    assert not svc._queue
    for q, h in handles:
        assert h.done
        np.testing.assert_allclose(np.asarray(h.result()),
                                   np.asarray(sm.scorer().score(q)),
                                   rtol=1e-5, atol=1e-6)


@settings(max_examples=12, deadline=None)
@given(ops=OPS)
def test_stream_stats_invariants(ops):
    """Per-bucket counters add up exactly: live rows == submitted rows,
    requests == handles, regardless of grouping/chunking."""
    svc = ScoringService(MODELS["m0"].scorer(), max_batch=MAX_BATCH)
    handles = []
    for i, (n, flush_now) in enumerate(ops):
        handles.append(svc.submit(_request(2000 + i, n)))
        if flush_now:
            svc.flush()
    svc.flush()
    total_rows = sum(n for n, _ in ops)
    assert sum(s.queries for s in svc.stats.values()) == total_rows
    assert sum(s.requests for s in svc.stats.values()) == len(handles)
    assert sum(h.n for h in handles) == total_rows
    assert all(h.done for h in handles)
    # every recorded launch was a real one
    assert all(s.batches >= 1 for s in svc.stats.values())


@settings(max_examples=10, deadline=None)
@given(ops=st.lists(
    st.tuples(st.sampled_from(sorted(MODELS)),
              st.integers(min_value=1, max_value=2 * MAX_BATCH),
              st.booleans()),
    min_size=1, max_size=8))
def test_multi_model_admission_routes_every_request(ops):
    """Random multi-model streams through the admission controller:
    results always come from the request's own model, and per-model
    accounting matches what was admitted."""
    ctrl = AdmissionController(_StaticRegistry(MODELS),
                               max_batch=MAX_BATCH)
    handles = []
    for i, (name, n, poll_now) in enumerate(ops):
        q = _request(3000 + i, n)
        handles.append((name, q, ctrl.submit(name, q)))
        if poll_now:
            ctrl.poll()                 # deadline-less: a no-op window scan
    ctrl.drain()
    for name, q, h in handles:
        assert h.done
        np.testing.assert_allclose(
            np.asarray(h.result()),
            np.asarray(MODELS[name].scorer().score(q)),
            rtol=1e-5, atol=1e-6)
    for name in MODELS:
        submitted = sum(n for m, n, _ in ops if m == name)
        svc = ctrl._services.get(name)
        served = (sum(s.queries for s in svc.stats.values())
                  if svc is not None else 0)
        assert served == submitted
        assert ctrl.queued_rows(name) == 0


def test_zero_row_submit_rejected_everywhere():
    """A zero-row request fails fast at the admission edge — service and
    controller both — and leaves no queue residue behind."""
    svc = ScoringService(MODELS["m0"].scorer(), max_batch=MAX_BATCH)
    with pytest.raises(ValueError):
        svc.submit(np.zeros((0, D), np.float32))
    assert not svc._queue and svc.flush() == 0

    ctrl = AdmissionController(_StaticRegistry(MODELS),
                               max_batch=MAX_BATCH)
    with pytest.raises(ValueError):
        ctrl.submit("m0", np.zeros((0, D), np.float32))
    assert ctrl.queued_rows("m0") == 0 and ctrl.drain() == 0
