"""Streaming warm-start layer: artifact, fit_update, drift-gated refresh.

Covers the incremental-fit path end to end:

* warm-vs-cold parity matrix — {rbf, linear} x {f32, bf16} x {blocked,
  pallas, sharded}: a warm-started re-fit must land on the cold fit's
  optimum within the documented precision tolerance (the sharded cells
  run under forced host devices in a subprocess);
* the ISSUE acceptance bound — ``fit_update`` on a 5% appended-rows
  delta converges in <= 25% of the cold iteration count, read from the
  engine's own ``SMOResult.iters``;
* drift gating — an in-distribution append refreshes warm, a shifted
  append demonstrably forces the cold refit (``refresh_modes``);
* ``ExtendableFingerprint`` parity with the full re-hash, and its
  refusal to extend when only a full re-hash can be exact;
* registry refresh preserving per-model quota and the admission layer's
  window/latency state, on a manual clock;
* ``SolverArtifact`` checkpoint round-trip feeding ``fit_update``;
* provider-level ``append_rows`` / ``expire_rows`` parity against a
  from-scratch provider.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro
from conftest import run_forced_devices
from repro.core import SlabSpec, engine, linear, rbf
from repro.core.ocssvm import dual_objective_matfree
from repro.data import make_toy
from repro.kernels.precision import truth_tolerance
from repro.serve import (AdmissionController, BucketStats,
                         ExtendableFingerprint, ModelRegistry,
                         fingerprint_array, score_drift)

KERNELS = {"rbf": rbf(gamma=0.5), "linear": linear()}
M_PREV, N_APP, N_EXP = 96, 12, 6


def _spec(kernel_name):
    return SlabSpec(nu1=0.5, nu2=0.05, eps=0.5,
                    kernel=KERNELS[kernel_name])


def _stream(seed=5, m=M_PREV, n_app=N_APP, n_exp=N_EXP):
    """(X_prev, X_new): drop the first n_exp rows, append n_app fresh."""
    X = np.asarray(make_toy(jax.random.PRNGKey(seed), m + n_app)[0],
                   np.float32)
    X_prev = X[:m]
    X_new = np.concatenate([X_prev[n_exp:], X[m:]])
    return X_prev, X_new


def _objective(res, X, spec):
    return float(dual_objective_matfree(
        res.model.gamma, jnp.asarray(X, jnp.float32), spec.kernel))


# -- warm vs cold parity matrix ---------------------------------------------

@pytest.mark.parametrize("strategy", ["blocked", "pallas"])
@pytest.mark.parametrize("precision", ["f32", "bf16"])
@pytest.mark.parametrize("kernel_name", ["rbf", "linear"])
def test_warm_cold_parity_matrix(kernel_name, precision, strategy):
    spec = _spec(kernel_name)
    X_prev, X_new = _stream()
    prev = repro.fit(X_prev, spec, strategy=strategy, precision=precision,
                     tol=1e-4)
    art = engine.artifact_from_result(prev, precision=precision)

    cold = repro.fit(X_new, spec, strategy=strategy, precision=precision,
                     tol=1e-4)
    stats = {}
    warm = repro.fit_update(art, X_new, strategy=strategy, tol=1e-4,
                            stats_out=stats)
    assert stats["mode"] == "warm"
    assert stats["n_fresh"] == N_APP and stats["n_expired"] == N_EXP
    assert stats["n_overlap"] == M_PREV - N_EXP

    obj_cold = _objective(cold, X_new, spec)
    obj_warm = _objective(warm, X_new, spec)
    np.testing.assert_allclose(obj_warm, obj_cold,
                               **truth_tolerance(precision, obj_cold))
    # the slab the two fits carve must agree on fresh queries
    q = np.asarray(make_toy(jax.random.PRNGKey(9), 32)[0], np.float32)
    sc = np.asarray(cold.model.decision_function(q))
    sw = np.asarray(warm.model.decision_function(q))
    np.testing.assert_allclose(sw, sc, **truth_tolerance(precision, sc))


def test_warm_cold_parity_sharded():
    """The sharded cells of the matrix: {rbf, linear} x {f32, bf16}
    under 4 forced host devices, warm seeded from a local blocked fit."""
    out = run_forced_devices("""
        import json
        import numpy as np, jax, jax.numpy as jnp
        import repro
        from repro.core import SlabSpec, engine, linear, rbf
        from repro.core.ocssvm import dual_objective_matfree
        from repro.data import make_toy

        M, APP, EXP = 64, 8, 4
        X = np.asarray(make_toy(jax.random.PRNGKey(5), M + APP)[0],
                       np.float32)
        X_prev, X_new = X[:M], np.concatenate([X[EXP:M], X[M:]])
        cells = {}
        for kname, kern in (("rbf", rbf(gamma=0.5)), ("linear", linear())):
            spec = SlabSpec(nu1=0.5, nu2=0.05, eps=0.5, kernel=kern)
            for prec in ("f32", "bf16"):
                prev = repro.fit(X_prev, spec, strategy="blocked",
                                 precision=prec, tol=1e-4)
                art = engine.artifact_from_result(prev, precision=prec)
                cold = repro.fit(X_new, spec, strategy="sharded",
                                 precision=prec, tol=1e-4)
                stats = {}
                warm = repro.fit_update(art, X_new, strategy="sharded",
                                        tol=1e-4, stats_out=stats)
                obj = lambda r: float(dual_objective_matfree(
                    r.model.gamma, jnp.asarray(X_new), kern))
                cells[f"{kname}-{prec}"] = {
                    "cold": obj(cold), "warm": obj(warm),
                    "mode": stats["mode"]}
        print(json.dumps({"devices": jax.device_count(), "cells": cells}))
    """, devices=4)
    assert out["devices"] == 4
    for name, cell in out["cells"].items():
        assert cell["mode"] == "warm", name
        prec = name.split("-")[1]
        np.testing.assert_allclose(
            cell["warm"], cell["cold"],
            err_msg=name, **truth_tolerance(prec, cell["cold"]))


# -- the acceptance bound: 5% delta in <= 25% of cold iterations ------------

def test_fit_update_5pct_delta_quarter_iters():
    M, APP = 1000, 50                        # 5% appended-rows delta
    spec = _spec("rbf")
    X = np.asarray(make_toy(jax.random.PRNGKey(5), M + APP)[0], np.float32)
    X_prev, X_new = X[:M], X                 # pure append, no expiry

    prev = repro.fit(X_prev, spec, strategy="blocked", tol=1e-4)
    art = engine.artifact_from_result(prev)
    cold = repro.fit(X_new, spec, strategy="blocked", tol=1e-4)

    stats = {}
    warm = repro.fit_update(art, X_new, strategy="blocked", tol=1e-4,
                            stats_out=stats)
    assert stats["mode"] == "warm"
    assert warm.converged and cold.converged
    ratio = int(warm.iters) / int(cold.iters)
    assert ratio <= 0.25, (
        f"warm {int(warm.iters)} vs cold {int(cold.iters)} iters "
        f"(ratio {ratio:.2f} > 0.25)")
    obj_cold = _objective(cold, X_new, spec)
    np.testing.assert_allclose(_objective(warm, X_new, spec), obj_cold,
                               **truth_tolerance("f32", obj_cold))


def test_fit_update_low_overlap_falls_back_cold():
    spec = _spec("rbf")
    X_prev, _ = _stream(seed=5)
    X_other = np.asarray(make_toy(jax.random.PRNGKey(77), M_PREV)[0],
                         np.float32)
    prev = repro.fit(X_prev, spec, strategy="blocked", tol=1e-3)
    stats = {}
    res = repro.fit_update(engine.artifact_from_result(prev), X_other,
                           strategy="blocked", tol=1e-3, stats_out=stats)
    assert stats["mode"] == "cold" and stats["n_overlap"] == 0
    assert res.converged


# -- drift gating through the registry --------------------------------------

SPEC = SlabSpec(nu1=0.5, nu2=0.05, eps=0.5, kernel=rbf(gamma=0.5))
FIT_KW = dict(tol=1e-3, strategy="blocked")


def _inband_append(X_prev, n):
    """Fresh rows guaranteed in-distribution: jittered training rows
    (the jitter keeps their content hashes fresh, the distribution not —
    the toy generator's tail can run anomaly-heavy, which at n=12 is a
    legitimate drift signal, not a flake to paper over)."""
    rng = np.random.default_rng(0)
    return np.asarray(
        X_prev[:n] + rng.normal(0, 1e-3, (n, X_prev.shape[1])), np.float32)


def test_refresh_routes_warm_by_default_and_drift_forces_refit():
    X_prev, _ = _stream(seed=5)
    app = _inband_append(X_prev, N_APP)
    reg = ModelRegistry()
    reg.register("a", X_prev, SPEC, **FIT_KW)
    sm1 = reg.get("a")
    assert sm1.artifact is not None and sm1.artifact.m == M_PREV

    # in-distribution append: warm delta-solve through the cache
    sm2 = reg.refresh("a", append=app)
    st = reg.refresh_stats("a")
    assert sm2 is not sm1
    assert st["modes"] == {"warm": 1, "cold": 0}
    assert st["last_drift"] is not None and not st["last_drift"].drifted
    assert st["last_warm"]["mode"] == "warm"
    assert st["last_warm"]["n_fresh"] == N_APP

    # adversarial cell: a shifted append must trip the detector and
    # refit cold — warm-starting from the wrong distribution is the
    # failure mode the gate exists for
    shifted = np.asarray(app + 5.0, np.float32)
    sm3 = reg.refresh("a", append=shifted)
    st = reg.refresh_stats("a")
    assert sm3 is not sm2
    assert st["modes"]["cold"] == 1
    assert st["last_drift"].drifted
    assert st["last_drift"].statistic > st["last_drift"].threshold

    # the detector's raw verdicts, straight from the artifact
    assert not score_drift(sm1.artifact, X_prev).drifted
    assert score_drift(sm1.artifact, X_prev + 5.0).drifted


def test_refresh_mode_forced_and_validated():
    X_prev, X_new = _stream(seed=5)
    reg = ModelRegistry()
    reg.register("a", X_prev, SPEC, **FIT_KW)
    reg.get("a")
    reg.refresh("a", mode="cold")
    reg.refresh("a", mode="warm")
    assert reg.refresh_stats("a")["modes"] == {"warm": 1, "cold": 1}
    with pytest.raises(ValueError):
        reg.refresh("a", mode="tepid")
    with pytest.raises(ValueError):
        reg.refresh("a", append=X_new[:2], X=X_new)


# -- extendable fingerprint --------------------------------------------------

def test_extendable_fingerprint_matches_full_rehash():
    X = np.asarray(make_toy(jax.random.PRNGKey(3), 64)[0], np.float32)
    fp = ExtendableFingerprint(X[:48])
    assert fp.key == fingerprint_array(X[:48])
    ext = fp.extend(X[48:])
    assert ext is not None
    assert ext.key == fingerprint_array(X)          # O(dm) == full O(m)
    # chaining keeps parity
    more = np.asarray(make_toy(jax.random.PRNGKey(4), 8)[0], np.float32)
    assert ext.extend(more).key == fingerprint_array(
        np.concatenate([X, more]))


def test_extendable_fingerprint_refuses_when_rehash_required(monkeypatch):
    from repro.serve import model_cache
    X = np.asarray(make_toy(jax.random.PRNGKey(3), 64)[0], np.float32)
    fp = ExtendableFingerprint(X)
    # dtype / width changes break the byte-prefix property
    assert fp.extend(X[:4].astype(np.float64)) is None
    assert fp.extend(np.zeros((2, X.shape[1] + 1), np.float32)) is None
    # above the sample budget fingerprint_array strides — no prefix
    monkeypatch.setattr(model_cache, "_HASH_SAMPLE_BYTES", X.nbytes - 1)
    sampled = ExtendableFingerprint(X)
    assert sampled.key == model_cache.fingerprint_array(X)
    assert sampled.extend(X[:4]) is None
    # an extension that would cross the budget refuses too
    monkeypatch.setattr(model_cache, "_HASH_SAMPLE_BYTES", X.nbytes + 1)
    small = ExtendableFingerprint(X)
    assert small.extend(X[:4]) is None


def test_refresh_append_rekeys_in_delta_only(monkeypatch):
    """After the first append the registry re-keys through the cached
    fingerprint: fingerprint_array (the full re-hash) must not run."""
    from repro.serve import registry as registry_mod
    X_prev, X_new = _stream(seed=5)
    app = X_new[M_PREV - N_EXP:]
    reg = ModelRegistry()
    reg.register("a", X_prev[:M_PREV - N_EXP], SPEC, **FIT_KW)
    reg.get("a")
    reg.refresh("a", append=app[:6])        # first append: builds the fp

    def boom(X):
        raise AssertionError("full re-hash on the delta path")

    monkeypatch.setattr(registry_mod.ExtendableFingerprint, "__init__",
                        lambda self, X: boom(X))
    sm = reg.refresh("a", append=app[6:])   # O(dm) keying only
    expect = np.concatenate(
        [X_prev[:M_PREV - N_EXP], app[:6], app[6:]])
    assert reg.recipe("a").X.shape == expect.shape
    assert reg.get("a") is sm


# -- refresh preserves quota + admission state (fake clock) ------------------

class ManualClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def test_refresh_preserves_quota_and_admission_state():
    X_prev, X_new = _stream(seed=5)
    app = X_new[M_PREV - N_EXP:]
    reg = ModelRegistry()
    reg.register("a", X_prev, SPEC, quota=100, **FIT_KW)
    clock = ManualClock()
    ctrl = AdmissionController(reg, clock=clock, max_batch=128)
    svc1 = ctrl.service("a")
    # a deterministic latency observation the deadline policy relies on
    svc1.stats.setdefault(64, BucketStats()).record(64, 1, 0.25)
    est_before = ctrl.estimate_latency_s("a", 30)
    assert est_before == pytest.approx(0.25)
    # an open window mid-refresh
    h = ctrl.submit("a", np.asarray(X_prev[4:12]))
    ver_before = reg.version("a")

    reg.refresh("a", append=app)

    assert reg.quota("a") == 100                 # quota survives
    assert reg.version("a") == ver_before + 1    # consumers re-resolve
    assert ctrl.queued_rows("a") == 8            # window survives
    svc2 = ctrl.service("a")
    assert svc2 is not svc1                      # fresh model behind it
    # ...but the observed bucket latencies carried over: the deadline
    # policy keeps estimating instead of resetting to fallback
    assert ctrl.estimate_latency_s("a", 30) == pytest.approx(est_before)
    assert ctrl.flush_model("a") >= 1 and h.done
    scores = np.asarray(h.result())
    direct = np.asarray(reg.get("a").scorer().score(
        np.asarray(X_prev[4:12])))
    np.testing.assert_allclose(scores, direct, rtol=0, atol=0)


def test_refresh_window_deadline_state_survives_on_fake_clock():
    """A deadline set before a refresh still flushes at the right tick
    after it — refresh must not reset the window's deadline pressure."""
    X_prev, X_new = _stream(seed=5)
    reg = ModelRegistry()
    reg.register("a", X_prev, SPEC, **FIT_KW)
    clock = ManualClock()
    ctrl = AdmissionController(reg, clock=clock, max_batch=128)
    svc = ctrl.service("a")
    svc.stats.setdefault(64, BucketStats()).record(64, 1, 0.25)
    h = ctrl.submit("a", np.asarray(X_prev[:4]), deadline=1.0)
    assert not ctrl.due("a")
    reg.refresh("a", append=X_new[M_PREV - N_EXP:])
    assert not ctrl.due("a")            # not due merely because refreshed
    clock.advance(0.8)                  # 0.8 + 0.25 >= 1.0: due now
    assert ctrl.due("a")
    assert ctrl.poll() == 1 and h.done


# -- artifact checkpoint round-trip -----------------------------------------

def test_artifact_roundtrip_feeds_fit_update(tmp_path):
    spec = _spec("rbf")
    X_prev, X_new = _stream(seed=5)
    prev = repro.fit(X_prev, spec, strategy="blocked", tol=1e-4)
    art = engine.artifact_from_result(prev)
    path = str(tmp_path / "model.npz")
    art.save(path)
    loaded = engine.SolverArtifact.load(path)
    assert loaded.m == art.m and loaded.precision == art.precision
    np.testing.assert_array_equal(loaded.hashes, art.hashes)
    np.testing.assert_allclose(np.asarray(loaded.f), np.asarray(art.f),
                               rtol=0, atol=0)
    assert (float(loaded.spec.nu1) == pytest.approx(float(spec.nu1))
            and loaded.spec.kernel.name == "rbf")

    stats = {}
    warm = repro.fit_update(loaded, X_new, strategy="blocked", tol=1e-4,
                            stats_out=stats)
    assert stats["mode"] == "warm"
    cold = repro.fit(X_new, spec, strategy="blocked", tol=1e-4)
    obj_cold = _objective(cold, X_new, spec)
    np.testing.assert_allclose(_objective(warm, X_new, spec), obj_cold,
                               **truth_tolerance("f32", obj_cold))


# -- provider-level append / expire parity -----------------------------------

@pytest.mark.parametrize("gram_mode", ["precomputed", "on_the_fly",
                                       "pallas"])
def test_provider_append_expire_matches_rebuild(gram_mode):
    spec = _spec("rbf")
    X_prev, _ = _stream(seed=5)
    X = jnp.asarray(X_prev[:40])
    X_app = jnp.asarray(X_prev[40:52])
    kern = spec.kernel
    prov = engine.make_provider(gram_mode, X, kern, interpret=True)
    gamma = jnp.linspace(0.001, 0.02, X.shape[0], dtype=jnp.float32)
    f = prov.init_scores(gamma)

    p2, g2, f2 = prov.append_rows(X_app, gamma, f)
    ref = engine.make_provider(
        gram_mode, jnp.concatenate([X, X_app]), kern, interpret=True)
    f_ref = ref.init_scores(g2)
    np.testing.assert_allclose(np.asarray(f2), np.asarray(f_ref),
                               rtol=0, atol=5e-6)
    assert float(jnp.abs(g2[X.shape[0]:]).max()) == 0.0

    idx = np.asarray([0, 3, 17, 41])
    p3, g3, f3 = p2.expire_rows(idx, g2, f2)
    keep = np.setdiff1d(np.arange(int(g2.shape[0])), idx)
    ref3 = engine.make_provider(
        gram_mode, jnp.concatenate([X, X_app])[keep], kern, interpret=True)
    np.testing.assert_allclose(np.asarray(f3),
                               np.asarray(ref3.init_scores(g3)),
                               rtol=0, atol=5e-6)
    np.testing.assert_allclose(np.asarray(g3),
                               np.asarray(g2)[keep], rtol=0, atol=0)
