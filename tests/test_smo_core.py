"""Core solver tests: the paper's SMO vs the QP baseline, constraint
preservation, convergence, and rho recovery."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SlabSpec, dual_objective, feasible_init, linear,
                        mcc, rbf, solve_blocked, solve_qp, solve_smo)
from repro.core.ocssvm import recover_rhos
from repro.data import make_toy

SPECS = [
    SlabSpec(nu1=0.5, nu2=0.05, eps=0.5, kernel=rbf(gamma=0.5)),
    SlabSpec(nu1=0.5, nu2=0.01, eps=2.0 / 3.0, kernel=linear()),
    SlabSpec(nu1=0.3, nu2=0.1, eps=0.4, kernel=rbf(gamma=1.5)),
]


def _toy(m=200, seed=1):
    return make_toy(jax.random.PRNGKey(seed), m)


@pytest.mark.parametrize("spec", SPECS)
def test_smo_matches_qp_objective(spec):
    X, _ = _toy(200)
    K = spec.kernel.gram(X.astype(jnp.float32))
    res = solve_smo(X, spec, selection="mvp", tol=1e-4)
    qp = solve_qp(X, spec, max_iters=60_000, tol=1e-10)
    o_smo = float(dual_objective(res.model.gamma, K))
    o_qp = float(dual_objective(qp.gamma, K))
    assert o_smo <= o_qp + 5e-4 + 0.05 * abs(o_qp)


@pytest.mark.parametrize("selection", ["paper", "mvp"])
def test_selection_modes_agree(selection):
    spec = SPECS[0]
    X, _ = _toy(150)
    K = spec.kernel.gram(X.astype(jnp.float32))
    res = solve_smo(X, spec, selection=selection, tol=1e-4)
    qp = solve_qp(X, spec, max_iters=60_000, tol=1e-10)
    assert float(dual_objective(res.model.gamma, K)) == pytest.approx(
        float(dual_objective(qp.gamma, K)), abs=2e-3)


@pytest.mark.parametrize("spec", SPECS)
@pytest.mark.parametrize("P", [1, 4, 16])
def test_blocked_smo_matches_qp(spec, P):
    X, _ = _toy(192)
    K = spec.kernel.gram(X.astype(jnp.float32))
    res = solve_blocked(X, spec, P=P, tol=1e-4)
    qp = solve_qp(X, spec, max_iters=60_000, tol=1e-10)
    assert float(dual_objective(res.model.gamma, K)) == pytest.approx(
        float(dual_objective(qp.gamma, K)), abs=2e-3)


@pytest.mark.parametrize("spec", SPECS)
def test_constraints_preserved(spec):
    X, _ = _toy(160)
    m = X.shape[0]
    for solver in (lambda: solve_smo(X, spec, selection="mvp", tol=1e-4),
                   lambda: solve_blocked(X, spec, P=8, tol=1e-4)):
        g = solver().model.gamma
        assert float(jnp.sum(g)) == pytest.approx(spec.total(), abs=1e-4)
        assert float(jnp.max(g)) <= spec.upper(m) + 1e-6
        assert float(jnp.min(g)) >= spec.lower(m) - 1e-6


def test_blocked_on_the_fly_equals_precomputed():
    # fp reduction-order differences in the kernel rows can flip argmax
    # selections, so trajectories (gammas) may differ — the reached
    # optimum must not.
    spec = SPECS[0]
    X, _ = _toy(128)
    K = spec.kernel.gram(X.astype(jnp.float32))
    r1 = solve_blocked(X, spec, P=8, gram_mode="precomputed", tol=1e-4)
    r2 = solve_blocked(X, spec, P=8, gram_mode="on_the_fly", tol=1e-4)
    o1 = float(dual_objective(r1.model.gamma, K))
    o2 = float(dual_objective(r2.model.gamma, K))
    assert o1 == pytest.approx(o2, abs=1e-4)
    assert bool(r1.converged) and bool(r2.converged)


def test_feasible_init_always_feasible():
    for m in (7, 50, 333):
        for spec in SPECS:
            g = feasible_init(m, spec)
            assert float(jnp.sum(g)) == pytest.approx(spec.total(), rel=1e-5)
            assert float(jnp.max(g)) <= spec.upper(m) + 1e-9
            assert float(jnp.min(g)) >= spec.lower(m) - 1e-9


def test_objective_never_increases_blocked():
    """Gauss-Seidel blocked steps are monotone descent on the dual."""
    spec = SPECS[0]
    X, _ = _toy(96)
    K = spec.kernel.gram(X.astype(jnp.float32))
    prev = None
    g = None
    for iters in (1, 2, 5, 10, 25, 60):
        res = solve_blocked(X, spec, P=4, tol=0.0, max_outer=iters)
        obj = float(dual_objective(res.model.gamma, K))
        if prev is not None:
            assert obj <= prev + 1e-6
        prev = obj


def test_decision_function_and_predict():
    spec = SPECS[0]
    X, y = _toy(200)
    res = solve_blocked(X, spec, P=8, tol=1e-4)
    pred = res.model.predict(X)
    assert set(np.unique(np.asarray(pred))).issubset({-1, 1})
    # decision values match sign of predictions
    dec = res.model.decision_function(X)
    np.testing.assert_array_equal(np.asarray(pred),
                                  np.where(np.asarray(dec) >= 0, 1, -1))


def test_recover_rhos_midpoint_fallback():
    # all-at-bound gamma: no free SVs on either plane
    spec = SlabSpec(nu1=0.5, nu2=0.5, eps=0.5, kernel=linear())
    m = 8
    hi, lo = spec.upper(m), spec.lower(m)
    gamma = jnp.array([hi] * 6 + [lo] * 2)  # sum = 6*0.25 - 2*0.125 = 1.25
    scores = jnp.arange(m, dtype=jnp.float32)
    r1, r2 = recover_rhos(gamma, scores, spec)
    assert np.isfinite(float(r1)) and np.isfinite(float(r2))


def test_mcc_basics():
    y = jnp.array([1, 1, -1, -1])
    assert float(mcc(y, y)) == pytest.approx(1.0)
    assert float(mcc(y, -y)) == pytest.approx(-1.0)
    assert float(mcc(y, jnp.array([1, -1, 1, -1]))) == pytest.approx(0.0)
