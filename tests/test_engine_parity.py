"""Engine parity matrix: every (GramProvider x Selector) composition must
reach the QP-baseline objective on the toy set — including the Pallas
provider in interpret mode (CPU), shrinking-through-engine, and the
``repro.fit`` strategy router. Also asserts the blocked solver's f-cache
update really goes through the Pallas ``fupdate`` kernel when
``gram_mode="pallas"``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import (SlabSpec, dual_objective, linear, rbf, solve_blocked,
                        solve_qp, solve_smo)
from repro.core.shrinking import solve_blocked_shrinking
from repro.data import make_toy
# the same scale-aware per-dtype tolerances the kernel parity matrix in
# tests/test_kernels.py asserts with
from repro.kernels.precision import truth_tolerance

SPEC = SlabSpec(nu1=0.5, nu2=0.05, eps=0.5, kernel=rbf(gamma=0.5))
M = 96

PROVIDERS = ["precomputed", "on_the_fly", "pallas"]
SELECTORS = ["paper", "mvp", "block"]


@pytest.fixture(scope="module")
def toy():
    X, y = make_toy(jax.random.PRNGKey(5), M)
    K = SPEC.kernel.gram(X.astype(jnp.float32))
    qp = solve_qp(X, SPEC, max_iters=60_000, tol=1e-10)
    return X, K, float(dual_objective(qp.gamma, K))


def _objective(res, K):
    return float(dual_objective(res.model.gamma, K))


@pytest.mark.parametrize("gram_mode", PROVIDERS)
@pytest.mark.parametrize("selection", SELECTORS)
def test_provider_selector_matrix_reaches_qp(toy, gram_mode, selection):
    X, K, o_qp = toy
    if selection == "block":
        res = solve_blocked(X, SPEC, P=4, gram_mode=gram_mode, tol=1e-4)
    else:
        res = solve_smo(X, SPEC, selection=selection, gram_mode=gram_mode,
                        tol=1e-4)
    assert _objective(res, K) == pytest.approx(o_qp, abs=2e-3)
    # feasibility of the returned gamma
    g = res.model.gamma
    assert float(jnp.sum(g)) == pytest.approx(SPEC.total(), abs=1e-4)
    assert float(jnp.max(g)) <= SPEC.upper(M) + 1e-6
    assert float(jnp.min(g)) >= SPEC.lower(M) - 1e-6


def test_shrinking_through_engine_pallas(toy):
    """The shrinking repack driver drives the engine's pallas provider."""
    X, K, o_qp = toy
    res = solve_blocked_shrinking(X, SPEC, P=4, gram_mode="pallas",
                                  tol=1e-4, warm_iters=30)
    assert _objective(res, K) == pytest.approx(o_qp, abs=2e-3)


def test_pallas_gram_invokes_fupdate_kernel(toy, monkeypatch):
    """gram_mode='pallas' must route the f-cache update through the Pallas
    fupdate kernel (interpret mode on CPU)."""
    from repro.core.engine import gram as engine_gram
    from repro.kernels.fupdate.ops import fupdate as real_fupdate

    calls = {"n": 0}

    def counting_fupdate(*args, **kwargs):
        calls["n"] += 1
        return real_fupdate(*args, **kwargs)

    monkeypatch.setattr(engine_gram, "fupdate", counting_fupdate)
    X, K, o_qp = toy
    # P=3 is used nowhere else in the suite, so jit must retrace and the
    # trace goes through the patched symbol.
    res = solve_blocked(X, SPEC, P=3, gram_mode="pallas", tol=1e-3)
    assert calls["n"] > 0
    assert _objective(res, K) == pytest.approx(o_qp, abs=2e-3)


@pytest.mark.parametrize("strategy", ["auto", "paper", "mvp", "blocked"])
def test_fit_strategies_reach_qp(toy, strategy):
    X, K, o_qp = toy
    res = repro.fit(X, SPEC, strategy=strategy, tol=1e-4)
    assert _objective(res, K) == pytest.approx(o_qp, abs=2e-3)


def test_fit_rejects_unknown_strategy(toy):
    X, _, _ = toy
    with pytest.raises(ValueError):
        repro.fit(X, SPEC, strategy="nope")
    with pytest.raises(ValueError):
        repro.fit(X, SPEC, strategy="distributed")   # no mesh given


def test_block_selector_p1_matches_mvp(toy):
    """Block top-P with P=1 is the classic maximal-violating pair — the
    paper's single-pair analytic update — and lands on the same optimum."""
    X, K, _ = toy
    r_blk = solve_blocked(X, SPEC, P=1, gram_mode="precomputed", tol=1e-4)
    r_mvp = solve_smo(X, SPEC, selection="mvp", gram_mode="precomputed",
                      tol=1e-4)
    assert _objective(r_blk, K) == pytest.approx(_objective(r_mvp, K),
                                                 abs=1e-4)


def test_engine_state_is_single_source():
    """No duplicated solver state types remain: all facades carry the
    engine's SolverState and return its SMOResult."""
    from repro.core import batched_smo, distributed_smo, smo
    from repro.core.engine.types import SMOResult

    assert smo.SMOResult is SMOResult
    for mod in (smo, batched_smo, distributed_smo):
        assert not hasattr(mod, "SMOState")
        assert not hasattr(mod, "BlockedState")
        assert not hasattr(mod, "_DistState")


def test_spec_roundtrip_from_fitted_model(toy):
    """A spec recovered from a fitted model (its kernel params come back
    as 0-d jax arrays through the jit boundary) must be reusable."""
    X, K, o_qp = toy
    res = repro.fit(X, SPEC, strategy="blocked", tol=1e-3)
    spec_rt = res.model.spec
    assert not isinstance(spec_rt.kernel.gamma, float)   # array round-trip
    res2 = repro.fit(X, spec_rt, strategy="blocked", tol=1e-3)
    assert _objective(res2, K) == pytest.approx(o_qp, abs=2e-3)


def test_fit_kwargs_flow_across_strategies(toy):
    """The iteration-cap kwarg reaches whichever solver 'auto' picks —
    max_iters and max_outer are accepted interchangeably."""
    X, _, _ = toy
    r1 = repro.fit(X, SPEC, strategy="shrinking", max_outer=500, tol=1e-3)
    r2 = repro.fit(X, SPEC, strategy="paper", max_outer=50, tol=1e-3)
    r3 = repro.fit(X, SPEC, strategy="blocked", max_iters=50, tol=1e-3)
    assert int(r2.iters) <= 50
    assert int(r3.iters) <= 50
    assert np.isfinite(float(r1.gap))


@pytest.mark.parametrize("precision", ["bf16", "f16"])
@pytest.mark.parametrize("gram_mode", PROVIDERS)
def test_low_precision_providers_reach_qp(toy, gram_mode, precision):
    """16-bit Gram tile inputs must not move the optimum beyond the
    documented tolerance: the solve still reaches the f32 QP objective
    (f32 accumulation keeps the dual well-conditioned; only the inputs
    are rounded) and returns a feasible gamma."""
    X, K, o_qp = toy
    res = solve_blocked(X, SPEC, P=4, gram_mode=gram_mode,
                        precision=precision, tol=1e-4)
    assert _objective(res, K) == pytest.approx(o_qp, abs=5e-3)
    g = res.model.gamma
    assert float(jnp.sum(g)) == pytest.approx(SPEC.total(), abs=1e-4)
    assert float(jnp.max(g)) <= SPEC.upper(M) + 1e-6
    assert float(jnp.min(g)) >= SPEC.lower(M) - 1e-6


def test_precision_f32_solve_bit_identical(toy):
    """precision="f32" must leave the solver bit-for-bit unchanged."""
    X, _, _ = toy
    r0 = solve_blocked(X, SPEC, P=4, gram_mode="precomputed", tol=1e-4)
    r1 = solve_blocked(X, SPEC, P=4, gram_mode="precomputed",
                       precision="f32", tol=1e-4)
    assert bool(jnp.all(r0.model.gamma == r1.model.gamma))
    assert int(r0.iters) == int(r1.iters)


def test_fit_threads_precision_to_provider(toy, monkeypatch):
    """repro.fit(..., precision=...) must reach the provider layer for
    every local strategy."""
    from repro.core.engine import gram as engine_gram

    seen = []
    real = engine_gram.make_provider

    def spying(gram_mode, X, kernel, interpret=None, precision="f32"):
        seen.append(precision)
        return real(gram_mode, X, kernel, interpret=interpret,
                    precision=precision)

    monkeypatch.setattr(engine_gram, "make_provider", spying)
    # the facades bind engine.make_provider through the package namespace
    import repro.core.engine as engine_pkg
    monkeypatch.setattr(engine_pkg, "make_provider", spying)
    X, _, _ = toy
    for strategy in ("blocked", "mvp", "shrinking"):
        seen.clear()
        repro.fit(X, SPEC, strategy=strategy, precision="bf16", tol=1e-2,
                  max_outer=40, **({"warm_iters": 20}
                                   if strategy == "shrinking" else {}))
        assert seen and all(p == "bf16" for p in seen), strategy


SHRINK_KERNELS = {"rbf": lambda: rbf(gamma=0.5), "linear": linear}

# Two independently converged solves of the same dual agree only to the
# KKT tolerance, not to machine precision: this floor (calibrated on the
# toy set at tol=1e-4) is added on top of the per-dtype kernel
# tolerances, which only cover the Gram-tile rounding.
SOLVER_ATOL_FLOOR = 5e-3


@pytest.mark.parametrize("precision", ["f32", "bf16"])
@pytest.mark.parametrize("kernel_name", ["rbf", "linear"])
def test_shrinking_matches_blocked(kernel_name, precision):
    """The shrinking repack driver must land on the same slab as the
    plain blocked solver — objective AND both offsets — for every
    (kernel, precision) cell, within the scale-aware per-dtype
    tolerances plus the solver-convergence floor."""
    spec = SlabSpec(nu1=0.5, nu2=0.05, eps=0.5,
                    kernel=SHRINK_KERNELS[kernel_name]())
    X, _ = make_toy(jax.random.PRNGKey(5), M)
    K = spec.kernel.gram(X.astype(jnp.float32))   # f32 scoreboard
    r_blk = solve_blocked(X, spec, P=4, gram_mode="precomputed",
                          precision=precision, tol=1e-4)
    r_shr = solve_blocked_shrinking(X, spec, P=4, gram_mode="precomputed",
                                    precision=precision, tol=1e-4,
                                    warm_iters=30)
    o_blk = float(dual_objective(r_blk.model.gamma, K))
    o_shr = float(dual_objective(r_shr.model.gamma, K))
    tol_obj = truth_tolerance(precision, np.asarray([o_blk]))
    np.testing.assert_allclose(
        o_shr, o_blk, rtol=tol_obj["rtol"],
        atol=max(tol_obj["atol"], SOLVER_ATOL_FLOOR))

    rho_blk = np.asarray([float(r_blk.model.rho1), float(r_blk.model.rho2)])
    rho_shr = np.asarray([float(r_shr.model.rho1), float(r_shr.model.rho2)])
    tol_rho = truth_tolerance(precision, rho_blk)
    np.testing.assert_allclose(
        rho_shr, rho_blk, rtol=tol_rho["rtol"],
        atol=max(tol_rho["atol"], SOLVER_ATOL_FLOOR))


def test_provider_rejects_unknown_precision(toy):
    X, _, _ = toy
    with pytest.raises(ValueError):
        solve_blocked(X, SPEC, P=4, gram_mode="precomputed",
                      precision="fp8", tol=1e-2)


# -- sharded engine cells ---------------------------------------------------
# The sharded provider/selector need >1 device, and jax pins the device
# count at first import, so each cell runs in a forced-device subprocess
# (the shared harness in conftest.py). One subprocess per precision keeps
# the jax start-up cost at one import per cell while still giving CI a
# distinct pass/fail signal per dtype.

from conftest import run_forced_devices  # noqa: E402


@pytest.mark.parametrize("precision", ["f32", "bf16", "f16"])
def test_sharded_engine_parity_matches_blocked(precision):
    """repro.fit(strategy="sharded") on an 8-forced-device launch-layer
    mesh must reach the single-device blocked optimum at every supported
    Gram tile precision — objective AND both slab offsets — and the hot
    loop must actually run the per-shard Pallas fupdate kernel (counted
    via the engine module's symbol, which ShardedGram.apply_update
    resolves at trace time)."""
    res = run_forced_devices(f"""
        import json
        import jax, jax.numpy as jnp
        import repro
        import repro.core.engine.gram as eg
        from repro.core import SlabSpec, rbf, solve_blocked, dual_objective
        from repro.data import make_toy

        calls = {{"n": 0}}
        real_fupdate = eg.fupdate
        def counting(*a, **k):
            calls["n"] += 1
            return real_fupdate(*a, **k)
        eg.fupdate = counting

        precision = {precision!r}
        X, _ = make_toy(jax.random.PRNGKey(5), 96)
        spec = SlabSpec(nu1=0.5, nu2=0.05, eps=0.5, kernel=rbf(gamma=0.5))
        K = spec.kernel.gram(X.astype(jnp.float32))
        rs = repro.fit(X, spec, strategy="sharded", P=4, tol=1e-4,
                       precision=precision)
        rb = solve_blocked(X, spec, P=4, tol=1e-4, precision=precision)
        print(json.dumps({{
            "obj_sharded": float(dual_objective(rs.model.gamma, K)),
            "obj_blocked": float(dual_objective(rb.model.gamma, K)),
            "rho_sharded": [float(rs.model.rho1), float(rs.model.rho2)],
            "rho_blocked": [float(rb.model.rho1), float(rb.model.rho2)],
            "sum_gamma": float(rs.model.gamma.sum()),
            "expected_sum": spec.total(),
            "converged": bool(rs.converged),
            "fupdate_calls": calls["n"],
            "n_devices": jax.device_count(),
        }}))
    """, devices=8)
    assert res["n_devices"] == 8
    assert res["converged"]
    assert res["fupdate_calls"] > 0, "sharded hot loop bypassed Pallas"
    assert res["sum_gamma"] == pytest.approx(res["expected_sum"], abs=1e-4)
    tol_obj = truth_tolerance(precision, np.asarray([res["obj_blocked"]]))
    np.testing.assert_allclose(
        res["obj_sharded"], res["obj_blocked"], rtol=tol_obj["rtol"],
        atol=max(tol_obj["atol"], SOLVER_ATOL_FLOOR))
    tol_rho = truth_tolerance(precision, np.asarray(res["rho_blocked"]))
    np.testing.assert_allclose(
        np.asarray(res["rho_sharded"]), np.asarray(res["rho_blocked"]),
        rtol=tol_rho["rtol"], atol=max(tol_rho["atol"], SOLVER_ATOL_FLOOR))
