"""Multi-model registry + deadline-aware admission tests.

Covers the serving front-end end to end: name -> recipe -> warm model
routing (fit-on-first-use through the ModelCache, typed errors,
evict/refresh lifecycle), quota enforcement, and the admission
controller's deadline policy — every timing decision driven by a manual
fake clock, so nothing here sleeps or depends on wall-clock.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import SlabSpec, rbf
from repro.data import make_toy
from repro.serve import (AdmissionController, BucketStats,
                         DuplicateModelError, ModelCache, ModelRegistry,
                         QuotaExceededError, UnknownModelError, bucket_for)
from repro.serve.registry import serve as routed_serve

SPEC_A = SlabSpec(nu1=0.5, nu2=0.05, eps=0.5, kernel=rbf(gamma=0.5))
SPEC_B = SlabSpec(nu1=0.3, nu2=0.05, eps=0.5, kernel=rbf(gamma=1.5))
M = 48
FIT_KW = dict(tol=1e-2, max_outer=60)


class ManualClock:
    """Fake absolute clock: reads return ``t`` until ``advance``d."""

    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


@pytest.fixture()
def X():
    return make_toy(jax.random.PRNGKey(5), M)[0]


@pytest.fixture()
def counting_fit(monkeypatch):
    """Count real repro.fit calls (the expensive thing the registry must
    not repeat)."""
    from repro import api

    calls = {"n": 0}
    real_fit = api.fit

    def spy(*args, **kwargs):
        calls["n"] += 1
        return real_fit(*args, **kwargs)

    monkeypatch.setattr(api, "fit", spy)
    return calls


# -- registry: recipes, routing, lifecycle ----------------------------------

def test_register_defers_fit_and_get_fits_once(X, counting_fit):
    reg = ModelRegistry()
    reg.register("a", X, SPEC_A, **FIT_KW)
    assert counting_fit["n"] == 0           # recording a recipe is free
    sm1 = reg.get("a")
    sm2 = reg.get("a")
    assert sm2 is sm1 and counting_fit["n"] == 1
    assert reg.cache.misses == 1 and reg.cache.hits == 1


def test_unknown_model_typed_error(X):
    reg = ModelRegistry()
    with pytest.raises(UnknownModelError) as ei:
        reg.get("ghost")
    assert isinstance(ei.value, KeyError)
    assert ei.value.name == "ghost"
    reg.register("real", X, SPEC_A, **FIT_KW)
    with pytest.raises(UnknownModelError) as ei:
        reg.quota("ghost")
    assert ei.value.known == ("real",)


def test_reregister_identical_recipe_is_noop(X):
    reg = ModelRegistry()
    r1 = reg.register("a", X, SPEC_A, quota=100, **FIT_KW)
    r2 = reg.register("a", X, SPEC_A, **FIT_KW)     # quota=None keeps 100
    assert r2 is r1 and reg.quota("a") == 100
    r3 = reg.register("a", X, SPEC_A, quota=50, **FIT_KW)
    assert r3.key == r1.key and reg.quota("a") == 50


def test_reregister_different_recipe_raises_unless_replace(X, counting_fit):
    reg = ModelRegistry()
    reg.register("a", X, SPEC_A, **FIT_KW)
    with pytest.raises(DuplicateModelError):
        reg.register("a", X, SPEC_B, **FIT_KW)
    sm_a = reg.get("a")
    reg.register("a", X, SPEC_B, replace=True, **FIT_KW)
    sm_b = reg.get("a")
    assert sm_b is not sm_a and counting_fit["n"] == 2
    assert float(sm_b.spec.nu1) == pytest.approx(0.3)


def test_evict_keeps_recipe_and_refits_on_next_get(X, counting_fit):
    reg = ModelRegistry()
    reg.register("a", X, SPEC_A, **FIT_KW)
    sm1 = reg.get("a")
    assert reg.evict("a") is True
    assert reg.evict("a") is False          # already gone
    assert "a" in reg                       # the recipe survives
    sm2 = reg.get("a")
    assert sm2 is not sm1 and counting_fit["n"] == 2


def test_refresh_refits_eagerly(X, counting_fit):
    reg = ModelRegistry()
    reg.register("a", X, SPEC_A, **FIT_KW)
    sm1 = reg.get("a")
    sm2 = reg.refresh("a")
    assert sm2 is not sm1 and counting_fit["n"] == 2
    assert reg.get("a") is sm2


def test_unregister_removes_name_and_model(X, counting_fit):
    reg = ModelRegistry()
    reg.register("a", X, SPEC_A, **FIT_KW)
    reg.get("a")
    reg.unregister("a")
    assert "a" not in reg and len(reg) == 0
    with pytest.raises(UnknownModelError):
        reg.get("a")
    # the cache entry went with it: re-registering re-fits
    reg.register("a", X, SPEC_A, **FIT_KW)
    reg.get("a")
    assert counting_fit["n"] == 2


def test_registry_validates_inputs(X):
    reg = ModelRegistry()
    with pytest.raises(ValueError):
        reg.register("", X, SPEC_A)
    with pytest.raises(ValueError):
        reg.register("a", X, SPEC_A, quota=0)


def test_api_serve_model_routing(X):
    reg = ModelRegistry()
    sm1 = repro.serve(X, SPEC_A, model="a", registry=reg, **FIT_KW)
    sm2 = repro.serve(model="a", registry=reg)        # pure name lookup
    assert sm2 is sm1
    # idempotent re-register with the same recipe
    assert repro.serve(X, SPEC_A, model="a", registry=reg,
                       **FIT_KW) is sm1
    # a different recipe under the same name is the guarded error
    with pytest.raises(DuplicateModelError):
        repro.serve(X, SPEC_B, model="a", registry=reg, **FIT_KW)
    with pytest.raises(UnknownModelError):
        repro.serve(model="ghost", registry=reg)


def test_routed_serve_rejects_bad_combinations(X):
    with pytest.raises(TypeError):
        routed_serve()                                 # no X, no model
    with pytest.raises(TypeError):
        routed_serve(X, SPEC_A, quota=5)               # quota without model
    with pytest.raises(TypeError):
        routed_serve(X, SPEC_A, model="a", cache=ModelCache())


# -- registry: concurrency ---------------------------------------------------

def test_concurrent_gets_coalesce_to_one_fit(X, monkeypatch):
    """N threads racing on the same unregistered-but-recipe'd name must
    run exactly ONE fit — the registry piggy-backs on the cache's
    per-key in-flight locks."""
    import time as _time

    from repro import api

    calls = {"n": 0}
    real_fit = api.fit

    def slow_fit(*args, **kwargs):
        calls["n"] += 1
        _time.sleep(0.4)        # long enough for every thread to race
        return real_fit(*args, **kwargs)

    monkeypatch.setattr(api, "fit", slow_fit)
    reg = ModelRegistry()
    reg.register("a", X, SPEC_A, **FIT_KW)
    n_threads = 4
    results = [None] * n_threads
    barrier = threading.Barrier(n_threads)

    def worker(i):
        barrier.wait()
        results[i] = reg.get("a")

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert calls["n"] == 1, "the fleet ran the expensive fit more than once"
    assert all(r is results[0] for r in results)
    assert reg.cache.misses == 1 and reg.cache.hits == n_threads - 1


def test_evict_during_inflight_score_is_safe(X):
    """Evicting a model while another thread is mid-score must not
    corrupt that thread's results: the scorer holds its own reference;
    eviction only forgets the cache's."""
    reg = ModelRegistry()
    reg.register("a", X, SPEC_A, **FIT_KW)
    sm = reg.get("a")
    q = np.asarray(make_toy(jax.random.PRNGKey(9), 500)[0])
    ref = np.asarray(sm.model.decision_function(jnp.asarray(q, jnp.float32)))

    out, errs = [], []
    started = threading.Event()

    def score_loop():
        scorer = sm.scorer()
        started.set()
        try:
            for _ in range(5):
                out.append(np.asarray(scorer.score(q)))
        except BaseException as e:     # surface, don't swallow
            errs.append(e)

    t = threading.Thread(target=score_loop)
    t.start()
    started.wait(timeout=60)
    for _ in range(5):                 # evict repeatedly mid-flight
        reg.evict("a")
    t.join(timeout=300)
    assert not errs
    assert len(out) == 5
    for scores in out:
        np.testing.assert_allclose(scores, ref, rtol=2e-4, atol=2e-4)
    # and the name still serves (re-fit on demand)
    assert reg.get("a").score(q[:4]).shape == (4,)


# -- admission: policy, quotas, deadlines (all on the fake clock) ------------

@pytest.fixture()
def fleet(X):
    """Two registered models + a controller on a manual clock."""
    reg = ModelRegistry()
    reg.register("a", X, SPEC_A, **FIT_KW)
    reg.register("b", X, SPEC_B, **FIT_KW)
    clock = ManualClock()
    ctrl = AdmissionController(reg, clock=clock, max_batch=128)
    return reg, ctrl, clock


def _q(seed, n):
    return np.asarray(make_toy(jax.random.PRNGKey(seed), n)[0])


def test_admission_windows_group_per_model(fleet):
    reg, ctrl, clock = fleet
    ha = ctrl.submit("a", _q(1, 10))
    hb = ctrl.submit("b", _q(2, 20))
    assert ctrl.queued_rows("a") == 10 and ctrl.queued_rows("b") == 20
    assert not ha.flushed and not hb.flushed
    assert ctrl.poll() == 0            # no deadlines, below capacity
    assert ctrl.flush_model("a") == 1
    assert ha.done and not hb.flushed
    assert ctrl.drain() == 1
    assert hb.done


def test_admission_bucket_fill_flushes_at_submit(fleet):
    reg, ctrl, clock = fleet
    h1 = ctrl.submit("b", _q(1, 100))
    assert not h1.flushed
    h2 = ctrl.submit("b", _q(2, 28))   # 128 rows == max_batch -> flush now
    assert h1.flushed and h2.flushed and h1.done and h2.done
    assert ctrl.queued_rows("b") == 0


def test_admission_deadline_uses_observed_latency(fleet):
    """The window flushes exactly when waiting longer would miss the
    earliest deadline given OBSERVED per-bucket latency — not a tick
    earlier, and never via wall-clock."""
    reg, ctrl, clock = fleet
    svc = ctrl.service("a")
    # seed the observation: the 64-bucket takes 250ms per launch
    # (dyadic values, so the due-time comparison is float-exact)
    svc.stats.setdefault(64, BucketStats()).record(64, 1, 0.25)
    assert ctrl.estimate_latency_s("a", 30) == pytest.approx(0.25)

    h = ctrl.submit("a", _q(1, 30), deadline=1.0)
    assert not ctrl.due("a")           # 0 + 0.25 << 1.0: keep coalescing
    assert ctrl.poll() == 0
    clock.t = 0.5
    assert not ctrl.due("a")           # 0.5 + 0.25 < 1.0: still early
    clock.t = 0.75
    assert ctrl.due("a")               # 0.75 + 0.25 >= 1.0: last safe moment
    assert ctrl.poll() == 1
    assert h.done


def test_admission_unobserved_bucket_uses_fallback(X):
    reg = ModelRegistry()
    reg.register("a", X, SPEC_A, **FIT_KW)
    clock = ManualClock()
    ctrl = AdmissionController(reg, clock=clock, fallback_latency_s=0.050)
    ctrl.submit("a", _q(1, 10), deadline=0.060)
    assert ctrl.estimate_latency_s("a") == pytest.approx(0.050)
    assert not ctrl.due("a")           # 0 + 50 < 60
    clock.t = 0.010
    assert ctrl.due("a")               # 10 + 50 >= 60
    # safety_factor scales the estimate
    ctrl2 = AdmissionController(reg, clock=ManualClock(),
                                fallback_latency_s=0.050, safety_factor=2.0)
    ctrl2.submit("a", _q(1, 10), deadline=0.060)
    assert ctrl2.due("a")              # 0 + 2*50 >= 60: flush right away


def test_admission_estimate_sums_launch_plan(fleet):
    """A window bigger than one launch costs the sum of its planned
    launches' observed bucket latencies."""
    reg, ctrl, clock = fleet
    svc = ctrl.service("a")
    top = svc.scorer.chunk_rows()
    svc.stats.setdefault(bucket_for(top), BucketStats()).record(top, 1, 0.040)
    svc.stats.setdefault(64, BucketStats()).record(64, 1, 0.010)
    # top-bucket chunk + 50-row remainder -> 40ms + 10ms
    assert ctrl.estimate_latency_s("a", top + 50) == pytest.approx(0.050)


def test_admission_max_wait_bounds_deadline_less_windows(fleet):
    reg, ctrl, clock = fleet
    ctrl.max_wait_s = 0.5
    h = ctrl.submit("a", _q(1, 10))    # no deadline
    assert ctrl.poll() == 0
    clock.advance(0.49)
    assert ctrl.poll() == 0
    clock.advance(0.02)
    assert ctrl.poll() == 1 and h.done


def test_admission_quota_rejects_typed_and_recovers(fleet):
    reg, _, clock = fleet
    # quota on "a" (identical recipe re-register just updates the
    # quota); max_batch above it so the window genuinely accumulates —
    # bucket fill would otherwise flush before the quota can bind
    reg.register("a", reg.recipe("a").X, SPEC_A, quota=200, **FIT_KW)
    ctrl = AdmissionController(reg, clock=clock)
    ctrl.submit("a", _q(1, 150))
    with pytest.raises(QuotaExceededError) as ei:
        ctrl.submit("a", _q(2, 51))    # 150 + 51 > 200
    err = ei.value
    assert (err.model, err.quota, err.queued_rows, err.requested_rows) \
        == ("a", 200, 150, 51)
    assert ctrl.rejected["a"] == 1
    # under the line still fits; "b" (no quota) is unconstrained
    ctrl.submit("a", _q(3, 50))
    ctrl.submit("b", _q(4, 120))       # fills its 128-bucket? no: 120 < 128
    assert ctrl.queued_rows("a") == 200
    # flushing frees the window: quota applies to QUEUED rows, not history
    ctrl.flush_model("a")
    ctrl.submit("a", _q(5, 200))
    assert ctrl.queued_rows("a") == 200


def test_admission_handle_result_forces_its_window(fleet):
    reg, ctrl, clock = fleet
    q = _q(1, 12)
    h = ctrl.submit("a", q, deadline=99.0)
    out = np.asarray(h.result())       # no poll, no clock advance
    direct = np.asarray(reg.get("a").scorer().score(q))
    np.testing.assert_allclose(out, direct, rtol=0, atol=0)
    assert ctrl.queued_rows("a") == 0


def test_handle_result_routes_inflight_through_model_lock(fleet):
    """A handle whose pending is bound but NOT done (another thread
    mid-flush) must route result() through controller.flush_model (the
    model lock) — never poke the non-thread-safe service flush
    directly."""
    reg, ctrl, clock = fleet
    h = ctrl.submit("a", _q(1, 8), deadline=99.0)

    class _StuckPending:
        done = False

        def result(self):
            raise AssertionError("bypassed the model lock: "
                                 "Pending.result() before flush_model")

    calls = []
    real = ctrl.flush_model

    def spy(model):
        calls.append(model)
        h._pending = None          # 'flush finished': let the real one bind
        return real(model)

    ctrl.flush_model = spy
    h._pending = _StuckPending()   # simulate a flush in progress
    out = h.result()
    assert calls == ["a"]
    assert np.asarray(out).shape == (8,)


def test_admission_rejects_bad_requests(fleet):
    reg, ctrl, clock = fleet
    d = reg.get("a").d
    with pytest.raises(ValueError):
        ctrl.submit("a", np.zeros((0, d), np.float32))      # zero rows
    with pytest.raises(ValueError):
        ctrl.submit("a", np.zeros((4, d + 1), np.float32))  # wrong d
    with pytest.raises(UnknownModelError):
        ctrl.submit("ghost", _q(1, 4))


def test_admission_poll_flushes_in_deadline_order(fleet):
    reg, ctrl, clock = fleet
    order = []
    real = ctrl.flush_model

    def spy(model):
        order.append(model)
        return real(model)

    ctrl.flush_model = spy
    ctrl.submit("a", _q(1, 10), deadline=2.0)
    ctrl.submit("b", _q(2, 10), deadline=1.0)
    clock.t = 5.0                      # both overdue
    ctrl.poll()
    assert order == ["b", "a"]         # earliest deadline first


def test_admission_max_wait_defers_to_deadline_policy(fleet):
    """A window WITH a deadline is governed by deadline pressure alone:
    the max_wait_s age bound (documented for deadline-less windows) must
    not flush it early and waste the promised coalescing."""
    reg, ctrl, clock = fleet
    ctrl.max_wait_s = 0.05
    svc = ctrl.service("a")
    svc.stats.setdefault(64, BucketStats()).record(64, 1, 0.25)
    ctrl.submit("a", _q(1, 30), deadline=2.0)
    clock.t = 1.0                      # way past max_wait_s
    assert not ctrl.due("a")           # ...but 1.0 + 0.25 < 2.0: wait
    assert ctrl.poll() == 0
    clock.t = 1.75
    assert ctrl.due("a")               # deadline pressure, not age
    assert ctrl.poll() == 1


def test_admission_rebuilds_service_after_refresh_and_replace(X,
                                                              counting_fit):
    """evict/refresh/replace on the registry must reach a live
    controller: its memoized per-model service is rebuilt on the next
    touch (registry version bump), so post-refresh traffic scores
    against the fresh model, not a stale scorer."""
    reg = ModelRegistry()
    reg.register("a", X, SPEC_A, **FIT_KW)
    ctrl = AdmissionController(reg)
    ctrl.submit("a", _q(1, 4)).result()
    svc1 = ctrl._services["a"]
    reg.refresh("a")
    ctrl.submit("a", _q(2, 4)).result()
    assert ctrl._services["a"] is not svc1
    assert counting_fit["n"] == 2      # initial fit + the refresh re-fit

    # replace=True swaps the spec under the same name: traffic follows
    reg.set_quota("a", 90)
    reg.register("a", X, SPEC_B, replace=True, **FIT_KW)
    assert reg.quota("a") == 90        # replace keeps the quota too
    q = _q(3, 16)
    out = np.asarray(ctrl.submit("a", q).result())
    direct = np.asarray(reg.get("a").scorer().score(q))
    np.testing.assert_allclose(out, direct, rtol=0, atol=0)
    assert float(reg.get("a").spec.nu1) == pytest.approx(0.3)


def test_admission_fit_of_one_model_does_not_block_another(X, monkeypatch):
    """Per-model locking: a cold model's fit-on-first-use must not
    serialize a warm model's traffic behind the controller."""
    from repro import api

    real_fit = api.fit
    gate = threading.Event()

    def gated_fit(Xa, spec, **kwargs):
        if float(spec.nu1) == pytest.approx(0.3):     # model "b" only
            assert gate.wait(timeout=60)
        return real_fit(Xa, spec, **kwargs)

    monkeypatch.setattr(api, "fit", gated_fit)
    reg = ModelRegistry()
    reg.register("a", X, SPEC_A, **FIT_KW)
    reg.register("b", X, SPEC_B, **FIT_KW)
    ctrl = AdmissionController(reg)
    ctrl.service("a")                  # warm "a" (nu1=0.5: not gated)

    b_done = threading.Event()

    def cold_path():
        ctrl.submit("b", _q(1, 8))     # stuck inside b's gated fit
        b_done.set()

    t = threading.Thread(target=cold_path)
    t.start()
    try:
        # while b is mid-fit, a's admission and scoring must flow
        out = ctrl.submit("a", _q(2, 8)).result()
        assert np.asarray(out).shape == (8,)
        assert not b_done.is_set()     # b really was still fitting
    finally:
        gate.set()
        t.join(timeout=120)
    assert b_done.is_set()
    ctrl.drain()


def test_flush_failure_keeps_window_and_recovers(X, counting_fit):
    """A flush whose service resolution fails (name unregistered between
    submit and flush) must NOT drop the queued requests: the window
    survives, the error surfaces, and re-registering the recipe lets a
    later flush serve the original handles."""
    reg = ModelRegistry()
    reg.register("a", X, SPEC_A, **FIT_KW)
    ctrl = AdmissionController(reg)
    ctrl.service("a")                        # warm, version 0
    q = _q(1, 12)
    h = ctrl.submit("a", q)
    reg.unregister("a")                      # version bump -> rebuild path
    with pytest.raises(UnknownModelError):
        ctrl.flush_model("a")
    assert ctrl.queued_rows("a") == 12       # nothing was dropped
    assert not h.flushed
    reg.register("a", X, SPEC_A, **FIT_KW)   # heal the name
    assert ctrl.flush_model("a") == 1
    direct = np.asarray(reg.get("a").scorer().score(q))
    np.testing.assert_allclose(np.asarray(h.result()), direct,
                               rtol=0, atol=0)


def test_registry_grows_own_cache_with_fleet(X):
    """A fleet larger than the default ModelCache LRU must not thrash:
    the registry grows its own cache so every registered recipe keeps
    its warm slot (registration alone is free — no fits here)."""
    reg = ModelRegistry()
    for i in range(12):
        spec = SlabSpec(nu1=0.3 + 0.02 * i, nu2=0.05, eps=0.5,
                        kernel=rbf(gamma=0.5))
        reg.register(f"tenant-{i}", X, spec, **FIT_KW)
    assert reg.cache.maxsize >= 12
    # a caller-owned cache is respected, not resized
    own = ModelCache(maxsize=2)
    reg2 = ModelRegistry(cache=own)
    for i in range(4):
        spec = SlabSpec(nu1=0.3 + 0.02 * i, nu2=0.05, eps=0.5,
                        kernel=rbf(gamma=0.5))
        reg2.register(f"t{i}", X, spec, **FIT_KW)
    assert own.maxsize == 2


def test_routed_serve_quota_update_without_X(X):
    """serve(model=, quota=) on a registered name must apply the quota,
    not silently drop it; spec/fit kwargs without X are an error."""
    reg = ModelRegistry()
    reg.register("a", X, SPEC_A, **FIT_KW)
    assert reg.quota("a") is None
    routed_serve(model="a", registry=reg, quota=77)
    assert reg.quota("a") == 77
    with pytest.raises(TypeError):
        routed_serve(spec=SPEC_B, model="a", registry=reg)
    with pytest.raises(TypeError):
        routed_serve(model="a", registry=reg, tol=1e-3)
    with pytest.raises(UnknownModelError):
        routed_serve(model="ghost", registry=reg, quota=5)


def test_rejected_submit_leaves_no_window(X):
    """A rejected first request must not create an empty window: its
    stale opened_at would backdate the next admitted request's age and
    make max_wait_s flush it immediately."""
    reg = ModelRegistry()
    reg.register("a", X, SPEC_A, quota=100, **FIT_KW)
    clock = ManualClock()
    ctrl = AdmissionController(reg, clock=clock, max_wait_s=0.5)
    with pytest.raises(QuotaExceededError):
        ctrl.submit("a", _q(1, 150))         # oversized single request
    assert "a" not in ctrl._windows          # no residue
    clock.t = 10.0                           # much later
    ctrl.submit("a", _q(2, 10))
    assert not ctrl.due("a")                 # fresh window, age 0
    clock.t = 10.49
    assert not ctrl.due("a")
    clock.t = 10.51
    assert ctrl.due("a")


def test_replace_with_incompatible_dim_fails_only_stale_handles(X):
    """A request admitted against the OLD model but flushed after a
    replace to a different feature dim is permanently unservable: its
    handle must carry the error (result() raises), the flush must not
    orphan it with a bare AttributeError, and fresh-dim traffic must
    flow immediately after."""
    reg = ModelRegistry()
    reg.register("a", X, SPEC_A, **FIT_KW)            # d=2 toy
    ctrl = AdmissionController(reg)
    ctrl.service("a")
    h_stale = ctrl.submit("a", _q(1, 8))              # validated vs d=2
    X3, _ = make_toy(jax.random.PRNGKey(5), M, d=3)
    reg.register("a", X3, SPEC_A, replace=True, **FIT_KW)
    assert ctrl.flush_model("a") == 0                 # nothing servable
    assert h_stale.done
    with pytest.raises(ValueError, match="feature dim"):
        h_stale.result()
    q3 = np.asarray(make_toy(jax.random.PRNGKey(9), 8, d=3)[0])
    h_new = ctrl.submit("a", q3)
    np.testing.assert_allclose(
        np.asarray(h_new.result()),
        np.asarray(reg.get("a").scorer().score(q3)), rtol=0, atol=0)


def test_forget_releases_retired_model_state(X):
    """forget() flushes and then drops every per-model structure, so a
    churning fleet doesn't pin retired tenants' packed models/locks/
    stats in a long-lived controller."""
    reg = ModelRegistry()
    reg.register("a", X, SPEC_A, **FIT_KW)
    ctrl = AdmissionController(reg)
    h = ctrl.submit("a", _q(1, 8))                    # still queued
    ctrl.forget("a")
    assert h.done                                     # flushed, not dropped
    assert np.asarray(h.result()).shape == (8,)
    assert "a" not in ctrl._services
    assert "a" not in ctrl._windows
    # the lock entry deliberately survives: popping it under a waiting
    # thread would let a later submit mint a second, concurrent lock
    assert "a" in ctrl._model_locks
    assert ctrl.stats_dict() == {}
    reg.unregister("a")
    assert len(reg) == 0


def test_rejected_only_model_still_visible_in_stats(X):
    """A model shedding 100% of its traffic (every submit over quota,
    service never resolved) must still appear in stats output — an
    operator reading zero rejections while load is being dropped is the
    worst kind of silent."""
    reg = ModelRegistry()
    reg.register("a", X, SPEC_A, quota=10, **FIT_KW)
    ctrl = AdmissionController(reg)
    with pytest.raises(QuotaExceededError):
        ctrl.submit("a", _q(1, 50))
    assert "a" not in ctrl._services          # the reject paid no fit
    stats = ctrl.stats_dict()
    assert stats["a"]["rejected"] == 1 and stats["a"]["buckets"] == {}
    assert any("model=a" in ln and "rejected=1" in ln
               for ln in ctrl.stats_lines())


def test_admission_warns_on_unbindable_quota(X):
    """A quota at or above max_batch can never reject (bucket fill
    drains the window first) — the controller says so once instead of
    letting the operator believe load-shedding is armed."""
    reg = ModelRegistry()
    reg.register("a", X, SPEC_A, quota=1000, **FIT_KW)
    ctrl = AdmissionController(reg, max_batch=64)
    with pytest.warns(RuntimeWarning, match="quota 1000"):
        ctrl.service("a")


def test_unbindable_quota_warning_covers_edge_and_set_quota(X):
    """Rejection needs quota < rows+n < max_batch, so quota ==
    max_batch - 1 is just as unbindable as quota == max_batch (the
    off-by-one); and installing an unbindable quota via set_quota AFTER
    the service is memoized must still warn on the next submit."""
    import warnings as _warnings

    reg = ModelRegistry()
    reg.register("a", X, SPEC_A, quota=63, **FIT_KW)    # max_batch - 1
    ctrl = AdmissionController(reg, max_batch=64)
    with pytest.warns(RuntimeWarning, match="cannot bind"):
        ctrl.service("a")

    # a binding quota (<= max_batch - 2) stays silent
    reg2 = ModelRegistry()
    reg2.register("a", X, SPEC_A, quota=62, **FIT_KW)
    ctrl2 = AdmissionController(reg2, max_batch=64)
    ctrl2.service("a")       # fit outside the filter (jax may warn)
    with _warnings.catch_warnings():
        _warnings.simplefilter("error", RuntimeWarning)
        ctrl2.submit("a", _q(1, 4))   # enqueue only: no compute
    ctrl2.drain()

    # set_quota after memoization: the submit path re-checks
    reg2.set_quota("a", 64)
    with pytest.warns(RuntimeWarning, match="cannot bind"):
        ctrl2.submit("a", _q(2, 4))
    ctrl2.drain()


def test_warm_registry_lookup_skips_refingerprint(X, monkeypatch):
    """`serve(model=...)` is documented as a pure name lookup: a warm
    get() must hit the cache through the precomputed recipe key, not
    re-hash the whole training set per request."""
    from repro.serve import model_cache

    reg = ModelRegistry()
    reg.register("a", X, SPEC_A, **FIT_KW)
    sm = reg.get("a")                      # cold: fit + key computation

    calls = {"n": 0}
    real = model_cache.fingerprint_array

    def spy(arr):
        calls["n"] += 1
        return real(arr)

    monkeypatch.setattr(model_cache, "fingerprint_array", spy)
    assert reg.get("a") is sm
    assert routed_serve(model="a", registry=reg) is sm
    assert calls["n"] == 0
    assert reg.cache.hits == 2


def test_quota_yields_to_bucket_fill_flush(X):
    """An admission that reaches max_batch flushes the window instead of
    growing it, so it must be ADMITTED even when window+request exceeds
    the quota — rejecting it would shed traffic that never threatened
    the backlog."""
    reg = ModelRegistry()
    reg.register("a", X, SPEC_A, quota=100, **FIT_KW)
    ctrl = AdmissionController(reg, max_batch=128)
    ctrl.submit("a", _q(1, 90))
    h = ctrl.submit("a", _q(2, 60))    # 150 >= max_batch: flush, not reject
    assert h.done and ctrl.queued_rows("a") == 0
    assert ctrl.rejected.get("a", 0) == 0
    # ...while a request that WOULD sit queued over quota still rejects
    ctrl.submit("a", _q(3, 90))
    with pytest.raises(QuotaExceededError):
        ctrl.submit("a", _q(4, 20))    # 110 queued < max_batch, > quota


def test_rejected_submit_never_triggers_fit(X, counting_fit):
    """Admission decisions run before service resolution: an over-quota
    or malformed request against a COLD model must not pay the fit."""
    reg = ModelRegistry()
    reg.register("a", X, SPEC_A, quota=100, **FIT_KW)
    ctrl = AdmissionController(reg)
    with pytest.raises(QuotaExceededError):
        ctrl.submit("a", _q(1, 150))
    with pytest.raises(ValueError):
        ctrl.submit("a", np.zeros((0, 2), np.float32))
    with pytest.raises(UnknownModelError):
        ctrl.submit("ghost", _q(2, 4))
    assert counting_fit["n"] == 0      # the model is still cold


def test_evict_version_ordering_no_stale_memo(X):
    """The lifecycle version must bump AFTER the cache eviction: a
    consumer racing between the two memoizes at worst (old model, old
    version), which the bump invalidates — never (old, new) forever."""
    reg = ModelRegistry()
    reg.register("a", X, SPEC_A, **FIT_KW)
    ctrl = AdmissionController(reg)
    ctrl.service("a")
    sm_old = reg.get("a")

    real_evict = reg.cache.evict
    raced = {}

    def racing_evict(key):
        # a controller touch sneaking in mid-refresh, BEFORE the entry
        # is dropped: it must not be able to pin the stale model
        raced["svc"] = ctrl.service("a")
        return real_evict(key)

    reg.cache.evict = racing_evict
    try:
        reg.refresh("a")
    finally:
        reg.cache.evict = real_evict
    fresh = ctrl.service("a")
    assert fresh.scorer.model is not sm_old
    assert fresh.scorer.model is reg.get("a").scorer().model


def test_evict_spares_shared_recipe_entry(X, counting_fit):
    """Two names over the identical recipe share one cache entry (by
    design); evicting or unregistering ONE must not cold-start the
    other."""
    reg = ModelRegistry()
    reg.register("a", X, SPEC_A, **FIT_KW)
    reg.register("b", X, SPEC_A, **FIT_KW)      # identical recipe
    sm = reg.get("a")
    assert reg.get("b") is sm and counting_fit["n"] == 1
    assert reg.evict("a") is False              # shared: entry survives
    assert reg.get("b") is sm and counting_fit["n"] == 1
    reg.unregister("a")
    assert reg.get("b") is sm and counting_fit["n"] == 1
    # with "a" gone the recipe is no longer shared: eviction now bites
    assert reg.evict("b") is True
    reg.get("b")
    assert counting_fit["n"] == 2


# -- acceptance: the end-to-end two-model story ------------------------------

def test_end_to_end_two_models_through_admission(X):
    """ISSUE 4 acceptance: two registered models with distinct specs
    served concurrently through the admission controller — every request
    routed to the correct model (scores match that model's direct
    ``BatchScorer.score``), deadline-ordered flushes verified on a fake
    clock, and over-quota submits rejected with the typed error. No
    ``time.sleep`` anywhere."""
    reg = ModelRegistry()
    # quotas strictly below max_batch — at or above it, bucket fill
    # drains the window before a quota could ever bind
    reg.register("tenant-a", X, SPEC_A, quota=300, **FIT_KW)
    reg.register("tenant-b", X, SPEC_B, quota=300, **FIT_KW)
    clock = ManualClock()
    ctrl = AdmissionController(reg, clock=clock, max_batch=512)

    # the two models are genuinely distinct artifacts
    sm_a, sm_b = reg.get("tenant-a"), reg.get("tenant-b")
    assert float(sm_a.spec.kernel.gamma) != float(sm_b.spec.kernel.gamma)

    # interleaved traffic, per-request deadlines: b's window is due first
    reqs = []
    for i in range(6):
        name = ("tenant-a", "tenant-b")[i % 2]
        q = _q(100 + i, 17 + 9 * i)
        deadline = {"tenant-a": 2.0, "tenant-b": 1.0}[name]
        reqs.append((name, q, ctrl.submit(name, q, deadline=deadline)))

    assert ctrl.poll() == 0                      # t=0: nobody is due
    clock.t = 1.0
    ctrl.poll()                                  # only b's deadline hit
    assert all(h.done == (name == "tenant-b") for name, _, h in reqs)
    clock.t = 2.0
    ctrl.poll()
    assert all(h.done for _, _, h in reqs)

    # every request came back from ITS model, bit-for-bit
    for name, q, h in reqs:
        direct = np.asarray(reg.get(name).scorer().score(q))
        np.testing.assert_allclose(np.asarray(h.result()), direct,
                                   rtol=0, atol=0)
        # and the two models disagree on the same rows (routing is real)
        other = ("tenant-a", "tenant-b")[name == "tenant-a"]
        cross = np.asarray(reg.get(other).scorer().score(q))
        assert float(np.max(np.abs(direct - cross))) > 1e-6

    # over-quota traffic is shed with the typed error (200 + 101 rows
    # would stay queued — below max_batch, above the 300-row quota)
    ctrl.submit("tenant-a", _q(900, 200))
    with pytest.raises(QuotaExceededError):
        ctrl.submit("tenant-a", _q(901, 101))
    assert ctrl.rejected["tenant-a"] == 1
    ctrl.drain()

    # per-model stats saw exactly the admitted traffic
    stats = ctrl.stats_dict()
    served_a = sum(b["queries"]
                   for b in stats["tenant-a"]["buckets"].values())
    served_b = sum(b["queries"]
                   for b in stats["tenant-b"]["buckets"].values())
    assert served_a == sum(q.shape[0] for n, q, _ in reqs
                           if n == "tenant-a") + 200
    assert served_b == sum(q.shape[0] for n, q, _ in reqs
                           if n == "tenant-b")
