"""The paper's solver at pod scale: compile the distributed blocked SMO
for m = 1M training points on the single-pod (16x16) and multi-pod
(2x16x16) meshes and report the per-iteration communication profile.

Run standalone (needs 512 host devices BEFORE jax init):

    PYTHONPATH=src python -m benchmarks.smo_pod_scale

Inside `benchmarks.run` (1-device process) it reports from the cached
results file if present.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

RESULTS = "results/smo_pod_scale.json"

_CHILD = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
import jax, jax.numpy as jnp
from repro.core import SlabSpec, rbf
from repro.core.distributed_smo import solve_blocked_distributed
from repro.core.engine import CollectiveLedger
from repro.launch.mesh import make_solver_mesh
from repro.utils import hlo_analysis as H

spec = SlabSpec(nu1=0.5, nu2=0.05, eps=0.5, kernel=rbf(gamma=0.5))
out = []
for multi_pod in (False, True):
    mesh, axes = make_solver_mesh(multi_pod=multi_pod)
    m = 1_048_576
    d = 64
    X = jax.ShapeDtypeStruct((m, d), jnp.float32)
    ledger = CollectiveLedger()   # fills when .lower() traces the solve
    lowered = jax.jit(lambda X: solve_blocked_distributed(
        X, spec, mesh, data_axes=axes, P_pairs=32, tol=1e-4,
        fused_stats=True, ledger=ledger)).lower(X)
    compiled = lowered.compile()
    text = compiled.as_text()
    comps, entry = H._parse_computations(text)
    body = None
    best = -1
    for c in comps.values():
        for inst in c.insts:
            if inst.op == "while":
                cb = H._COND_BODY_RE.search(inst.line)
                if cb and comps.get(cb.group(2)) and \
                        len(comps[cb.group(2)].insts) > best:
                    body = comps[cb.group(2)]
                    best = len(body.insts)
    n_coll = sum(1 for i in body.insts
                 if any(i.op.startswith(k) for k in H.COLLECTIVES)
                 and not i.op.endswith("-done"))
    coll_b = sum(H._collective_operand_bytes(i, mesh.size)[1]
                 for i in body.insts
                 if any(i.op.startswith(k) for k in H.COLLECTIVES)
                 and not i.op.endswith("-done"))
    mem = compiled.memory_analysis()
    out.append({
        "mesh": "2x16x16" if multi_pod else "16x16",
        "m": m, "d": d, "P": 32,
        "m_per_shard": m // (32 if multi_pod else 16),
        "collective_ops_per_iter": n_coll,
        "collective_bytes_per_iter_per_dev": coll_b,
        # the engine's own trace-time accounting hook, for cross-checking
        # the HLO-derived numbers above (and for asserting the O(P d)
        # budget in CI without an HLO parse)
        "ledger_iter_ops": ledger.iteration_ops,
        "ledger_iter_bytes": ledger.iteration_bytes,
        "ledger_init_bytes": ledger.phase_bytes("init"),
        "peak_bytes_per_device": int(mem.argument_size_in_bytes
                                     + mem.output_size_in_bytes
                                     + mem.temp_size_in_bytes
                                     - mem.alias_size_in_bytes),
    })
print(json.dumps(out))
'''


def run():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", _CHILD],
                          capture_output=True, text=True, env=env,
                          timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-1500:])
    rows = json.loads(proc.stdout.strip().splitlines()[-1])
    os.makedirs("results", exist_ok=True)
    with open(RESULTS, "w") as f:
        json.dump(rows, f, indent=1)
    return rows


def main():
    if os.path.exists(RESULTS):
        rows = json.load(open(RESULTS))
    else:
        try:
            rows = run()
        except Exception as e:  # pragma: no cover
            print(f"smo_pod_scale,error,{str(e)[:120]}")
            return
    for r in rows:
        ledger = (f",ledger_iter_bytes={r['ledger_iter_bytes']}"
                  if "ledger_iter_bytes" in r else "")
        print(f"smo_pod_scale,mesh={r['mesh']},m={r['m']},"
              f"m_per_shard={r['m_per_shard']},"
              f"coll_ops_per_iter={r['collective_ops_per_iter']},"
              f"coll_bytes_per_iter={r['collective_bytes_per_iter_per_dev']:.0f},"
              f"peak_gb_per_dev={r['peak_bytes_per_device']/1e9:.3f}"
              f"{ledger}")


if __name__ == "__main__":
    main()
