"""Pallas kernel-autotune sweep: produce BENCH_autotune.json (+ the table).

    PYTHONPATH=src python benchmarks/autotune_kernels.py --quick \
        --precisions f32,bf16 --json BENCH_autotune.json
    PYTHONPATH=src python benchmarks/autotune_kernels.py --quick \
        --precisions f32,bf16 --update-table   # refresh the committed table

Sweeps (block_m, block_n, block_k, depth) per (family, shape, precision)
cell through ``repro.kernels.autotune`` and writes candidate + winner
rows (each with its roofline DMA-vs-compute classification) in the
``results/BENCH_autotune.json`` schema. ``--update-table`` additionally
merges the winners into ``src/repro/kernels/tuned_configs.json`` — the
committed table ``kernels/tiling.resolve_tiles`` consults at trace time.

CI runs ``--quick`` in the kernels-interpret job and diffs the fresh
rows against the committed ``results/BENCH_autotune.json`` baseline via
``benchmarks/check_regression.py`` (>25% slowdown on a gated row, or a
dropped row, fails the job). On CPU the kernels run in interpret mode:
wall numbers are emulation-regression canaries, not TPU projections —
re-run on real hardware to grow the table's "tpu" backend rows
(docs/kernels.md walks through the workflow).
"""
from __future__ import annotations

import argparse
import json

from repro.kernels.autotune import (FULL_CELLS, QUICK_CELLS, sweep,
                                    winners_to_entries, write_table)
from repro.kernels.precision import parse_precisions
from repro.kernels.tiling import TUNED_TABLE_PATH


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--quick", action="store_true",
                      help="CI-sized sweep (default): the tier-1 shapes")
    mode.add_argument("--full", action="store_true",
                      help="larger m / wider d cells for nearest-shape "
                           "interpolation")
    ap.add_argument("--precisions", default="f32",
                    help="comma list of tile precisions (default f32)")
    ap.add_argument("--repeats", type=int, default=5,
                    help="timing repeats per candidate; min is kept "
                         "(default 5)")
    ap.add_argument("--json", default="BENCH_autotune.json",
                    help="where to write the sweep JSON")
    ap.add_argument("--update-table", nargs="?", const=str(TUNED_TABLE_PATH),
                    default=None, metavar="PATH",
                    help="merge winners into the committed tuned table "
                         f"(default path: {TUNED_TABLE_PATH})")
    args = ap.parse_args(argv)

    mode_name = "full" if args.full else "quick"
    cells = FULL_CELLS if args.full else QUICK_CELLS
    result = sweep(cells, mode=mode_name,
                   precisions=parse_precisions(args.precisions),
                   repeats=args.repeats, progress=print)
    with open(args.json, "w") as fh:
        json.dump(result, fh, indent=1)
        fh.write("\n")
    print(f"autotune,{mode_name},backend={result['backend']},"
          f"candidates={len(result['candidates'])},"
          f"winners={len(result['winners'])},json={args.json}")

    if args.update_table is not None:
        doc = write_table(winners_to_entries(result), args.update_table)
        print(f"autotune,table={args.update_table},"
              f"entries={len(doc['entries'])}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
