"""Serving benchmark: cold path vs warm cache + cached bucket executables.

Cold = first ``repro.serve`` on a fresh cache (fit + SV compaction + tile
packing) plus the first score per bucket (compiles the executable).
Warm = the same request stream again: cache hit + cached executables.
Acceptance (ISSUE 2): warm beats cold by >= 5x on the 2000-row toy.

``--precisions`` repeats the whole cold/warm protocol once per Gram tile
precision (each is its own cache entry + packed model) and nests the
per-precision rows under ``per_precision`` in the BENCH JSON — the trend
line for the 16-bit support-stream win (meaningful on TPU; the
interpret-mode CPU numbers only track that the path stays wired).

    PYTHONPATH=src python benchmarks/serving_latency.py [--reduced]
        [--precisions f32,bf16] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

import repro
from repro.core import SlabSpec, rbf
from repro.data import make_toy
from repro.kernels.precision import parse_precisions
from repro.serve import ModelCache, ScoringService

BATCHES = (64, 256, 1024)


def _stream(sm, batches):
    """One scoring pass per batch size; returns per-bucket seconds."""
    svc = ScoringService(sm.scorer())
    out = {}
    for i, n in enumerate(batches):
        q = np.asarray(make_toy(jax.random.PRNGKey(100 + i), n)[0])
        t0 = time.perf_counter()
        jax.block_until_ready(svc.score(q))
        out[n] = time.perf_counter() - t0
    return out


def run(m: int = 2000, batches=BATCHES, tol: float = 1e-3,
        precision: str = "f32") -> dict:
    spec = SlabSpec(nu1=0.5, nu2=0.05, eps=0.5, kernel=rbf(gamma=0.5))
    X, _ = make_toy(jax.random.PRNGKey(0), m)
    cache = ModelCache()

    t0 = time.perf_counter()
    sm = repro.serve(X, spec, cache=cache, tol=tol, P=16,
                     precision=precision)
    cold_first = _stream(sm, batches)
    cold_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    sm2 = repro.serve(X, spec, cache=cache, tol=tol, P=16,
                      precision=precision)
    warm_first = _stream(sm2, batches)
    warm_s = time.perf_counter() - t0

    assert sm2 is sm and cache.hits == 1, "warm pass must hit the cache"
    return {
        "m": m, "n_sv": sm.n_sv, "tol": tol, "precision": precision,
        "cold_s": cold_s, "warm_s": warm_s,
        "speedup": cold_s / warm_s,
        "cold_per_bucket_s": {str(k): v for k, v in cold_first.items()},
        "warm_per_bucket_s": {str(k): v for k, v in warm_first.items()},
    }


def _print_rows(res):
    print(f"serving,m={res['m']},n_sv={res['n_sv']},"
          f"precision={res['precision']},"
          f"cold={res['cold_s']*1e3:.0f}ms,warm={res['warm_s']*1e3:.1f}ms,"
          f"speedup={res['speedup']:.0f}x")
    for b in res["cold_per_bucket_s"]:
        print(f"serving_bucket,b={b},precision={res['precision']},"
              f"cold={res['cold_per_bucket_s'][b]*1e3:.1f}ms,"
              f"warm={res['warm_per_bucket_s'][b]*1e3:.1f}ms")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--reduced", action="store_true",
                    help="small problem for CI smoke (m=500, 2 buckets)")
    ap.add_argument("--precisions", type=str, default="f32",
                    help="comma list of Gram tile precisions to benchmark "
                         "(each runs the full cold/warm protocol)")
    ap.add_argument("--json", type=str, default=None)
    args = ap.parse_args(argv)

    precisions = parse_precisions(args.precisions)
    kwargs = dict(m=500, batches=(64, 256)) if args.reduced else {}
    per_precision = {}
    for p in precisions:
        per_precision[p] = run(precision=p, **kwargs)
        _print_rows(per_precision[p])
        if per_precision[p]["speedup"] < 5:
            print(f"WARNING: warm speedup "
                  f"{per_precision[p]['speedup']:.1f}x below the 5x "
                  f"acceptance bar at precision={p}")

    # top level keeps the first (f32 by convention) run's schema so older
    # trend consumers of BENCH_serving.json keep working
    res = dict(per_precision[precisions[0]])
    res["per_precision"] = per_precision
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(res, fh, indent=2)
        print(f"wrote {args.json}")
    return res


if __name__ == "__main__":
    main()
