"""Serving benchmark: cold path vs warm cache + cached bucket executables.

Cold = first ``repro.serve`` on a fresh cache (fit + SV compaction + tile
packing) plus the first score per bucket (compiles the executable).
Warm = the same request stream again: cache hit + cached executables.
Acceptance (ISSUE 2): warm beats cold by >= 5x on the 2000-row toy.

``--precisions`` repeats the whole cold/warm protocol once per Gram tile
precision (each is its own cache entry + packed model) and nests the
per-precision rows under ``per_precision`` in the BENCH JSON — the trend
line for the 16-bit support-stream win (meaningful on TPU; the
interpret-mode CPU numbers only track that the path stays wired).

A two-model fleet (registry + deadline-aware admission controller) runs
once at the lead precision and lands under ``multi_model`` in the JSON:
per-model cold fit, deadline-driven stream throughput, per-model
per-bucket stats, and a routing-parity spot check.

    PYTHONPATH=src python benchmarks/serving_latency.py [--reduced]
        [--precisions f32,bf16] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

import repro
from repro.core import SlabSpec, linear, rbf
from repro.data import make_toy
from repro.kernels.precision import parse_precisions
from repro.serve import (AdmissionController, ModelCache, ModelRegistry,
                         ScoringService)

BATCHES = (64, 256, 1024)


def _stream(sm, batches):
    """One scoring pass per batch size; returns per-bucket seconds."""
    svc = ScoringService(sm.scorer())
    out = {}
    for i, n in enumerate(batches):
        q = np.asarray(make_toy(jax.random.PRNGKey(100 + i), n)[0])
        t0 = time.perf_counter()
        jax.block_until_ready(svc.score(q))
        out[n] = time.perf_counter() - t0
    return out


def run(m: int = 2000, batches=BATCHES, tol: float = 1e-3,
        precision: str = "f32") -> dict:
    spec = SlabSpec(nu1=0.5, nu2=0.05, eps=0.5, kernel=rbf(gamma=0.5))
    X, _ = make_toy(jax.random.PRNGKey(0), m)
    cache = ModelCache()

    t0 = time.perf_counter()
    sm = repro.serve(X, spec, cache=cache, tol=tol, P=16,
                     precision=precision)
    cold_first = _stream(sm, batches)
    cold_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    sm2 = repro.serve(X, spec, cache=cache, tol=tol, P=16,
                      precision=precision)
    warm_first = _stream(sm2, batches)
    warm_s = time.perf_counter() - t0

    assert sm2 is sm and cache.hits == 1, "warm pass must hit the cache"
    return {
        "m": m, "n_sv": sm.n_sv, "tol": tol, "precision": precision,
        "cold_s": cold_s, "warm_s": warm_s,
        "speedup": cold_s / warm_s,
        "cold_per_bucket_s": {str(k): v for k, v in cold_first.items()},
        "warm_per_bucket_s": {str(k): v for k, v in warm_first.items()},
    }


def run_multi_model(m: int = 500, requests: int = 16,
                    deadline_ms: float = 20.0, tol: float = 1e-3,
                    precision: str = "f32") -> dict:
    """Two-model fleet through the registry + admission controller.

    Measures the multi-model serving front-end end to end: per-model
    cold fit (fit-on-first-use via the registry), then a deadline-driven
    interleaved stream — every submit is followed by a ``poll()`` so
    flushes happen exactly when deadline pressure (observed per-bucket
    latency vs earliest deadline) says they must. Routing correctness is
    spot-checked against each model's direct scorer.
    """
    X, _ = make_toy(jax.random.PRNGKey(0), m)
    registry = ModelRegistry()
    registry.register(
        "slab-rbf", X,
        SlabSpec(nu1=0.5, nu2=0.05, eps=0.5, kernel=rbf(gamma=0.5)),
        tol=tol, P=16, precision=precision)
    registry.register(
        "slab-linear", X,
        SlabSpec(nu1=0.3, nu2=0.05, eps=0.5, kernel=linear()),
        tol=tol, P=16, precision=precision)
    names = registry.names()

    ctrl = AdmissionController(registry, max_wait_s=0.05)
    cold = {}
    for name in names:
        t0 = time.perf_counter()
        ctrl.service(name).scorer.warmup()      # fit + compile, once
        cold[name] = time.perf_counter() - t0

    handles = []
    t0 = time.perf_counter()
    for i in range(requests):
        name = names[i % len(names)]
        q = np.asarray(make_toy(jax.random.PRNGKey(200 + i),
                                32 + 16 * (i % 5))[0])
        handles.append((name, q, ctrl.submit(
            name, q, deadline=ctrl.clock() + deadline_ms / 1e3)))
        ctrl.poll()
    ctrl.drain()
    stream_s = time.perf_counter() - t0

    max_err = 0.0
    for name, q, h in handles[:4]:      # routing spot check, kept cheap
        direct = np.asarray(registry.get(name).scorer().score(q))
        max_err = max(max_err, float(np.max(np.abs(
            np.asarray(h.result()) - direct))))
    assert max_err < 1e-5, f"routing parity broke: {max_err}"

    queries = sum(h.n for _, _, h in handles)
    return {
        "m": m, "precision": precision, "models": list(names),
        "requests": requests, "queries": queries,
        "deadline_ms": deadline_ms, "stream_s": stream_s,
        "routing_max_abs_err": max_err,
        "cold_s": cold,
        "per_model": ctrl.stats_dict(),
    }


def _print_rows(res):
    print(f"serving,m={res['m']},n_sv={res['n_sv']},"
          f"precision={res['precision']},"
          f"cold={res['cold_s']*1e3:.0f}ms,warm={res['warm_s']*1e3:.1f}ms,"
          f"speedup={res['speedup']:.0f}x")
    for b in res["cold_per_bucket_s"]:
        print(f"serving_bucket,b={b},precision={res['precision']},"
              f"cold={res['cold_per_bucket_s'][b]*1e3:.1f}ms,"
              f"warm={res['warm_per_bucket_s'][b]*1e3:.1f}ms")


def _print_multi_rows(res):
    for name in res["models"]:
        stats = res["per_model"][name]
        served = sum(b["queries"] for b in stats["buckets"].values())
        print(f"serving_multimodel,model={name},"
              f"precision={res['precision']},"
              f"cold={res['cold_s'][name]*1e3:.0f}ms,"
              f"queries={served},rejected={stats['rejected']},"
              f"routing_max_abs_err={res['routing_max_abs_err']:.2e}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--reduced", action="store_true",
                    help="small problem for CI smoke (m=500, 2 buckets)")
    ap.add_argument("--precisions", type=str, default="f32",
                    help="comma list of Gram tile precisions to benchmark "
                         "(each runs the full cold/warm protocol)")
    ap.add_argument("--json", type=str, default=None)
    args = ap.parse_args(argv)

    precisions = parse_precisions(args.precisions)
    kwargs = dict(m=500, batches=(64, 256)) if args.reduced else {}
    per_precision = {}
    for p in precisions:
        per_precision[p] = run(precision=p, **kwargs)
        _print_rows(per_precision[p])
        if per_precision[p]["speedup"] < 5:
            print(f"WARNING: warm speedup "
                  f"{per_precision[p]['speedup']:.1f}x below the 5x "
                  f"acceptance bar at precision={p}")

    # top level keeps the first (f32 by convention) run's schema so older
    # trend consumers of BENCH_serving.json keep working
    res = dict(per_precision[precisions[0]])
    res["per_precision"] = per_precision

    # multi-model registry + admission rows (once, at the lead precision)
    multi_kwargs = (dict(m=300, requests=8) if args.reduced
                    else dict(m=500, requests=16))
    res["multi_model"] = run_multi_model(precision=precisions[0],
                                         **multi_kwargs)
    _print_multi_rows(res["multi_model"])
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(res, fh, indent=2)
        print(f"wrote {args.json}")
    return res


if __name__ == "__main__":
    main()
