"""Pallas-kernel microbenchmarks (interpret mode on CPU; the BlockSpec
tiling is the TPU contract — wall numbers here are CPU-emulation only and
serve as regression canaries, not TPU projections)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import rbf
from repro.kernels import decision, fupdate, gram
from repro.kernels.gram.ref import gram_ref


def _timed(fn, n=3):
    out = fn()
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def run():
    kern = rbf(gamma=0.5)
    key = jax.random.PRNGKey(0)
    X = jax.random.normal(key, (1024, 128), jnp.float32)
    rows = []
    t = _timed(lambda: gram(X, X, kern, interpret=True))
    rows.append(("gram_1024x1024x128_pallas", t))
    t = _timed(lambda: gram_ref(X, X, kind="rbf", gamma=0.5))
    rows.append(("gram_1024x1024x128_jnp_ref", t))
    f = jnp.zeros((1024,))
    dl = jnp.ones((16,)) * 0.01
    t = _timed(lambda: fupdate(X, X[:16], dl, f, kern, interpret=True))
    rows.append(("fupdate_1024x128_P16_pallas", t))
    gv = jnp.ones((1024,)) * 0.001
    t = _timed(lambda: decision(X[:256], X, gv, 0.1, 0.9, kern,
                                interpret=True))
    rows.append(("decision_256q_1024sv_pallas", t))
    return rows


def main():
    for name, t in run():
        print(f"{name},{t*1e6:.0f}us,interpret=True")


if __name__ == "__main__":
    main()
