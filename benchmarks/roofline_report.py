"""Render the repo's roofline rows from the committed results/ layout.

Two sections, each skipped cleanly when its input is absent:

* the launch-layer dry-run sweep (``results/dryrun/*.json``, produced by
  ``python -m repro.launch.dryrun --all --out results/dryrun``): one row
  per (arch x shape x mesh) with the three roofline terms, the dominant
  bottleneck, MODEL_FLOPS/HLO_FLOPs ratio, per-device memory and the
  fit-16GB flag;
* the kernel-autotune winners (``results/BENCH_autotune.json``, produced
  by ``benchmarks/autotune_kernels.py``): one row per
  (family x shape x precision) with the winning tile config, its
  analytic FLOPs / HBM bytes and the DMA-vs-compute classification
  (docs/kernels.md explains how to read these).

Usage: ``PYTHONPATH=src python benchmarks/roofline_report.py``;
``DRYRUN_RESULTS`` / ``AUTOTUNE_RESULTS`` override the input paths.
"""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.environ.get("DRYRUN_RESULTS", "results/dryrun")
AUTOTUNE = os.environ.get("AUTOTUNE_RESULTS", "results/BENCH_autotune.json")


def load(results_dir=RESULTS):
    rows = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def fmt_row(r):
    if r["status"] == "skipped":
        return (f"{r['arch']},{r['shape']},{r['mesh']},SKIPPED,"
                f"reason={r['reason'][:60]}")
    if r["status"] == "failed":
        return (f"{r['arch']},{r['shape']},{r['mesh']},FAILED,"
                f"{r['error'][:80]}")
    t = r["roofline"]
    mem = r["memory"]
    a = r["analytic"]
    return (f"{r['arch']},{r['shape']},{r['mesh']},"
            f"compute={t['compute_s']:.4f}s,memory={t['memory_s']:.4f}s,"
            f"collective={t['collective_s']:.4f}s,dom={t['dominant']},"
            f"useful_ratio={a['useful_flops_ratio'] and round(a['useful_flops_ratio'],3)},"
            f"roofline_frac={t['mfu_fraction']:.3f},"
            f"peak_gb={mem['peak_bytes_per_device']/1e9:.2f},"
            f"fits16gb={mem['fits_16gb_hbm']}")


def fmt_autotune_row(w):
    blocks = "/".join(f"{k[6:]}{w[k]}" for k in
                      ("block_m", "block_n", "block_k")
                      if w.get(k) is not None)
    intensity = w["flops"] / w["hbm_bytes"] if w["hbm_bytes"] else 0.0
    return (f"{w['family']},m{w['m']}xn{w['n']}xd{w['d']},{w['precision']},"
            f"{blocks},x{w['depth']},bound={w['bound']},"
            f"flops={w['flops']:.3g},hbm={w['hbm_bytes']:.3g},"
            f"intensity={intensity:.1f}flop/B,best={w['best_s']*1e6:.0f}us")


def main():
    rows = load()
    if not rows:
        print("roofline_report,dryrun,no_results_yet,"
              "run: python -m repro.launch.dryrun --all --out results/dryrun")
    else:
        ok = sum(1 for r in rows if r["status"] == "ok")
        sk = sum(1 for r in rows if r["status"] == "skipped")
        fl = sum(1 for r in rows if r["status"] == "failed")
        print(f"roofline_report,dryrun,cells={len(rows)},ok={ok},"
              f"skipped={sk},failed={fl}")
        for r in rows:
            print(fmt_row(r))

    if not os.path.exists(AUTOTUNE):
        print("roofline_report,autotune,no_results_yet,"
              "run: python benchmarks/autotune_kernels.py --quick "
              f"--json {AUTOTUNE}")
        return
    with open(AUTOTUNE) as f:
        doc = json.load(f)
    winners = doc.get("winners", [])
    print(f"roofline_report,autotune,backend={doc.get('backend')},"
          f"mode={doc.get('mode')},winners={len(winners)}")
    for w in winners:
        print(fmt_autotune_row(w))


if __name__ == "__main__":
    main()
