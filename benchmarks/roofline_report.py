"""Render the dry-run sweep (results/dryrun/*.json) as the roofline table.

One row per (arch x shape x mesh): the three terms, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs ratio, per-device memory, and fit-16GB flag. This is
the generator for EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.environ.get("DRYRUN_RESULTS", "results/dryrun")


def load(results_dir=RESULTS):
    rows = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def fmt_row(r):
    if r["status"] == "skipped":
        return (f"{r['arch']},{r['shape']},{r['mesh']},SKIPPED,"
                f"reason={r['reason'][:60]}")
    if r["status"] == "failed":
        return (f"{r['arch']},{r['shape']},{r['mesh']},FAILED,"
                f"{r['error'][:80]}")
    t = r["roofline"]
    mem = r["memory"]
    a = r["analytic"]
    return (f"{r['arch']},{r['shape']},{r['mesh']},"
            f"compute={t['compute_s']:.4f}s,memory={t['memory_s']:.4f}s,"
            f"collective={t['collective_s']:.4f}s,dom={t['dominant']},"
            f"useful_ratio={a['useful_flops_ratio'] and round(a['useful_flops_ratio'],3)},"
            f"roofline_frac={t['mfu_fraction']:.3f},"
            f"peak_gb={mem['peak_bytes_per_device']/1e9:.2f},"
            f"fits16gb={mem['fits_16gb_hbm']}")


def main():
    rows = load()
    if not rows:
        print("roofline_report,no_results_yet,"
              "run: python -m repro.launch.dryrun --all --out results/dryrun")
        return
    ok = sum(1 for r in rows if r["status"] == "ok")
    sk = sum(1 for r in rows if r["status"] == "skipped")
    fl = sum(1 for r in rows if r["status"] == "failed")
    print(f"roofline_report,cells={len(rows)},ok={ok},skipped={sk},failed={fl}")
    for r in rows:
        print(fmt_row(r))


if __name__ == "__main__":
    main()
