"""Paper Figs 1-2: the recovered slab on the 2-D toy set, as data.

Fig 1: m=1000, nu1=0.5, nu2=0.01, eps=2/3.
Fig 2: m=2000, nu1=0.2, nu2=0.08, eps=1/2.
For the linear kernel the primal normal is w = sum_i gamma_i x_i; the two
hyperplanes are {w.x = rho1} and {w.x = rho2}.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.ocssvm_paper import FIG2_SPEC, PAPER_SPEC
from repro.core import mcc, solve_smo
from repro.data import make_toy


def run():
    out = []
    for name, m, spec in (("fig1", 1000, PAPER_SPEC),
                          ("fig2", 2000, FIG2_SPEC)):
        X, y = make_toy(jax.random.PRNGKey(0), m)
        res = solve_smo(X, spec, selection="paper", tol=1e-3,
                        max_iters=200_000)
        w = res.model.gamma @ res.model.X          # (d,) primal normal
        out.append({
            "name": name, "m": m,
            "w": [float(v) for v in w],
            "rho1": float(res.model.rho1), "rho2": float(res.model.rho2),
            "slab_width": float(res.model.rho2 - res.model.rho1),
            "iters": int(res.iters),
            "converged": bool(res.converged),
            "mcc": float(mcc(y, res.model.predict(X))),
            "n_sv": int(jnp.sum(jnp.abs(res.model.gamma) > 1e-7)),
        })
    return out


def main():
    for r in run():
        print(f"{r['name']},m={r['m']},w=({r['w'][0]:.4f},{r['w'][1]:.4f}),"
              f"rho1={r['rho1']:.4f},rho2={r['rho2']:.4f},"
              f"width={r['slab_width']:.4f},mcc={r['mcc']:.3f},"
              f"sv={r['n_sv']},iters={r['iters']}")


if __name__ == "__main__":
    main()
