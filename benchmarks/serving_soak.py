"""Async-serving soak: many writers x many models through the driver.

The serving benchmark measures one-thread throughput; this one measures
the async front-end under contention — ``--writers`` threads (default 8)
submit deadline-carrying requests for ``--models`` registered models
(default 2) while a single background ``AsyncDriver`` flushes on
deadline pressure. Nothing here polls: if the driver's wake-on-earliest-
deadline loop is wrong, requests miss their deadlines and the gate
below fails.

Per-request latency is measured submit -> done-callback (the callback
fires when the request's flush lands), against the absolute deadline on
the controller's clock. The BENCH JSON carries the tail:

* ``p50_s`` / ``p95_s`` / ``p99_s`` — gated ratio-wise like every
  timing. The tail is deadline-bound (a window flushes when waiting
  longer would miss its earliest deadline), so p99 tracks the
  configured ``--deadline-s``, stable enough to gate.
* ``deadline_miss_rate`` — gated by ``check_regression.py`` as an
  ABSOLUTE ceiling (``*_rate`` rule): the committed baseline is 0, so
  the first CI miss fails the job. Means alone don't gate tails.
* ``shm`` — the cross-process registry parity row: the lead model is
  published to shared memory, re-attached, and scored; ``parity`` is
  True only for bitwise-equal scores.

Estimates are seeded before the clock starts (one warm scoring pass per
bucket per model, after ``warmup()`` so nothing is recorded cold) —
deadline policy needs observed latencies, and a soak that guessed them
would measure the fallback constant, not the driver.

    PYTHONPATH=src python benchmarks/serving_soak.py [--reduced]
        [--writers 8] [--models 2] [--deadline-s 0.75] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import threading
import time

import jax
import numpy as np

from repro.core import SlabSpec, linear, rbf
from repro.data import make_toy
from repro.serve import (AdmissionController, AsyncDriver, ModelRegistry,
                         attach, publish)

SEED_BUCKETS = (64, 256, 1024)


def _build_registry(n_models: int, m: int, tol: float) -> ModelRegistry:
    X, _ = make_toy(jax.random.PRNGKey(0), m)
    specs = [SlabSpec(nu1=0.5, nu2=0.05, eps=0.5, kernel=rbf(gamma=0.5)),
             SlabSpec(nu1=0.3, nu2=0.05, eps=0.5, kernel=linear())]
    reg = ModelRegistry()
    for i in range(n_models):
        spec = specs[i % len(specs)]
        if i >= len(specs):
            spec = SlabSpec(nu1=spec.nu1, nu2=spec.nu2,
                            eps=spec.eps + 0.05 * (i // len(specs)),
                            kernel=spec.kernel)
        reg.register(f"soak-{i}", X, spec, tol=tol, P=16)
    return reg


def _prewarm(ctrl: AdmissionController, names, max_batch: int,
             pool: dict) -> None:
    """Fit + compile + recorded warm observations per bucket per model.

    Two rounds: single scores seed every bucket the traffic can touch,
    then two traffic-shaped windows (many coalesced requests through
    ``flush_model``) refresh the big-bucket means with launches recorded
    in real flush context — the deadline estimate reads those means, and
    seeding them from single-request launches alone would understate
    what a soak window costs to serve."""
    sizes = sorted(pool)
    for name in names:
        svc = ctrl.service(name)
        svc.warmup()
        for b in SEED_BUCKETS:
            if b > max_batch:
                break
            q = np.asarray(make_toy(jax.random.PRNGKey(1000 + b), b)[0])
            jax.block_until_ready(svc.score(q))
        for _ in range(2):
            for i in range(32):
                ctrl.submit(name, pool[sizes[i % len(sizes)]])
            ctrl.flush_model(name)


def _percentiles(latencies) -> dict:
    lat = np.asarray(latencies, dtype=np.float64)
    return {"p50_s": float(np.percentile(lat, 50)),
            "p95_s": float(np.percentile(lat, 95)),
            "p99_s": float(np.percentile(lat, 99))}


def _shm_parity(ctrl: AdmissionController, name: str) -> dict:
    """Publish the model, attach it back, compare scores bitwise."""
    sm = ctrl.registry.get(name)
    key = f"soak-parity-{os.getpid()}"
    q = np.asarray(make_toy(jax.random.PRNGKey(7), 96)[0])
    want = np.asarray(sm.scorer().score(q))
    with publish(sm, key):
        sm2, lease = attach(key)
        with lease:
            got = np.asarray(sm2.scorer().score(q))
    identical = bool(np.array_equal(want, got))
    return {"parity": identical, "n_sv": sm.n_sv,
            "max_abs_err": float(np.max(np.abs(want - got)))}


def run(n_models: int = 2, writers: int = 8, requests_per_writer: int = 24,
        m: int = 500, deadline_s: float = 0.75, rows_lo: int = 8,
        rows_hi: int = 32, tol: float = 1e-3,
        max_batch: int = 1024) -> dict:
    registry = _build_registry(n_models, m, tol)
    names = registry.names()
    # safety_factor 6: the earliest-deadline request is served last-
    # minute by construction (the whole point of deadline-pressure
    # coalescing), so the factor is its only slack — it must cover
    # scheduler jitter AND one other model's flush, which the single
    # driver thread may run first when deadlines collide.
    ctrl = AdmissionController(registry, max_batch=max_batch,
                               fallback_latency_s=0.05, safety_factor=6.0)

    # Queries are pre-generated: make_toy inside the writer loop would
    # trace/compile one executable per distinct row count while the
    # clock runs, and that GIL-heavy burst starves the driver thread —
    # the soak would measure jax compilation, not the serving path.
    pool = {n: np.asarray(make_toy(jax.random.PRNGKey(10_000 + n), n)[0])
            for n in range(rows_lo, rows_hi + 1)}
    _prewarm(ctrl, names, max_batch, pool)

    records = []                 # (model, latency_s, missed)
    errors = []
    rec_lock = threading.Lock()
    total = writers * requests_per_writer

    def writer(wid: int) -> None:
        rng = np.random.default_rng(wid)
        for i in range(requests_per_writer):
            name = names[int(rng.integers(len(names)))]
            n = int(rng.integers(rows_lo, rows_hi + 1))
            q = pool[n]
            t0 = ctrl.clock()
            deadline = t0 + deadline_s

            def _done(h, t0=t0, deadline=deadline, name=name):
                t1 = ctrl.clock()
                with rec_lock:
                    if h._error is not None:
                        errors.append(repr(h._error))
                    records.append((name, t1 - t0, t1 > deadline))

            ctrl.submit(name, q, deadline=deadline).add_done_callback(_done)
            time.sleep(float(rng.uniform(0.0005, 0.003)))

    t_start = time.perf_counter()
    with AsyncDriver(ctrl):
        threads = [threading.Thread(target=writer, args=(w,), daemon=True)
                   for w in range(writers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        deadline_wall = time.monotonic() + max(60.0, 4 * deadline_s)
        while time.monotonic() < deadline_wall:
            with rec_lock:
                if len(records) >= total:
                    break
            time.sleep(0.01)
        # context exit drains (nothing should be left: the driver owns
        # every pending deadline) and stops the driver
    soak_s = time.perf_counter() - t_start

    if len(records) != total:
        raise RuntimeError(f"soak lost requests: {len(records)}/{total} "
                           f"resolved (driver stalled?)")
    if errors:
        raise RuntimeError(f"soak flush errors: {errors[:3]}")

    stats = ctrl.stats_dict()
    per_model = {}
    for name in names:
        rows = [(lat, miss) for mdl, lat, miss in records if mdl == name]
        ws = stats[name]["windows"]
        per_model[name] = {
            "requests": len(rows),
            **_percentiles([lat for lat, _ in rows]),
            "deadline_miss_rate": (sum(miss for _, miss in rows)
                                   / max(1, len(rows))),
            "windows": ws,
            "mean_fill_rows": (ws["flushed_rows"] / ws["flushed"]
                               if ws["flushed"] else 0.0),
        }
    misses = sum(miss for _, _, miss in records)
    return {
        "models": list(names), "writers": writers,
        "requests": total, "deadline_s": deadline_s, "soak_s": soak_s,
        **_percentiles([lat for _, lat, _ in records]),
        "deadline_misses": misses,
        "deadline_miss_rate": misses / total,
        "per_model": per_model,
        "shm": _shm_parity(ctrl, names[0]),
    }


def _print_rows(res: dict) -> None:
    print(f"soak,models={len(res['models'])},writers={res['writers']},"
          f"requests={res['requests']},deadline={res['deadline_s']*1e3:.0f}ms,"
          f"p50={res['p50_s']*1e3:.1f}ms,p99={res['p99_s']*1e3:.1f}ms,"
          f"miss_rate={res['deadline_miss_rate']:.4f}")
    for name, row in res["per_model"].items():
        print(f"soak_model,model={name},requests={row['requests']},"
              f"p99={row['p99_s']*1e3:.1f}ms,"
              f"miss_rate={row['deadline_miss_rate']:.4f},"
              f"windows={row['windows']['flushed']}/"
              f"{row['windows']['opened']},"
              f"mean_fill={row['mean_fill_rows']:.1f}")
    shm = res["shm"]
    print(f"soak_shm,parity={shm['parity']},n_sv={shm['n_sv']},"
          f"max_abs_err={shm['max_abs_err']:.2e}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--reduced", action="store_true",
                    help="small problem for CI smoke (fewer requests, "
                         "smaller fit; writer/model counts keep the "
                         "contention shape)")
    ap.add_argument("--writers", type=int, default=8)
    ap.add_argument("--models", type=int, default=2)
    ap.add_argument("--deadline-s", type=float, default=0.75)
    ap.add_argument("--json", type=str, default=None)
    args = ap.parse_args(argv)

    kwargs = dict(n_models=args.models, writers=args.writers,
                  deadline_s=args.deadline_s)
    if args.reduced:
        kwargs.update(m=300, requests_per_writer=8, rows_hi=16)
    res = run(**kwargs)
    _print_rows(res)
    if res["deadline_misses"]:
        print(f"WARNING: {res['deadline_misses']} deadline misses "
              f"({res['deadline_miss_rate']:.2%}) — the regression gate "
              f"fails on any miss against a zero baseline")
    if not res["shm"]["parity"]:
        print("WARNING: shm re-attach was NOT bitwise identical")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(res, fh, indent=2)
        print(f"wrote {args.json}")
    return res


if __name__ == "__main__":
    main()
