"""Streaming refresh benchmark: warm ``fit_update`` vs cold re-fit.

The ISSUE-8 acceptance story in numbers: append a 5% row delta to an
already-fitted set and re-solve three ways —

* ``cold``  — ``repro.fit`` from scratch on the extended set;
* ``warm``  — ``repro.fit_update`` seeded from the prior fit's
  ``SolverArtifact`` (row matching + f-cache reconcile + delta-scaled
  working set);
* ``registry`` — the serving-facing path: ``ModelRegistry.refresh``
  with ``append=``, which adds the drift gate, the O(Δm) re-key and the
  pack on top of the warm solve (plus one forced-cold refresh so the
  routed-vs-forced costs sit side by side in the JSON).

Iteration counts are the portable signal (interpret-mode CPU timings
only track that the path stays wired); ``iters_ratio`` is the <= 0.25
acceptance bound asserted by ``tests/test_streaming.py``.

    PYTHONPATH=src python benchmarks/streaming_refresh.py [--reduced]
        [--precisions f32,bf16] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

import repro
from repro.core import SlabSpec, engine, rbf
from repro.data import make_toy
from repro.kernels.precision import parse_precisions
from repro.serve import ModelRegistry


def _spec():
    return SlabSpec(nu1=0.5, nu2=0.05, eps=0.5, kernel=rbf(gamma=0.5))


def _data(m: int, n_app: int):
    X = np.asarray(make_toy(jax.random.PRNGKey(0), m + n_app)[0],
                   np.float32)
    return X[:m], X


def _inband(X, n):
    """In-distribution fresh rows: jittered training rows (fresh content
    hashes, same distribution — keeps the drift gate on the warm route,
    which is the path this benchmark is pricing)."""
    rng = np.random.default_rng(1)
    return np.asarray(X[:n] + rng.normal(0, 1e-3, (n, X.shape[1])),
                      np.float32)


def run(m: int = 2000, delta_frac: float = 0.05, tol: float = 1e-4,
        precision: str = "f32") -> dict:
    spec = _spec()
    n_app = max(1, int(m * delta_frac))
    X_prev, X_new = _data(m, n_app)

    t0 = time.perf_counter()
    prev = repro.fit(X_prev, spec, strategy="blocked", tol=tol,
                     precision=precision)
    prev_fit_s = time.perf_counter() - t0
    art = engine.artifact_from_result(prev, precision=precision)

    t0 = time.perf_counter()
    cold = repro.fit(X_new, spec, strategy="blocked", tol=tol,
                     precision=precision)
    cold_s = time.perf_counter() - t0

    stats: dict = {}
    t0 = time.perf_counter()
    warm = repro.fit_update(art, X_new, strategy="blocked", tol=tol,
                            precision=precision, stats_out=stats)
    warm_s = time.perf_counter() - t0
    assert stats["mode"] == "warm" and warm.converged, stats

    return {
        "m": m, "precision": precision, "n_app": n_app, "tol": tol,
        "prev_fit_s": prev_fit_s, "cold_s": cold_s, "warm_s": warm_s,
        "cold_iters": int(cold.iters), "warm_iters": int(warm.iters),
        "iters_ratio": int(warm.iters) / int(cold.iters),
        "speedup": cold_s / warm_s,
        "overlap_frac": stats["overlap_frac"], "warm_P": stats["P"],
    }


def run_registry(m: int = 500, delta_frac: float = 0.05,
                 tol: float = 1e-3, precision: str = "f32") -> dict:
    """The serving-facing refresh: drift gate + O(Δm) re-key + pack."""
    spec = _spec()
    n_app = max(1, int(m * delta_frac))
    X_prev, _ = _data(m, n_app)
    reg = ModelRegistry()
    reg.register("stream", X_prev, spec, strategy="blocked", tol=tol,
                 precision=precision)

    t0 = time.perf_counter()
    reg.get("stream")
    first_fit_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    reg.refresh("stream", append=_inband(X_prev, n_app))
    refresh_warm_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    reg.refresh("stream", mode="cold")
    refresh_cold_s = time.perf_counter() - t0

    st = reg.refresh_stats("stream")
    assert st["modes"]["warm"] >= 1, st
    return {
        "m": m, "precision": precision, "n_app": n_app,
        "first_fit_s": first_fit_s,
        "refresh_warm_s": refresh_warm_s,
        "refresh_cold_s": refresh_cold_s,
        "refresh_modes": dict(st["modes"]),
        "drift_statistic": (st["last_drift"].statistic
                            if st["last_drift"] is not None else None),
    }


def _print_rows(res):
    print(f"streaming,m={res['m']},precision={res['precision']},"
          f"n_app={res['n_app']},cold_iters={res['cold_iters']},"
          f"warm_iters={res['warm_iters']},"
          f"iters_ratio={res['iters_ratio']:.3f},"
          f"cold={res['cold_s']*1e3:.0f}ms,warm={res['warm_s']*1e3:.0f}ms")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--reduced", action="store_true",
                    help="small problem for CI smoke (m=400)")
    ap.add_argument("--precisions", type=str, default="f32",
                    help="comma list of Gram tile precisions (each runs "
                         "the full cold/warm protocol)")
    ap.add_argument("--json", type=str, default=None)
    args = ap.parse_args(argv)

    precisions = parse_precisions(args.precisions)
    kwargs = dict(m=400, tol=1e-3) if args.reduced else {}
    per_precision = {}
    for p in precisions:
        per_precision[p] = run(precision=p, **kwargs)
        _print_rows(per_precision[p])
        if per_precision[p]["iters_ratio"] > 0.25:
            print(f"WARNING: warm/cold iteration ratio "
                  f"{per_precision[p]['iters_ratio']:.2f} above the "
                  f"0.25 acceptance bound at precision={p}")

    res = dict(per_precision[precisions[0]])
    res["per_precision"] = per_precision
    reg_kwargs = dict(m=200) if args.reduced else {}
    res["registry"] = run_registry(precision=precisions[0], **reg_kwargs)
    print(f"streaming_registry,m={res['registry']['m']},"
          f"warm={res['registry']['refresh_warm_s']*1e3:.0f}ms,"
          f"cold={res['registry']['refresh_cold_s']*1e3:.0f}ms,"
          f"modes={res['registry']['refresh_modes']}")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(res, fh, indent=2)
        print(f"wrote {args.json}")
    return res


if __name__ == "__main__":
    main()
