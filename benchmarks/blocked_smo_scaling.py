"""Beyond-paper: blocked-SMO scaling with pair-block size P.

The paper's claim is SMO scales better than generic QP with m; the
TPU-native blocked solver additionally turns the per-iteration work into
rank-2P matmuls. This benchmark sweeps P at fixed m and m at fixed P
(RBF kernel — the non-degenerate regime).
"""
from __future__ import annotations

import time

import jax

import repro
from repro.core import SlabSpec, rbf
from repro.data import make_toy


def _timed(fn):
    out = fn()
    jax.block_until_ready(out.model.gamma)
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out.model.gamma)
    return out, time.perf_counter() - t0


def run():
    spec = SlabSpec(nu1=0.5, nu2=0.05, eps=0.5, kernel=rbf(gamma=0.5))
    rows = []
    m = 2048
    X, _ = make_toy(jax.random.PRNGKey(0), m)
    # gram_mode pinned so the sweep stays apples-to-apples (fit's auto
    # heuristic would switch provider with m).
    for P in (1, 4, 16, 64):
        res, t = _timed(lambda: repro.fit(X, spec, strategy="blocked", P=P,
                                          gram_mode="on_the_fly", tol=1e-3,
                                          max_outer=50_000))
        rows.append({"sweep": "P", "m": m, "P": P, "time_s": t,
                     "iters": int(res.iters),
                     "converged": bool(res.converged)})
    for m2 in (512, 1024, 2048, 4096):
        X2, _ = make_toy(jax.random.PRNGKey(0), m2)
        res, t = _timed(lambda: repro.fit(X2, spec, strategy="blocked", P=16,
                                          gram_mode="on_the_fly", tol=1e-3,
                                          max_outer=50_000))
        rows.append({"sweep": "m", "m": m2, "P": 16, "time_s": t,
                     "iters": int(res.iters),
                     "converged": bool(res.converged)})
    return rows


def main():
    for r in run():
        print(f"blocked_scaling,{r['sweep']},m={r['m']},P={r['P']},"
              f"time={r['time_s']*1e6:.0f}us,iters={r['iters']},"
              f"converged={r['converged']}")


if __name__ == "__main__":
    main()
