"""Paper Table 1: training time and MCC vs training-set size.

Protocol (Section 4): linear kernel, nu1=0.5, nu2=0.01, eps=2/3,
m in {500, 1000, 2000, 5000}. We time the paper-faithful SMO, the MVP
variant, the blocked TPU-native solver, and the generic-QP baseline the
paper compares against. Paper's reported times (their hardware):
0.35 / 0.67 / 2.1 / 5.91 s; MCC 0.07 / 0.13 / 0.26 / 0.33.

``--precisions`` additionally times the blocked Pallas solver per Gram
tile precision (f32 vs bf16/f16 streams) and emits
``pallas_<precision>_s`` rows into the BENCH JSON — the trend line for
the bytes-bound MXU win (meaningful on TPU; interpret-mode CPU numbers
only track that the path stays wired).
"""
from __future__ import annotations

import argparse
import json
import time

import jax

import repro
from repro.configs.ocssvm_paper import PAPER_SPEC, TABLE1_SIZES
from repro.core import mcc, solve_qp
from repro.data import make_toy
from repro.kernels.precision import parse_precisions


def _timed(fn):
    # compile (excluded, as the paper times the solve)
    out = fn()
    jax.block_until_ready(jax.tree.leaves(out)[0])
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(jax.tree.leaves(out)[0])
    return out, time.perf_counter() - t0


def run(sizes=TABLE1_SIZES, precisions=()):
    rows = []
    for m in sizes:
        X, y = make_toy(jax.random.PRNGKey(0), m)
        # gram_mode pinned per solver (the historical defaults) so timings
        # stay comparable across m and with previously recorded numbers.
        res_p, t_p = _timed(lambda: repro.fit(
            X, PAPER_SPEC, strategy="paper", gram_mode="precomputed",
            tol=1e-3, max_iters=100_000))
        res_m, t_m = _timed(lambda: repro.fit(
            X, PAPER_SPEC, strategy="mvp", gram_mode="precomputed",
            tol=1e-3, max_iters=100_000))
        res_b, t_b = _timed(lambda: repro.fit(
            X, PAPER_SPEC, strategy="blocked", gram_mode="on_the_fly",
            P=16, tol=1e-3, max_outer=50_000))
        res_q, t_q = _timed(lambda: solve_qp(
            X, PAPER_SPEC, max_iters=20_000, tol=1e-9))
        row = {
            "m": m,
            "paper_smo_s": t_p, "paper_smo_iters": int(res_p.iters),
            "paper_smo_mcc": float(mcc(y, res_p.model.predict(X))),
            "mvp_smo_s": t_m, "mvp_iters": int(res_m.iters),
            "blocked_s": t_b, "blocked_iters": int(res_b.iters),
            "qp_fista_s": t_q, "qp_iters": int(res_q.iters),
        }
        for p in precisions:
            res_x, t_x = _timed(lambda: repro.fit(
                X, PAPER_SPEC, strategy="blocked", gram_mode="pallas",
                precision=p, P=16, tol=1e-3, max_outer=50_000))
            row[f"pallas_{p}_s"] = t_x
            row[f"pallas_{p}_iters"] = int(res_x.iters)
        rows.append(row)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--reduced", action="store_true",
                    help="CI smoke: only the two smallest sizes")
    ap.add_argument("--precisions", type=str, default="",
                    help="comma list (e.g. f32,bf16): also time the "
                         "blocked Pallas solver per Gram tile precision")
    ap.add_argument("--json", type=str, default=None,
                    help="also write the rows to this path as JSON")
    args = ap.parse_args(argv)

    precisions = parse_precisions(args.precisions) if args.precisions \
        else ()
    rows = run(sizes=(500, 1000) if args.reduced else TABLE1_SIZES,
               precisions=precisions)
    for r in rows:
        print(f"table1,m={r['m']},paper_smo={r['paper_smo_s']*1e6:.0f}us"
              f"(iters={r['paper_smo_iters']}),mcc={r['paper_smo_mcc']:.3f},"
              f"mvp={r['mvp_smo_s']*1e6:.0f}us,"
              f"blocked={r['blocked_s']*1e6:.0f}us,"
              f"qp={r['qp_fista_s']*1e6:.0f}us")
        for p in precisions:
            print(f"table1_precision,m={r['m']},precision={p},"
                  f"pallas={r[f'pallas_{p}_s']*1e6:.0f}us"
                  f"(iters={r[f'pallas_{p}_iters']})")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(rows, fh, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
