"""Bench-regression gate: diff fresh BENCH_*.json against the committed
baselines under results/ and fail on missing rows or real slowdowns.

    PYTHONPATH=src python benchmarks/check_regression.py \
        --pair BENCH_table1.json results/BENCH_table1.json \
        --pair BENCH_serving.json results/BENCH_serving.json \
        --tolerance 0.25 --out BENCH_compare.json

Every benchmark JSON is flattened into ``path -> leaf`` entries; list
elements are identified by their row-identity keys (m, precision, name,
bucket) when present, so reordering rows never trips the gate while a
DROPPED row always does. Timing leaves (a key ending in ``_s``, or a
value nested directly under one — per-bucket tables) are gated:

* a baseline timing missing from the fresh file       -> FAIL (missing row)
* fresh > baseline * (1 + tolerance)                  -> FAIL (slowdown)
* baseline under ``--min-seconds``                    -> reported, not
  gated (interpret-mode micro-timings jitter far beyond any real
  regression; the floor keeps the gate about trends, not noise)
* path not matching ``--gate-only`` (when given)      -> reported, not
  gated. The autotune pair uses this to gate only the ``winners`` rows:
  a winner's ``best_s`` is a min over every candidate x repeat, stable
  enough for a 25% gate, while individual per-candidate ``time_s`` rows
  jitter far beyond it — those still fail the job when DROPPED.

Rate leaves (a key ending in ``_rate`` — the soak bench's
``deadline_miss_rate``) are gated as an absolute ceiling instead of a
ratio: ``fresh <= baseline + --rate-slack``. With the default slack of
0 and a committed baseline of 0 misses, the first fresh miss fails the
job — exactly the property a deadline soak wants.

Non-timing leaves (iteration counts, MCC, speedups) participate in the
missing-row check only. The full comparison is written to ``--out`` and
shipped as a CI artifact either way.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from typing import Dict, List, Optional

# Keys that identify a row inside a list of dicts, in preference order.
IDENTITY_KEYS = ("m", "precision", "name", "bucket")


def _flatten(node, prefix: str, out: Dict[str, object]) -> None:
    if isinstance(node, dict):
        for k, v in node.items():
            _flatten(v, f"{prefix}.{k}" if prefix else str(k), out)
    elif isinstance(node, list):
        for i, v in enumerate(node):
            tag = str(i)
            if isinstance(v, dict):
                ids = [f"{k}={v[k]}" for k in IDENTITY_KEYS
                       if k in v and not isinstance(v[k], (dict, list))]
                if ids:
                    tag = ",".join(ids)
            _flatten(v, f"{prefix}[{tag}]", out)
    else:
        out[prefix] = node


def flatten(doc) -> Dict[str, object]:
    out: Dict[str, object] = {}
    _flatten(doc, "", out)
    return out


def _is_timing(path: str) -> bool:
    """A leaf is a gated timing if its key ends in _s, or it sits directly
    under a *_s table (per-bucket dicts: warm_per_bucket_s."64")."""
    segs = [s for s in path.replace("]", "").replace("[", ".").split(".")
            if s]
    if not segs:
        return False
    if segs[-1].endswith("_s"):
        return True
    return len(segs) >= 2 and segs[-2].endswith("_s")


def _is_rate(path: str) -> bool:
    """A leaf whose key ends in ``_rate`` is gated as an ABSOLUTE
    ceiling, not a ratio: ratios are meaningless against the baselines
    that matter most (a committed deadline-miss rate of exactly 0), so
    the gate is ``fresh <= baseline + rate_slack``. A soak baseline of 0
    misses therefore fails the job on the FIRST fresh miss."""
    segs = [s for s in path.replace("]", "").replace("[", ".").split(".")
            if s]
    return bool(segs) and segs[-1].endswith("_rate")


def compare_pair(fresh_path: str, baseline_path: str, *, tolerance: float,
                 min_seconds: float, rate_slack: float = 0.0,
                 gate_only: Optional[str] = None) -> dict:
    with open(fresh_path) as fh:
        fresh = flatten(json.load(fh))
    with open(baseline_path) as fh:
        baseline = flatten(json.load(fh))

    missing: List[str] = []
    regressions: List[dict] = []
    ungated: List[dict] = []
    checked = 0
    for path, base_v in sorted(baseline.items()):
        if path not in fresh:
            missing.append(path)
            continue
        if _is_rate(path) and isinstance(base_v, (int, float)):
            new_v = fresh[path]
            if not isinstance(new_v, (int, float)):
                missing.append(path)
                continue
            entry = {"path": path, "baseline_rate": base_v,
                     "fresh_rate": new_v, "slack": rate_slack}
            if gate_only is not None and not re.search(gate_only, path):
                ungated.append(entry)
                continue
            checked += 1
            if float(new_v) > float(base_v) + rate_slack:
                regressions.append(entry)
            continue
        if not (_is_timing(path) and isinstance(base_v, (int, float))):
            continue
        new_v = fresh[path]
        if not isinstance(new_v, (int, float)):
            missing.append(path)   # shape change: timing became non-numeric
            continue
        ratio = (float(new_v) / float(base_v)) if base_v > 0 else 1.0
        entry = {"path": path, "baseline_s": base_v, "fresh_s": new_v,
                 "ratio": round(ratio, 3)}
        if float(base_v) < min_seconds or (
                gate_only is not None and not re.search(gate_only, path)):
            ungated.append(entry)
            continue
        checked += 1
        if ratio > 1.0 + tolerance:
            regressions.append(entry)

    return {
        "fresh": fresh_path,
        "baseline": baseline_path,
        "checked_timings": checked,
        "missing_rows": missing,
        "regressions": regressions,
        "below_noise_floor": ungated,
        "ok": not missing and not regressions,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pair", nargs=2, action="append", required=True,
                    metavar=("FRESH", "BASELINE"),
                    dest="pairs", help="fresh JSON vs committed baseline")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed fractional slowdown (default 0.25)")
    ap.add_argument("--min-seconds", type=float, default=0.05,
                    help="baseline timings under this are not gated")
    ap.add_argument("--rate-slack", type=float, default=0.0,
                    help="allowed ABSOLUTE increase for *_rate leaves "
                         "(default 0.0: a zero-miss baseline fails on "
                         "the first fresh miss)")
    ap.add_argument("--gate-only", default=None, metavar="REGEX",
                    help="gate only timing paths matching this regex "
                         "(missing-row checks still cover everything)")
    ap.add_argument("--out", default="BENCH_compare.json",
                    help="where to write the comparison report")
    args = ap.parse_args(argv)

    results = [compare_pair(f, b, tolerance=args.tolerance,
                            min_seconds=args.min_seconds,
                            rate_slack=args.rate_slack,
                            gate_only=args.gate_only)
               for f, b in args.pairs]
    ok = all(r["ok"] for r in results)
    report = {"ok": ok, "tolerance": args.tolerance,
              "min_seconds": args.min_seconds, "rate_slack": args.rate_slack,
              "gate_only": args.gate_only, "pairs": results}
    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=1)

    for r in results:
        status = "ok" if r["ok"] else "FAIL"
        print(f"{status}: {r['fresh']} vs {r['baseline']} — "
              f"{r['checked_timings']} timings gated, "
              f"{len(r['missing_rows'])} missing, "
              f"{len(r['regressions'])} regressions "
              f"({len(r['below_noise_floor'])} below noise floor)")
        for path in r["missing_rows"]:
            print(f"  missing: {path}")
        for e in r["regressions"]:
            if "baseline_rate" in e:
                print(f"  rate: {e['path']} {e['baseline_rate']:.4f} -> "
                      f"{e['fresh_rate']:.4f} (slack {e['slack']:.4f})")
            else:
                print(f"  slowdown: {e['path']} {e['baseline_s']:.4f}s -> "
                      f"{e['fresh_s']:.4f}s ({e['ratio']:.2f}x)")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
