"""Benchmark driver — one section per paper table/figure plus the
beyond-paper additions. Prints ``name,us_per_call,derived`` CSV lines.

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import sys

from benchmarks import (blocked_smo_scaling, fig_slab_recovery,
                        kernel_microbench, roofline_report,
                        serving_latency, smo_pod_scale,
                        table1_training_time)


def main() -> None:
    quick = "--quick" in sys.argv
    print("# === paper Table 1: training time & MCC vs m ===")
    if quick:
        for r in table1_training_time.run(sizes=(500, 1000)):
            print(f"table1,m={r['m']},paper_smo={r['paper_smo_s']*1e6:.0f}us,"
                  f"mcc={r['paper_smo_mcc']:.3f}")
    else:
        table1_training_time.main([])
    print("# === paper Figs 1-2: slab recovery ===")
    fig_slab_recovery.main()
    print("# === beyond-paper: blocked-SMO scaling ===")
    if not quick:
        blocked_smo_scaling.main()
    print("# === serving: warm cache + bucketed Pallas scoring ===")
    serving_latency.main(["--reduced"] if quick else [])
    print("# === Pallas kernel microbench (interpret mode) ===")
    kernel_microbench.main()
    print("# === the paper's solver at pod scale (m=1M, 256/512 chips) ===")
    smo_pod_scale.main()
    print("# === roofline table from the dry-run sweep ===")
    roofline_report.main()


if __name__ == "__main__":
    main()
